"""The `repro.api` front door: Strategy validation, Program.compile,
Session execution parity across executors, and dynamic switching.

Multi-device JaxExecutor parity runs in the subprocess selftest
(``api:session/{2,4,8}`` cases asserted in test_runtime.py); here the
in-process tier covers planning, the SimulatorExecutor end-to-end, a
single-device JaxExecutor parity check, and the validation surface.
"""

import numpy as np
import pytest

from repro import api


# ---------------------------------------------------------------------------
# fixtures: the quickstart pipeline program
# ---------------------------------------------------------------------------

def pipeline_graph():
    g = api.Graph()
    g.placeholder("X", (8, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"), name="H")
    g.comm(h, name="H2")
    g.parameter("W2", (12, 6))
    g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
    return g


def pipeline_strategies():
    s0, s1 = [0, 1, 2, 3], [4, 5, 6, 7]
    tp = api.Strategy("tp-pipeline", {
        "X": api.spmd(s0, api.DS({api.DUP: 4})),
        "W1": api.spmd(s0, api.DS({1: 4})),
        "H2": api.spmd(s1, api.DS({0: 4})),
        "W2": api.spmd(s1, api.DS({api.DUP: 4})),
    })
    dp = api.Strategy("dp", {
        "X": api.spmd(s0, api.DS({0: 4})),
        "W1": api.spmd(s0, api.DS({api.DUP: 4})),
        "H2": api.spmd(s0, api.DS({0: 4})),
        "W2": api.spmd(s0, api.DS({api.DUP: 4})),
    })
    return [tp, dp]


def pipeline_values():
    rng = np.random.default_rng(0)
    xv = rng.integers(-4, 5, (8, 16)).astype(np.float32)
    w1v = rng.integers(-4, 5, (16, 12)).astype(np.float32)
    w2v = rng.integers(-4, 5, (12, 6)).astype(np.float32)
    return xv, w1v, w2v, np.maximum(xv @ w1v, 0) @ w2v


# ---------------------------------------------------------------------------
# Strategy validation
# ---------------------------------------------------------------------------

def test_strategy_rejects_empty_name():
    with pytest.raises(api.StrategyError, match="non-empty"):
        api.Strategy("", {"W": api.spmd([0], api.DS({}))})


def test_strategy_rejects_empty_bundle():
    with pytest.raises(api.StrategyError, match="empty annotation"):
        api.Strategy("s", {})


def test_strategy_rejects_non_hspmd_annotation():
    with pytest.raises(api.StrategyError, match="expected HSPMD"):
        api.Strategy("s", {"W": api.DS({0: 2})})


def test_strategy_rejects_bad_topology():
    with pytest.raises(api.StrategyError, match="Topology"):
        api.Strategy("s", {"W": api.spmd([0], api.DS({}))},
                     topology="nvlink")


def test_program_rejects_missing_annotation_point():
    g = pipeline_graph()
    incomplete = api.Strategy("partial", {
        "X": api.spmd([0], api.DS({}))})
    with pytest.raises(api.StrategyError, match="misses annotations"):
        api.Program(g, [incomplete])


def test_program_rejects_unknown_tensor_annotation():
    g = api.Graph()
    g.parameter("W", (4, 4))
    typo = api.Strategy("s", {"W": api.spmd([0], api.DS({})),
                              "Wv": api.spmd([0], api.DS({}))})
    with pytest.raises(api.StrategyError, match="unknown tensors"):
        api.Program(g, [typo])


def test_program_rejects_duplicate_strategy_names():
    g = api.Graph()
    g.parameter("W", (4, 4))
    s = api.Strategy("same", {"W": api.spmd([0], api.DS({}))})
    with pytest.raises(api.StrategyError, match="duplicate"):
        api.Program(g, [s, s])


def test_program_rejects_unknown_strategy_lookup():
    g = api.Graph()
    g.parameter("W", (4, 4))
    prog = api.Program(g, [api.Strategy(
        "only", {"W": api.spmd([0], api.DS({}))})])
    with pytest.raises(api.StrategyError, match="unknown strategy"):
        prog.compile("nope")


def test_ds_rejects_duplicate_special_entries():
    """Regression: duplicate DUP/PARTIAL entries used to pass _norm_entries
    silently (only d >= 0 was de-duped), corrupting num_devices."""
    with pytest.raises(ValueError, match="Duplicate annotated twice"):
        api.DS([(api.DUP, 2), (api.DUP, 2)])
    with pytest.raises(ValueError, match="Partial annotated twice"):
        api.DS([(api.PARTIAL, 2), (0, 2), (api.PARTIAL, 3)])
    with pytest.raises(ValueError, match="dim 1 annotated twice"):
        api.DS([(1, 2), (1, 2)])


# ---------------------------------------------------------------------------
# Program.compile on the quickstart hetero case
# ---------------------------------------------------------------------------

def test_compile_pipeline_plan():
    prog = api.Program(pipeline_graph(), pipeline_strategies())
    assert prog.report.n_strategies == 2
    plan = prog.compile("tp-pipeline")
    assert plan.devices == tuple(range(8))
    # stage-0 device: compute then the P2P comm; stage-1: comm then compute
    kinds0 = [i.kind for i in plan.exec_items(0)]
    assert "dot" in kinds0 and "relu" in kinds0 and "BSR" in kinds0
    roles5 = [i.role for i in plan.exec_items(5)]
    assert set(roles5) == {"compute", "comm"}
    # pipelines link stage 0 devices to the stage-1 group
    assert all(len(p.stages) == 2 for p in plan.specialization.pipelines)
    assert plan.cost.flops > 0
    assert plan.cost.comm_messages > 0
    assert "BSR" in plan.cost.per_kind_bytes
    assert "tp-pipeline" in plan.describe()


def test_compile_hetero_hsplits_strategy():
    """The quickstart's heterogeneous annotation (3:1 hsplit) compiles."""
    g = api.Graph()
    g.parameter("W", (12, 8))
    g.comm(g.tensors["W"], name="W2")
    hetero = api.HSPMD(dgs=[[0, 1], [2]],
                       dss=[api.DS({1: 2}), api.DS({})],
                       hdim=0, hsplits=[3, 1])
    strat = api.Strategy("hetero", {
        "W": api.spmd([0, 1, 2], api.DS({0: 3})),
        "W2": hetero,
    })
    plan = api.Program(g, [strat]).compile("hetero")
    assert plan.comm_plans[0].kind == "fallback:BSR"
    assert plan.devices == (0, 1, 2)


def test_compile_symbolic_shape_requires_env():
    from repro.core.symbolic import Sym
    g = api.Graph()
    g.parameter("W", (Sym("B"), 8))
    prog = api.Program(g, [api.Strategy(
        "s", {"W": api.spmd([0, 1], api.DS({1: 2}))})])
    with pytest.raises(api.CompileError, match="unbound symbolic"):
        prog.compile("s")
    plan = prog.compile("s", shape_env={"B": 6})
    assert plan.shapes["W"] == (6, 8)


def test_program_clears_stale_deduced_annotations():
    """Regression: wrapping a previously-deduced multi-strategy graph
    with fewer Strategies must not inherit phantom strategies from stale
    intermediate annotations."""
    g = api.Graph()
    g.parameter("W", (8, 4), [api.spmd([0, 1], api.DS({0: 2})),
                              api.spmd([0, 1], api.DS({1: 2}))])
    g.relu(g.tensors["W"], name="R")
    g.deduce()
    prog = api.Program(g, [api.Strategy(
        "one", {"W": api.spmd([0], api.DS({}))})])
    assert prog.report.n_strategies == 1
    assert prog.compile("one").devices == (0,)


def test_executors_share_result_dtype_rule():
    """Regression: int inputs through gelu must yield the same (float32)
    dtype on both executors instead of numpy promoting to float64 while
    jax truncates back to int."""
    from repro.core.op_semantics import result_dtype
    assert result_dtype("gelu", [np.dtype(np.int32)]) == np.float32
    assert result_dtype("dot", [np.dtype(np.float32)] * 2) == np.float32
    g = api.Graph()
    g.placeholder("X", (4,))
    g.gelu(g.tensors["X"], name="Y")
    prog = api.Program(g, [api.Strategy(
        "s", {"X": api.spmd([0], api.DS({}))})])
    sess = api.Session(prog, "s")
    out = sess.run({"X": np.arange(4, dtype=np.int32)}).shards("Y")
    assert out.parts[0].dtype == np.float32


def test_from_annotated_shim():
    """Pre-API graphs (leaves annotated directly) wrap into a Program."""
    g = api.Graph()
    g.parameter("W", (8, 8), [api.spmd([0, 1], api.DS({0: 2})),
                              api.spmd([2, 3], api.DS({1: 2}))])
    prog = api.Program.from_annotated(g, names=["old", "new"])
    assert prog.names == ["old", "new"]
    assert prog.compile("new").devices == (2, 3)


# ---------------------------------------------------------------------------
# Session: run parity + switching numerics
# ---------------------------------------------------------------------------

def test_session_run_simulator():
    prog = api.Program(pipeline_graph(), pipeline_strategies())
    xv, w1v, w2v, want = pipeline_values()
    sess = api.Session(prog, "tp-pipeline")
    sess.load({"W1": w1v, "W2": w2v})
    out = sess.run({"X": xv})
    np.testing.assert_array_equal(out.value("Y"), want)
    # shards actually live on the stage-1 devices, row-split
    assert sorted(out.shards("Y").parts) == [4, 5, 6, 7]
    assert out.shards("Y").parts[4].shape == (2, 6)


def test_session_executor_parity_single_device():
    """Sim vs jax executor, bit-exact — single device (the multi-device
    2/4/8 sweep is the selftest's api:session cases)."""
    g = api.Graph()
    g.placeholder("X", (4, 8))
    g.parameter("W", (8, 6))
    g.dot(g.tensors["X"], g.tensors["W"], name="Y")
    strat = api.Strategy("solo", {
        "X": api.spmd([0], api.DS({})),
        "W": api.spmd([0], api.DS({})),
    })
    prog = api.Program(g, [strat])
    rng = np.random.default_rng(1)
    xv = rng.integers(-4, 5, (4, 8)).astype(np.float32)
    wv = rng.integers(-4, 5, (8, 6)).astype(np.float32)
    outs = {}
    for ex in (api.SimulatorExecutor(), api.JaxExecutor()):
        sess = api.Session(prog, "solo", executor=ex)
        sess.load({"W": wv})
        outs[ex.name] = sess.run({"X": xv}).shards("Y").parts[0]
    np.testing.assert_array_equal(outs["sim"], outs["jax"])
    np.testing.assert_array_equal(outs["sim"], xv @ wv)


def test_session_switch_numerics():
    prog = api.Program(pipeline_graph(), pipeline_strategies())
    xv, w1v, w2v, want = pipeline_values()
    sess = api.Session(prog, "tp-pipeline")
    sess.load({"W1": w1v, "W2": w2v})
    report = sess.switch("dp")
    assert report.message_count > 0
    assert sess.strategy.name == "dp"
    # weights re-sharded exactly; outputs unchanged under the new strategy
    np.testing.assert_array_equal(sess.weight_value("W1"), w1v)
    np.testing.assert_array_equal(sess.weight_value("W2"), w2v)
    out = sess.run({"X": xv})
    np.testing.assert_array_equal(out.value("Y"), want)
    assert sorted(out.shards("Y").parts) == [0, 1, 2, 3]
    # switching to the active strategy is a no-op
    assert sess.switch("dp").message_count == 0


def test_session_validates_feeds_and_weights():
    prog = api.Program(pipeline_graph(), pipeline_strategies())
    xv, w1v, w2v, _ = pipeline_values()
    sess = api.Session(prog, "tp-pipeline")
    with pytest.raises(ValueError, match="not a parameter"):
        sess.load({"X": xv})
    sess.load({"W1": w1v})
    with pytest.raises(ValueError, match="not loaded"):
        sess.run({"X": xv})
    sess.load({"W2": w2v})
    with pytest.raises(ValueError, match="missing feed"):
        sess.run({})
    with pytest.raises(ValueError, match="unknown feeds"):
        sess.run({"X": xv, "Z": xv})
    with pytest.raises(api.StrategyError, match="unknown strategy"):
        sess.switch("never-registered")


def test_session_switch_same_strategy_validates_weights():
    """Regression: the same-strategy fast path used to return an empty
    SwitchReport without the unloaded-parameter validation the normal
    path does — switching with unloaded weights must raise regardless of
    the destination."""
    prog = api.Program(pipeline_graph(), pipeline_strategies())
    xv, w1v, w2v, _ = pipeline_values()
    sess = api.Session(prog, "tp-pipeline")
    sess.load({"W1": w1v})  # W2 still unloaded
    with pytest.raises(ValueError, match="unloaded parameters.*W2"):
        sess.switch("tp-pipeline")
    sess.load({"W2": w2v})
    assert sess.switch("tp-pipeline").message_count == 0  # now a no-op


def test_get_executor_rejects_unknown_kwargs():
    """Regression: get_executor("sim", reduction=...) silently dropped
    all kwargs — typo'd options must fail loudly for both executors."""
    assert api.get_executor("sim").name == "sim"
    assert api.get_executor("sim", record_ticks=True).record_ticks
    assert api.get_executor("jax", reduction="fast").name == "jax"
    with pytest.raises(TypeError, match="reduction"):
        api.get_executor("sim", reduction="fast")
    with pytest.raises(TypeError, match="reductoin"):
        api.get_executor("jax", reductoin="fast")
    with pytest.raises(ValueError, match="unknown executor"):
        api.get_executor("tpu")


def test_weights_program_and_dp_strategy_helpers():
    shapes = {"a": (8, 4), "b": (6, 2), "scalar": ()}
    full = api.data_parallel_strategy("full", [0, 1, 2, 3], shapes)
    half = api.data_parallel_strategy("half", [0, 1], shapes)
    prog = api.Program(api.weights_graph(shapes), [full, half])
    rng = np.random.default_rng(2)
    values = {k: rng.normal(size=s).astype(np.float32)
              for k, s in shapes.items()}
    sess = api.Session(prog, "full")
    sess.load(values)
    report = sess.switch("half")
    assert report.total_bytes > 0
    for k, v in values.items():
        np.testing.assert_allclose(sess.weight_value(k), v, atol=1e-6)


def test_program_owns_a_graph_copy():
    """Regression: a second Program over the same graph must not rebind
    the first Program's annotations (live Sessions read them)."""
    g = api.Graph()
    g.parameter("W", (8, 4))
    a = api.Strategy("A", {"W": api.spmd([0, 1], api.DS({0: 2}))})
    b = api.Strategy("B", {"W": api.spmd([2, 3], api.DS({1: 2}))})
    sess = api.Session(api.Program(g, [a]), "A")
    wv = np.arange(32, dtype=np.float32).reshape(8, 4)
    sess.load({"W": wv})
    api.Program(g, [b])  # must not corrupt sess's placement
    assert sorted(sess.weights["W"].parts) == [0, 1]
    np.testing.assert_array_equal(sess.weight_value("W"), wv)
    assert not g.tensors["W"].annots  # caller's graph left untouched


def test_session_switch_uses_strategy_topology():
    """Regression: SwitchReport must be priced on the strategy topology
    (same fallback as Program.compile), not UniformTopology."""
    topo = api.NvlinkIbTopology(gpus_per_node=2)
    shapes = {"w": (16, 4)}
    full = api.data_parallel_strategy("full", [0, 1, 2, 3], shapes,
                                      topology=topo)
    solo = api.data_parallel_strategy("solo", [0], shapes, topology=topo)
    prog = api.Program(api.weights_graph(shapes), [full, solo])
    sess = api.Session(prog, "full")
    sess.load({"w": np.ones((16, 4), np.float32)})
    report = sess.switch("solo")
    # priced on the strategy topology AND the live float32 itemsize
    want = api.estimate_switch(
        [("w", full.annots["w"], solo.annots["w"], shapes["w"], 4)], topo)
    assert report.est_transfer_seconds == \
        pytest.approx(want.est_transfer_seconds)
    assert report.total_bytes == want.total_bytes


def test_estimate_switch_matches_session_report():
    shapes = {"w": (16, 4)}
    full = api.data_parallel_strategy("full", [0, 1, 2, 3], shapes)
    solo = api.data_parallel_strategy("solo", [0], shapes)
    report = api.estimate_switch(
        [("w", full.annots["w"], solo.annots["w"], shapes["w"], 2)])
    assert report.message_count == 3  # three shards converge on device 0
    assert report.total_bytes == 3 * 4 * 4 * 2
