"""Specialization-class partition (`core.lowered_ir`): the layer both
executors lower onto.

Property tests for the partition itself — homogeneous TP/DP strategies
collapse to exactly one class per segment, pipeline fixtures get one
participant class plus idle devices, the hsize=2 hetero fixture gets the
two classes its two shard geometries demand, and the partition structure
is invariant under device renumbering.  Every partition is cross-checked
against progressive specialization's per-device ExecItems (the ground
truth, ``check_against_exec_items``).  The matching emission accounting
(``LoweringStats.switch_branches_emitted`` etc.) is asserted on real
lowered programs in the runtime selftest and the graph-block benchmark
smoke; bit-exact sim<->jax training across m x {1f1b, gpipe,
interleaved} on the refactored path runs in ``tests/test_runtime.py``
(``api:train/*`` selftest cases).
"""

import numpy as np
import pytest

from repro import api
from repro.api.testing import (hetero_program, hetero_values,
                               loss_pipeline_program, loss_pipeline_values)
from repro.core.lowered_ir import (CommSlot, Segment, SegmentClass,
                                   check_against_exec_items,
                                   partition_graph)


def uniform_program(n, x_ds, w_ds, name="uni"):
    """One-segment program (no comm): ``L = sum(relu(X @ W))`` with the
    leaves sharded per ``x_ds`` / ``w_ds`` over all ``n`` devices."""
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W", (16, 8))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W"], name="H0"), name="H")
    g.sum(g.sum(h, 1, name="L1"), 0, name="L")
    devs = list(range(n))
    strat = api.Strategy(name, {
        "X": api.spmd(devs, x_ds),
        "W": api.spmd(devs, w_ds),
    })
    return api.Program(g, [strat])


def pipe_program(s0, s1, name="pipe"):
    """The 2-stage loss pipeline with EXPLICIT device groups (the
    testing fixture with renumberable devices)."""
    half = len(s0)
    col = api.DS({1: half}) if half > 1 else api.DS({})
    row = api.DS({0: half}) if half > 1 else api.DS({})
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
               name="H")
    g.comm(h, name="H2")
    g.parameter("W2", (12, 6))
    y = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    strat = api.Strategy(name, {
        "X": api.spmd(list(s0), api.DS({api.DUP: half})),
        "W1": api.spmd(list(s0), col),
        "H2": api.spmd(list(s1), row),
        "W2": api.spmd(list(s1), api.DS({api.DUP: half})),
    })
    return api.Program(g, [strat])


def ir_of(plan):
    return partition_graph(plan.graph, plan.strategy_index,
                           shapes=plan.shapes)


def structure(ir):
    """Renumbering-invariant shape of a partition: per segment, the
    sorted multiset of (class size, per-op specs)."""
    return [sorted((c.n_devices, c.specs) for c in seg.classes)
            for seg in ir.segments]


# -- homogeneous strategies: exactly one class -------------------------------

@pytest.mark.parametrize("n", [2, 4, 8])
@pytest.mark.parametrize("kind", ["dp", "tp"])
def test_homogeneous_single_class(kind, n):
    """Pure DP (batch row-split) and pure TP (column-split) put every
    device in ONE class for EVERY segment: the straight-line case the
    jax lowering emits with zero switches."""
    dup = api.DS({api.DUP: n})
    if kind == "dp":                # batch row-split, weight replicated
        x_ds, w_ds = api.DS({0: n}), dup
    else:                           # TP: weight column-split
        x_ds, w_ds = dup, api.DS({1: n})
    plan = uniform_program(n, x_ds, w_ds, name=kind).compile(kind)
    ir = ir_of(plan)
    assert len(ir.segments) >= 1
    for seg in ir.segments:
        assert seg.is_homogeneous(), seg.describe()
        assert seg.classes[0].devices == tuple(range(n))
    assert ir.class_counts() == [1] * len(ir.segments)
    check_against_exec_items(ir, plan.specialization)


def test_homogeneous_training_graph_single_class():
    """The joint fwd+bwd graph of a homogeneous strategy stays
    single-class in every compute segment (backward ops included)."""
    plan = uniform_program(4, api.DS({0: 4}), api.DS({api.DUP: 4}),
                           name="dp").compile_train("dp")
    ir = ir_of(plan)
    assert all(seg.is_homogeneous() for seg in ir.segments), \
        ir.describe()
    check_against_exec_items(ir, plan.specialization)


# -- pipeline stages: one participant class + idle devices -------------------

@pytest.mark.parametrize("n", [2, 4])
def test_pipeline_stage_classes(n):
    """Each stage's segment has exactly one participant class (the
    stage's devices) and the other stage idle — the lowering emits one
    real branch + one zero branch, never per-device branches."""
    plan = loss_pipeline_program(n).compile("pipe")
    ir = ir_of(plan)
    half = n // 2
    s0, s1 = tuple(range(half)), tuple(range(half, n))
    assert len(ir.segments) == 2 and len(ir.comm_slots) == 1
    first, second = ir.segments
    assert first.n_classes == 1 and first.classes[0].devices == s0
    assert first.idle_devices == s1
    assert second.n_classes == 1 and second.classes[0].devices == s1
    assert second.idle_devices == s0
    for dev in s0:
        assert first.class_of(dev) == 0 and second.class_of(dev) is None
    check_against_exec_items(ir, plan.specialization)


def test_entries_alternate_with_comm_slots():
    plan = loss_pipeline_program(4).compile("pipe")
    ir = ir_of(plan)
    kinds = [type(e) for e in ir.entries]
    assert kinds == [Segment, CommSlot, Segment]
    assert ir.comm_slots[0].op.outputs[0].name == "H2"
    assert ir.total_classes() == 2
    assert "classes" in ir.describe()


# -- hetero (hsize=2): one class per shard geometry --------------------------

def test_hetero_two_classes_per_segment():
    """The hsize=2 fixture (subgroup [0,1] row-splits its slab, [2,3]
    duplicates) yields exactly TWO classes in each segment — one per
    local shard geometry — and the class specs really differ in their
    local input shapes."""
    plan = hetero_program().compile("het")
    ir = ir_of(plan)
    assert ir.class_counts() == [2, 2], ir.describe()
    for seg in ir.segments:
        assert not seg.idle_devices
        (a, b) = seg.classes
        assert {a.devices, b.devices} == {(0, 1), (2, 3)}
        assert a.specs != b.specs
    check_against_exec_items(ir, plan.specialization)


def test_hetero_training_partition_checks_out():
    plan = hetero_program().compile_train("het")
    ir = ir_of(plan)
    assert all(seg.n_classes >= 1 for seg in ir.segments)
    check_against_exec_items(ir, plan.specialization)


# -- renumbering invariance --------------------------------------------------

def test_partition_structure_stable_under_renumbering():
    """Permuting the device ids permutes class MEMBERS but leaves the
    partition structure (class sizes and per-op specs) identical."""
    base = pipe_program([0, 1], [2, 3], name="a").compile("a")
    renum = pipe_program([3, 1], [0, 2], name="b").compile("b")
    ir_a, ir_b = ir_of(base), ir_of(renum)
    assert structure(ir_a) == structure(ir_b)
    # members really moved: stage 0 is {0,1} in one, {1,3} in the other
    assert ir_a.segments[0].classes[0].devices == (0, 1)
    assert set(ir_b.segments[0].classes[0].devices) == {1, 3}
    check_against_exec_items(ir_b, renum.specialization)


def test_hetero_structure_stable_under_subgroup_swap():
    """Swapping which devices form the split vs duplicated subgroup
    keeps the same two-class structure."""
    ha = hetero_program().compile("het")
    ir = ir_of(ha)
    sizes = [sorted(c.n_devices for c in seg.classes)
             for seg in ir.segments]
    assert sizes == [[2, 2], [2, 2]]


# -- partition feeds the emitters --------------------------------------------

def test_class_specs_match_device_shards():
    """Each class's OpSpec shapes equal the actual per-device shard
    shapes the simulator executes with (integer fixture values)."""
    plan = hetero_program().compile("het")
    xv, ws, _, _ = hetero_values()
    ir = ir_of(plan)
    k, shapes = plan.strategy_index, plan.shapes
    for seg in ir.segments:
        for cls in seg.classes:
            for op, spec in zip(seg.ops, cls.specs):
                if spec is None:
                    continue
                for dev in cls.devices:
                    for t, shp in zip(op.inputs, spec.in_shapes):
                        want = t.annots[k].device_shape(
                            dev, shapes[t.name])
                        assert tuple(want) == tuple(shp)


def test_segment_class_dataclass_basics():
    cls = SegmentClass(devices=(0, 1), specs=(None,))
    assert cls.n_devices == 2
    seg = Segment(ops=[], classes=[cls], idle_devices=(2,))
    assert not seg.is_homogeneous()
    assert seg.class_of(0) == 0 and seg.class_of(2) is None
    assert "idle=1" in seg.describe()


# -- executed parity on the partitioned path (sim, in-process) ---------------

def test_sim_vectorized_path_matches_reference_values():
    """The class-vectorized simulator dispatch produces the exact
    integer-fixture loss and gradients (stacked numpy application is
    bit-identical to per-device application)."""
    prog = hetero_program()
    xv, ws, want_loss, want_grads = hetero_values()
    sess = api.Session(prog, "het")
    sess.load(ws)
    r = sess.train_step({"X": xv})
    assert r.loss == want_loss
    for name, want in want_grads.items():
        for dev, part in r.grads[name].parts.items():
            np.testing.assert_array_equal(part, want.astype(np.float32))


def test_sim_vectorized_pipeline_matches_reference_values():
    prog = loss_pipeline_program(4)
    xv, ws, want_y = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load(ws)
    r = sess.train_step({"X": xv}, num_microbatches=4, schedule="1f1b")
    assert r.loss == float(want_y.sum())
