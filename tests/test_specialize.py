"""Graph specialization + pipeline construction tests (paper §5.3-5.4, Fig 9)."""

import pytest

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd
from repro.core.graph import Graph
from repro.core.specialize import (construct_pipelines, resolve_comm_ops,
                                   specialize)


def _fig9_graph():
    """The paper's Fig 9 running example.

    Heterogeneous deployment: X lives on a DG union spanning GPUs 0-4
    (TP pair {0,3}, CP pair {2,4}, solo {1}); W is resharded by CommOp
    (id=1); the Dot result Y is resharded by CommOp (id=2) toward GPUs
    {0, 5, 6} where the next stage runs (pipeline P2P to {5, 6}).
    """
    g = Graph()
    # TP (row-parallel) on {0,3}: X split on contraction dim, W on rows;
    # CP-ish pair {2,4}: X split on batch; solo {1}.
    x_annot = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                    dss=[DS({2: 2}), DS({0: 2}), DS({})], hdim=0)
    w_dup = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                  dss=[DS({DUP: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    w_tp = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                 dss=[DS({0: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    x = g.placeholder("X", (12, 16, 32), [x_annot])
    w = g.parameter("W", (32, 64), [w_dup])
    x2 = g.gelu(x)
    w2 = g.comm(w, w_tp)            # CommOp id=1 (one-shot, parameter)
    y = g.dot(x2, w2, name="Y")     # subgroup {0,3} yields Partial
    # CommOp id=2: subgroup {0,3} RS in place; subgroup {2,4} hands off to
    # the next pipeline stage {5,6} with a resharded layout (BSR)
    y_next = HSPMD(dgs=[[0, 3], [5, 6], [1]],
                   dss=[DS({0: 2}), DS({1: 2}), DS({})], hdim=0)
    g.comm(y, y_next, name="Y2")
    g.deduce()
    return g


def test_fig9_deduction_shapes():
    g = _fig9_graph()
    y = g.tensors["Y"]
    # TP subgroup {0,3}: matched contraction splits -> Partial; pair {2,4}
    # keeps its batch split; solo {1} unsharded
    assert y.annot.dss[0].get(PARTIAL) == 2
    assert y.annot.dss[1].get(0) == 2
    assert y.annot.hdim == 0


def test_fig9_commop_resolution_kinds():
    g = _fig9_graph()
    rcs = resolve_comm_ops(g)
    assert len(rcs) == 2
    # id=1: Dup -> row-split is a pure local slice (zero comm)
    assert rcs[0].plan.nbytes_moved() == 0
    # id=2: RS for subgroup {0,3}, BSR toward {5,6}, ID for {1} — the
    # paper's per-subgroup heterogeneous substitution (Fig 9)
    assert rcs[1].plan.kind == "bottom:BSR+ID+RS"


def test_fig9_specialization_prunes_nonlocal():
    g = _fig9_graph()
    # GPU6 participates only in the final CommOp (Fig 9: everything else
    # is removed from its executable graph)
    eg6 = specialize(g, 6)
    assert all(i.role == "comm" for i in eg6.items)
    assert len(eg6.items) >= 1
    # GPU0 runs gelu + dot + both comm ops
    eg0 = specialize(g, 0)
    kinds = eg0.kinds()
    assert "gelu" in kinds and "dot" in kinds


def test_fig9_device_specific_comm_substitution():
    """The same CommOp materializes as different operators per device."""
    g = _fig9_graph()
    eg0 = specialize(g, 0)   # TP member: substitutes CommOp id=2 with RS
    eg5 = specialize(g, 5)   # next-stage device: receives via BSR
    comm0 = [i.kind for i in eg0.items if i.role == "comm"]
    comm5 = [i.kind for i in eg5.items if i.role == "comm"]
    assert comm5 == ["BSR"]
    assert "RS" in comm0 and "BSR" not in comm0


def test_fig9_pipeline_construction():
    g = _fig9_graph()
    pipes = construct_pipelines(g)
    # devices 5,6 are appended as a successor stage; collective partners
    # merge into the first stage
    stages_flat = [sorted(s) for p in pipes for s in p.stages]
    assert any(5 in s and 6 in s for s in stages_flat)
    # the RS collective merges the TP pair {0,3} into one stage
    assert any(s == [0, 3] for s in stages_flat)
    # {5,6} are appended as a successor stage of their P2P senders {2,4}
    for p in pipes:
        devs = p.devices()
        if 5 in devs:
            isend = next(i for i, s in enumerate(p.stages)
                         if 2 in s or 4 in s)
            i5 = next(i for i, s in enumerate(p.stages) if 5 in s)
            assert isend < i5


def test_tp_ar_merges_pipeline():
    """Megatron-style TP pair: the partial->dup AR merges both devices into
    one pipeline stage."""
    g = Graph()
    x = g.placeholder("X", (4, 8, 32), [spmd([0, 1], DS({2: 2}))])
    w = g.parameter("W", (32, 16), [spmd([0, 1], DS({0: 2}))])
    y = g.dot(x, w)
    g.comm(y, spmd([0, 1], DS({DUP: 2})))
    g.deduce()
    pipes = construct_pipelines(g)
    assert len(pipes) == 1
    assert pipes[0].stages == [{0, 1}]


def test_two_stage_pipeline_via_sr():
    """Activation SR to fresh devices forms a 2-stage pipeline."""
    g = Graph()
    x = g.placeholder("X", (4, 8, 32), [spmd([0, 1], DS({0: 2}))])
    w = g.parameter("W", (32, 32), [spmd([0, 1], DS({DUP: 2}))])
    y = g.dot(x, w)
    g.comm(y, spmd([2, 3], DS({0: 2})))
    g.deduce()
    pipes = construct_pipelines(g)
    assert len(pipes) == 2  # two independent DP pipelines... no:
    # devices {0,1} are split-DP with no collective binding them; each SR
    # edge appends its receiver: {0}->{2}, {1}->{3}
    all_stages = sorted(tuple(sorted(s)) for p in pipes for s in p.stages)
    assert all_stages == [(0,), (1,), (2,), (3,)]
