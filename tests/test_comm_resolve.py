"""Tests for hierarchical communication resolution (paper §4, Figs 4-7).

Every case is validated numerically on the virtual-device simulator:
scatter by src annotation -> apply plan -> shards must equal the dst
decomposition of the same global value.
"""

import numpy as np
import pytest

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, replicated, spmd
from repro.core.comm_resolve import UnsupportedCommError, resolve
from repro.core.simulator import apply_plan, gather, roundtrip_check, scatter

RNG = np.random.default_rng(42)


def _check(src, dst, shape, expect_kind=None):
    plan = resolve(src, dst, shape)
    if expect_kind is not None:
        assert plan.kind == expect_kind, f"{plan.kind} != {expect_kind}"
    value = RNG.normal(size=shape)
    roundtrip_check(value, src, dst, plan, rng=np.random.default_rng(1))
    return plan


# ---------------------------------------------------------------------------
# bottom tier (§4.1, Fig 5)
# ---------------------------------------------------------------------------

def test_identity():
    a = spmd([0, 1], DS({0: 2}))
    plan = _check(a, a, (8, 4), "identity")
    assert plan.nbytes_moved() == 0


def test_send_recv_dg_change():
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([2, 3], DS({0: 2}))
    plan = _check(src, dst, (8, 4), "bottom:SR")
    assert plan.message_count() == 2


def test_allreduce_partial_to_dup():
    src = spmd([0, 1], DS({PARTIAL: 2}))
    dst = spmd([0, 1], DS({DUP: 2}))
    _check(src, dst, (8, 4), "bottom:AR")


def test_reduce_scatter_partial_to_split():
    src = spmd([0, 1], DS({PARTIAL: 2}))
    dst = spmd([0, 1], DS({0: 2}))
    _check(src, dst, (8, 4), "bottom:RS")


def test_allgather_split_to_dup():
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([0, 1], DS({DUP: 2}))
    _check(src, dst, (8, 4), "bottom:AG")


def test_allgather_dim1():
    src = spmd([0, 1, 2, 3], DS([(0, 2), (1, 2)]))
    dst = spmd([0, 1, 2, 3], DS([(0, 2), (DUP, 2)]))
    _check(src, dst, (8, 8), "bottom:AG")


def test_ar_with_coexisting_split():
    # Partial:2 x Split0:2 -> Dup:2 x Split0:2  (AR inside split groups)
    src = spmd([0, 1, 2, 3], DS([(0, 2), (PARTIAL, 2)]))
    dst = spmd([0, 1, 2, 3], DS([(0, 2), (DUP, 2)]))
    _check(src, dst, (8, 4), "bottom:AR")


def test_bottom_resharding_bsr():
    # split dim0 -> split dim1: no collective fits, BSR fallback
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([0, 1], DS({1: 2}))
    plan = _check(src, dst, (8, 8), "bottom:BSR")
    assert plan.nbytes_moved() > 0


def test_bottom_bsr_dg_and_ds_change():
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([2, 3], DS({1: 2}))
    _check(src, dst, (8, 8), "bottom:BSR")


def test_rs_with_coexisting_split():
    # Partial:2 x Split0:2 -> Split1:2 x Split0:2 is a valid RS (Fig 5)
    src = spmd([0, 1, 2, 3], DS([(PARTIAL, 2), (0, 2)]))
    dst = spmd([0, 1, 2, 3], DS([(1, 2), (0, 2)]))
    _check(src, dst, (8, 8), "bottom:RS")


def test_partial_bsr_unsupported():
    # Partial shards + DG *and* DS change: not collective-expressible,
    # and BSR cannot carry Partial (paper §4.3 Discussions)
    src = spmd([0, 1], DS({PARTIAL: 2}))
    dst = spmd([2, 3], DS({0: 2}))
    with pytest.raises(UnsupportedCommError):
        resolve(src, dst, (8, 8))


def test_sr_moves_partial_shards():
    # DS unchanged (still Partial) but DG changes: SR moves summands
    src = spmd([0, 1], DS({PARTIAL: 2}))
    dst = spmd([2, 3], DS({PARTIAL: 2}))
    plan = resolve(src, dst, (4, 4))
    assert plan.kind == "bottom:SR"
    value = RNG.normal(size=(4, 4))
    st = scatter(value, src, rng=np.random.default_rng(3))
    out = apply_plan(st, plan)
    np.testing.assert_allclose(gather(out), value, atol=1e-6)


def test_heterogeneous_bottom_mix():
    # two subgroups, one needs AR and one needs AG -> separate parallel steps
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({0: 2})], hdim=0)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({DUP: 2}), DS({DUP: 2})], hdim=0)
    plan = _check(src, dst, (8, 4), "bottom:AG+AR")
    assert {s.kind for s in plan.steps} == {"AR", "AG"}


# ---------------------------------------------------------------------------
# top tier (§4.2, Figs 6-7)
# ---------------------------------------------------------------------------

def test_split_allreduce():
    # hdim Partial -> Dup across two subgroups (the hetero-DP gradient sync)
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({0: 2}), DS({0: 2})], hdim=PARTIAL)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({0: 2}), DS({0: 2})], hdim=DUP)
    plan = _check(src, dst, (8, 4), "top:SplitAR")
    assert plan.steps[0].kind == "SplitAR"


def test_split_allreduce_asymmetric_subgroups():
    # subgroups of different size/sharding still sync correctly
    src = HSPMD(dgs=[[0, 1, 2, 3], [4, 5]],
                dss=[DS([(0, 2), (1, 2)]), DS({0: 2})], hdim=PARTIAL)
    dst = HSPMD(dgs=[[0, 1, 2, 3], [4, 5]],
                dss=[DS([(0, 2), (1, 2)]), DS({0: 2})], hdim=DUP)
    _check(src, dst, (8, 8), "top:SplitAR")


def test_split_reduce_scatter():
    # hdim Partial -> Split(0): each subgroup keeps its slab of the sum
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=PARTIAL)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=0)
    _check(src, dst, (8, 8), "top:SplitRS")


def test_split_allgather():
    # hdim Split(0) -> Dup: every subgroup reconstructs the full tensor
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=0)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=DUP)
    _check(src, dst, (8, 8), "top:SplitAG")


def test_split_allgather_bottom_splits_same_dim():
    # bottom tier splits the SAME dim as hdim — the geometry-hard case
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({0: 2}), DS({0: 2})], hdim=0)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({0: 2}), DS({0: 2})], hdim=DUP)
    _check(src, dst, (8, 4), "top:SplitAG")


def test_top_slice_dup_to_split():
    # hdim Dup -> Split: pure local slab extraction, zero bytes
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=DUP)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({1: 2}), DS({1: 2})], hdim=0)
    plan = _check(src, dst, (8, 8), "top:Slice")
    assert plan.nbytes_moved() == 0


def test_fig7_composition_bottom_then_top():
    # paper Fig 7: DS Union differs AND hdim differs -> RS (bottom) then SplitAR
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({0: 2})], hdim=PARTIAL)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({0: 2}), DS({0: 2})], hdim=DUP)
    plan = _check(src, dst, (8, 4))
    kinds = [s.kind for s in plan.steps]
    assert kinds == ["RS", "SplitAR"], kinds


def test_hsplits_rebalance_bsr():
    # same hdim, different non-uniform hsplits -> runtime rebalancing via BSR
    src = HSPMD(dgs=[[0, 1], [2]], dss=[DS({0: 2}), DS({})], hdim=0,
                hsplits=[2, 2])
    dst = HSPMD(dgs=[[0, 1], [2]], dss=[DS({0: 2}), DS({})], hdim=0,
                hsplits=[3, 1])
    plan = _check(src, dst, (16, 4))
    assert "BSR" in plan.kind or any(s.kind == "BSR" for s in plan.steps)


def test_cross_union_bsr_fallback():
    # different DG unions and HSize -> global BSR (Fig 8 regime)
    src = HSPMD(dgs=[[0, 1, 2, 3]], dss=[DS({0: 4})])
    dst = HSPMD(dgs=[[4, 5], [6]], dss=[DS({1: 2}), DS({})], hdim=0)
    plan = _check(src, dst, (8, 8), "fallback:BSR")
    assert plan.steps[0].kind == "BSR"


def test_cross_union_partial_unsupported():
    src = HSPMD(dgs=[[0, 1]], dss=[DS({PARTIAL: 2})])
    dst = HSPMD(dgs=[[2], [3]], dss=[DS({}), DS({})], hdim=0)
    with pytest.raises(UnsupportedCommError):
        resolve(src, dst, (8, 4))


def test_grow_subgroup_devices():
    # elastic scale-up: 2 devices -> 4 devices, resharded
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([0, 1, 2, 3], DS([(0, 2), (1, 2)]))
    _check(src, dst, (8, 8))


def test_shrink_subgroup_devices():
    # elastic failure: drop device 3, redistribute over 3 devices
    src = spmd([0, 1, 2, 3], DS({0: 4}))
    dst = spmd([0, 1, 2], DS({0: 3}))
    _check(src, dst, (12, 4))


def test_splitar_spectator_bottom_partial():
    # top-tier partial reduces across subgroups while bottom-tier Partial
    # survives (ZeRO-style): bottom summands must not be mixed
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({PARTIAL: 2})], hdim=PARTIAL)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({PARTIAL: 2})], hdim=DUP)
    plan = resolve(src, dst, (8, 4))
    assert plan.kind == "top:SplitAR"
    value = RNG.normal(size=(8, 4))
    st = scatter(value, src, rng=np.random.default_rng(9))
    out = apply_plan(st, plan)
    np.testing.assert_allclose(gather(out), value, atol=1e-6)


def test_splitag_spectator_bottom_partial():
    # hdim split -> dup while bottom Partial persists: gather per summand
    src = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({PARTIAL: 2})], hdim=0)
    dst = HSPMD(dgs=[[0, 1], [2, 3]],
                dss=[DS({PARTIAL: 2}), DS({PARTIAL: 2})], hdim=DUP)
    plan = resolve(src, dst, (8, 4))
    assert plan.kind == "top:SplitAG"
    value = RNG.normal(size=(8, 4))
    st = scatter(value, src, rng=np.random.default_rng(10))
    out = apply_plan(st, plan)
    np.testing.assert_allclose(gather(out), value, atol=1e-6)
