"""The runnable examples must stay runnable (subprocess smoke)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # keep subprocess thread/memory footprint small — under full-suite
    # load the TSL thread pool can fail to spawn (SIGABRT) otherwise
    env["OMP_NUM_THREADS"] = "1"
    env["OPENBLAS_NUM_THREADS"] = "1"
    env["XLA_FLAGS"] = ""  # never inherit the 512-device flag
    for attempt in range(2):
        p = subprocess.run([sys.executable] + args, capture_output=True,
                           text=True, env=env, timeout=timeout, cwd=ROOT)
        if p.returncode == 0 or attempt:
            return p
    return p


@pytest.mark.slow
def test_quickstart():
    p = _run(["examples/quickstart.py"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "numerical roundtrip: OK" in p.stdout
    assert "SplitAR" in p.stdout


@pytest.mark.slow
def test_elastic_example():
    p = _run(["examples/elastic_training.py"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "no restart" in p.stdout
    assert "verified exact" in p.stdout


@pytest.mark.slow
def test_train_driver_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    p = _run(["-m", "repro.launch.train", "--arch", "qwen2-1.5b",
              "--reduced", "--steps", "6", "--batch", "4", "--seq", "64",
              "--microbatches", "1", "--ckpt", ck])
    assert p.returncode == 0, p.stdout + p.stderr
    p2 = _run(["-m", "repro.launch.train", "--arch", "qwen2-1.5b",
               "--reduced", "--steps", "3", "--batch", "4", "--seq", "64",
               "--microbatches", "1", "--resume", ck])
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resumed" in p2.stdout


@pytest.mark.slow
def test_serve_example():
    p = _run(["examples/serve.py", "--arch", "qwen2-1.5b", "--gen", "8",
              "--prompt-len", "8"])
    assert p.returncode == 0, p.stdout + p.stderr
    assert "decode 8 tokens" in p.stdout
