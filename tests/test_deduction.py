"""Annotation deduction tests (paper §5.2, Figs 10-11)."""

import pytest

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd
from repro.core.graph import (DeductionError, Graph, convert_hsize,
                              unify_inputs)


def test_fig2_left_spmd_deduction():
    """Paper Fig 2 (left): DP x TP dot — Y inherits X's batch split and W's
    column split; the contraction is unsharded."""
    g = Graph()
    x = g.placeholder("X", (8, 16, 32),
                      [spmd([0, 1, 2, 3], DS([(0, 2), (DUP, 2)]))])
    w = g.parameter("W", (32, 64),
                    [spmd([0, 1, 2, 3], DS([(DUP, 2), (1, 2)]))])
    y = g.dot(x, w)
    g.deduce()
    ys = y.annot.dss[0]
    assert ys.get(0) == 2          # batch split passes through
    assert ys.get(2) == 2          # W's n-split becomes last dim
    assert ys.get(PARTIAL) == 1
    assert ys.num_devices == 4


def test_fig11_contraction_split_becomes_partial():
    """Fig 11: X split on contraction dim c, W split on dim 0 by c -> Partial."""
    g = Graph()
    x = g.placeholder("X", (4, 8, 32), [spmd([0, 1], DS({2: 2}))])
    w = g.parameter("W", (32, 16), [spmd([0, 1], DS({0: 2}))])
    y = g.dot(x, w)
    g.deduce()
    assert y.annot.dss[0].get(PARTIAL) == 2


def test_fig11_full_table():
    """The complete 3Dx2D Dot rule of Fig 11: X split (a,b,c), W split (c,d)
    -> Y gets (a, b, d) splits, partial c, dup n/(abcd)."""
    a, b, c, d = 2, 2, 2, 2
    n = a * b * c * d * 2  # dup 2
    devs = list(range(n))
    g = Graph()
    x = g.placeholder("X", (8, 8, 8),
                      [spmd(devs, DS([(0, a), (1, b), (2, c), (DUP, n // (a * b * c))]))])
    w = g.parameter("W", (8, 8),
                    [spmd(devs, DS([(0, c), (1, d), (DUP, n // (c * d))]))])
    y = g.dot(x, w)
    g.deduce()
    ys = y.annot.dss[0]
    assert ys.get(0) == a and ys.get(1) == b and ys.get(2) == d
    assert ys.get(PARTIAL) == c
    assert ys.get(DUP) == n // (a * b * c * d)


def test_contraction_mismatch_needs_commop():
    g = Graph()
    x = g.placeholder("X", (4, 8, 32), [spmd([0, 1], DS({2: 2}))])
    w = g.parameter("W", (32, 16), [spmd([0, 1], DS({1: 2}))])
    g.dot(x, w)
    with pytest.raises(DeductionError):
        g.deduce()


def test_unary_propagates():
    g = Graph()
    x = g.placeholder("X", (4, 8), [spmd([0, 1], DS({0: 2}))])
    y = g.gelu(x)
    g.deduce()
    assert y.annot == x.annot


def test_sum_split_dim_becomes_partial():
    g = Graph()
    x = g.placeholder("X", (4, 8), [spmd([0, 1], DS({1: 2}))])
    y = g.sum(x, dim=1)
    g.deduce()
    assert y.annot.dss[0].get(PARTIAL) == 2


def test_sum_renumbers_later_dims():
    g = Graph()
    x = g.placeholder("X", (4, 8, 6), [spmd([0, 1], DS({2: 2}))])
    y = g.sum(x, dim=0)
    g.deduce()
    assert y.annot.dss[0].get(1) == 2


# ---------------------------------------------------------------------------
# HSize / DG Union conversion (Fig 10)
# ---------------------------------------------------------------------------

def test_convert_hsize_preserves_placement():
    a = spmd([0, 1, 2, 3], DS([(0, 4)]))
    b = convert_hsize(a, 2)
    assert b.hsize == 2 and b.hdim == 0
    shape = (16, 8)
    for dev in range(4):
        assert a.device_box(dev, shape) == b.device_box(dev, shape)


def test_convert_hsize_dup_outer():
    a = spmd([0, 1, 2, 3], DS([(DUP, 2), (0, 2)]))
    b = convert_hsize(a, 2)
    assert b.hsize == 2 and b.hdim == DUP
    shape = (8, 8)
    for dev in range(4):
        assert a.device_box(dev, shape) == b.device_box(dev, shape)


def test_unify_inputs_alignment_required():
    hetero = HSPMD(dgs=[[0, 1], [2, 3]], dss=[DS({0: 2}), DS({1: 2})], hdim=0)
    flat = spmd([0, 2, 1, 3], DS([(0, 4)]))  # devices interleaved: misaligned
    with pytest.raises(DeductionError):
        unify_inputs([hetero, flat])


def test_hetero_dot_deduction_fig2_right():
    """Paper Fig 2 (right): heterogeneous DP where subgroups use different
    internal parallelism; Dot deduction runs per subgroup."""
    devs = [[0, 3], [5, 6], [2, 4], [1]]
    x = HSPMD(dgs=devs, dss=[DS({DUP: 2}), DS({DUP: 2}), DS({0: 2}), DS({})],
              hdim=0)
    w = HSPMD(dgs=devs, dss=[DS({1: 2}), DS({1: 2}), DS({DUP: 2}), DS({})],
              hdim=DUP)
    g = Graph()
    xt = g.placeholder("X", (8, 16, 32), [x])
    wt = g.parameter("W", (32, 64), [w])
    y = g.dot(xt, wt)
    g.deduce()
    ya = y.annot
    assert ya.hdim == 0            # hetero batch split survives the Dot
    assert ya.dss[0].get(2) == 2   # TP subgroups: output col-split
    assert ya.dss[2].get(0) == 2   # CP-ish subgroup keeps its row split
    assert ya.dss[3].num_devices == 1


def test_multi_annotation_synchronous_deduction():
    """§6.1: two strategies deduced synchronously through one graph."""
    s1 = spmd([0, 1], DS({0: 2}))
    s2 = spmd([0, 1], DS({DUP: 2}))
    g = Graph()
    x = g.placeholder("X", (4, 8, 8), [s1, s2])
    w = g.parameter("W", (8, 8), [spmd([0, 1], DS({DUP: 2}))])  # broadcast to both
    y = g.dot(x, w)
    g.deduce()
    assert y.n_strategies == 2
    assert y.annots[0].dss[0].get(0) == 2
    assert y.annots[1].dss[0].get(DUP) == 2


def test_transpose_moves_split_dims():
    g = Graph()
    x = g.placeholder("X", (4, 8, 16), [spmd([0, 1], DS({1: 2}))])
    y = g.transpose(x, (2, 0, 1))
    g.deduce()
    assert y.annot.dss[0].get(2) == 2  # old dim1 -> new dim2


def test_transpose_hdim_follows():
    a = HSPMD(dgs=[[0], [1]], dss=[DS({}), DS({})], hdim=1)
    g = Graph()
    x = g.placeholder("X", (4, 8), [a])
    y = g.transpose(x, (1, 0))
    g.deduce()
    assert y.annot.hdim == 0


def test_reshape_preserves_leading_split():
    g = Graph()
    x = g.placeholder("X", (8, 4, 16), [spmd([0, 1], DS({0: 2}))])
    y = g.reshape(x, (8, 64))
    g.deduce()
    assert y.annot.dss[0].get(0) == 2


def test_reshape_merging_sharded_dim_rejected():
    g = Graph()
    # dim1 split; reshape merges dims 0-1: the split dim has no unambiguous
    # image -> must reshard first
    x = g.placeholder("X", (4, 8, 16), [spmd([0, 1], DS({1: 2}))])
    g.reshape(x, (32, 16))
    with pytest.raises(DeductionError):
        g.deduce()
