"""Async MPMD executor: registry wiring and structured error surfaces.

The multi-device bitwise parity sweep (``async:pipeline/{2,4,8}``,
``async:train/4``) runs in the subprocess selftest and is asserted from
``tests/test_runtime.py``; here we pin the in-process contract — the
executor registry, and that unknown executor names / unsupported
schedule kinds fail with errors that NAME the valid options.
"""

import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro import api
from repro.core.schedule import build_schedule


def test_get_executor_registry_includes_async():
    ex = api.get_executor("async")
    assert isinstance(ex, api.AsyncExecutor)
    assert ex.name == "async"
    assert set(ex.supported_schedules) == {"1f1b", "gpipe", "interleaved"}
    # constructor kwargs pass through like the other executors'
    assert api.get_executor("async", serialize=True).serialize
    with pytest.raises(TypeError):
        api.get_executor("async", record_ticks=True)


def test_unknown_executor_error_lists_valid_names():
    with pytest.raises(ValueError) as e:
        api.get_executor("tpu")
    msg = str(e.value)
    for name in ("async", "jax", "sim"):
        assert name in msg, msg
    assert "tpu" in msg


def test_async_executor_rejects_unknown_schedule_kind():
    """run_schedule validates the kind BEFORE lowering anything, so a
    bogus timetable fails fast with the supported kinds listed."""
    sched = dataclasses.replace(build_schedule(2, 2, "1f1b"), kind="ring")
    ex = api.AsyncExecutor()
    with pytest.raises(api.ScheduleError) as e:
        ex.run_schedule(SimpleNamespace(n_stages=2), sched,
                        [{}, {}])
    msg = str(e.value)
    assert "'ring'" in msg
    for kind in ("1f1b", "gpipe", "interleaved"):
        assert kind in msg, msg


def test_async_executor_rejects_mismatched_states_and_stages():
    sched = build_schedule(2, 2, "1f1b")
    ex = api.AsyncExecutor()
    with pytest.raises(api.ScheduleError, match="microbatch"):
        ex.run_schedule(SimpleNamespace(n_stages=2), sched, [{}])
    with pytest.raises(api.ScheduleError, match="stage"):
        ex.run_schedule(SimpleNamespace(n_stages=3), sched, [{}, {}])


def test_session_rejects_kind_unsupported_by_executor():
    """Session consults executor.supported_schedules up front: an
    executor that only speaks gpipe turns a 1f1b request into a
    structured error naming the executor and its kinds."""
    from repro.api.testing import (loss_pipeline_program,
                                   loss_pipeline_values)

    class GPipeOnly(api.SimulatorExecutor):
        name = "gpipe-only"
        supported_schedules = ("gpipe",)

    prog = loss_pipeline_program(2, name="pipe2")
    xv, ws, want_y = loss_pipeline_values(seed=11)
    sess = api.Session(prog, "pipe2", executor=GPipeOnly())
    sess.load(ws)
    r = sess.run({"X": xv}, fetches=["Y"], num_microbatches=2,
                 schedule="gpipe")
    np.testing.assert_array_equal(r.value("Y"), want_y)
    with pytest.raises(api.ScheduleError) as e:
        sess.run({"X": xv}, fetches=["Y"], num_microbatches=2,
                 schedule="1f1b")
    msg = str(e.value)
    assert "gpipe-only" in msg and "'gpipe'" in msg, msg
    # unknown kinds still fail on the global list first
    with pytest.raises(api.ScheduleError, match="interleaved"):
        sess.run({"X": xv}, fetches=["Y"], num_microbatches=2,
                 schedule="ring")
