"""Multi-(host)device validation: the HSPMD annotation -> NamedSharding
bridge agrees with the virtual-device simulator on REAL jax arrays.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the default test environment keeps seeing 1 device (per spec)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.annotations import DS, DUP, spmd
    from repro.core.comm_resolve import resolve
    from repro.core.simulator import apply_plan, scatter
    from repro.sharding.rules import annot_to_spec

    devs = jax.devices()
    assert len(devs) == 8, devs
    mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))

    value = np.arange(16 * 8, dtype=np.float32).reshape(16, 8)

    # annotation -> NamedSharding: per-device shards must equal the
    # annotation's device_box decomposition
    a = spmd([d.id for d in devs], DS([(0, 2), (1, 4)]))
    spec = annot_to_spec(a, ("data", "model"))
    arr = jax.device_put(jnp.asarray(value), NamedSharding(mesh, spec))
    for shard in arr.addressable_shards:
        box = a.device_box(shard.device.id, value.shape)
        want = value[tuple(slice(lo, hi) for lo, hi in box)]
        np.testing.assert_array_equal(np.asarray(shard.data), want)
    print("placement OK")

    # resharding on real devices == the resolved plan on the simulator
    b = spmd([d.id for d in devs], DS([(1, 2), (0, 4)]))
    spec_b = annot_to_spec(b, ("data", "model"))
    arr2 = jax.device_put(arr, NamedSharding(mesh, spec_b))
    plan = resolve(a, b, value.shape)
    sim = apply_plan(scatter(value, a), plan)
    for shard in arr2.addressable_shards:
        np.testing.assert_array_equal(np.asarray(shard.data),
                                      sim.parts[shard.device.id])
    print("reshard OK: plan kind=%s" % plan.kind)

    # a sharded matmul's result matches the HSPMD Dot deduction
    from repro.core.graph import Graph
    g = Graph()
    xa = spmd([d.id for d in devs], DS([(0, 2), (DUP, 4)]))
    wa = spmd([d.id for d in devs], DS([(DUP, 2), (1, 4)]))
    xt = g.placeholder("X", (4, 8, 16), [xa])
    wt = g.parameter("W", (16, 8), [wa])
    yt = g.dot(xt, wt)
    g.deduce()
    xs = annot_to_spec(xa, ("data", "model"))
    ws = annot_to_spec(wa, ("data", "model"))
    X = jax.device_put(jnp.ones((4, 8, 16)), NamedSharding(mesh, P("data", None, None)))
    W = jax.device_put(jnp.ones((16, 8)), NamedSharding(mesh, P(None, "model")))
    with mesh:
        Y = jax.jit(lambda x, w: x @ w)(X, W)
    ya = yt.annot
    assert ya.dss[0].get(0) == 2 and ya.dss[0].get(2) == 4
    print("deduction matches execution OK")
""")


@pytest.mark.slow
def test_multidevice_bridge_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=560,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "placement OK" in proc.stdout
    assert "reshard OK" in proc.stdout
    assert "deduction matches execution OK" in proc.stdout
