"""Per-architecture smoke tests (reduced variants of each assigned family).

Each test instantiates the REDUCED config (<=2 layers / pattern,
d_model<=256, <=4 experts), runs one forward + one train step on CPU, and
asserts output shapes + finiteness.  Decode paths are validated against
the full forward (teacher-forcing equivalence).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import (_run_encoder, decode_step, forward,
                                init_decode_state, init_params, loss_fn)

ASSIGNED = [a for a in ARCHS if not a.startswith("llama")]


def _batch(cfg, key, B=2, S=16):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.input_kind == "embeds":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    elif cfg.input_kind == "audio":
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch["audio_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.n_frames, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)

    logits, aux = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one SGD step must produce finite loss + grads and change the params
    def step(p, b):
        (loss, m), grads = jax.value_and_grad(
            lambda q: loss_fn(q, b, cfg), has_aux=True)(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-3 * g, p, grads)
        return loss, new_p

    loss, new_params = jax.jit(step)(params, batch)
    assert bool(jnp.isfinite(loss))
    leaves_before = jax.tree.leaves(params)
    leaves_after = jax.tree.leaves(new_params)
    assert any(not np.allclose(a, b) for a, b in
               zip(leaves_before, leaves_after))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    logits_full, _ = forward(params, batch, cfg)

    enc_out = _run_encoder(params, batch, cfg) if cfg.encdec else None
    state = init_decode_state(cfg, B, max_len=S, enc_out=enc_out)
    step = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg))
    outs = []
    for t in range(S):
        sb = {}
        if cfg.input_kind == "embeds":
            sb["embeds"] = batch["embeds"][:, t:t + 1]
            sb["positions3"] = batch["positions3"][:, :, t:t + 1]
        else:
            sb["tokens"] = batch["tokens"][:, t:t + 1]
        lg, state = step(params, state, sb)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full),
                               atol=2e-3, rtol=1e-3)


def test_remat_forward_matches():
    cfg = get_config("qwen2-1.5b").reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    l1, _ = forward(params, batch, cfg, remat=False)
    l2, _ = forward(params, batch, cfg, remat=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_sliding_window_attention_masks_far_tokens():
    """Hybrid local attention must ignore tokens beyond the window."""
    cfg = get_config("recurrentgemma-9b").reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    S = cfg.hybrid.window + 24
    batch = _batch(cfg, key, B=1, S=S)
    logits, _ = forward(params, batch, cfg)
    # perturb a token far outside the window of the last position
    t2 = batch["tokens"].at[0, 0].set((batch["tokens"][0, 0] + 7) % cfg.vocab)
    batch2 = dict(batch, tokens=t2)
    logits2, _ = forward(params, batch2, cfg)
    # recurrent layers DO carry long-range state, so only check that the
    # window-attention code path executes over >window sequences
    assert logits.shape == logits2.shape == (1, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_param_counts_roughly_match_model_cards():
    """param_count() should land near the published sizes (within 40% —
    it is used only for roofline MODEL_FLOPS)."""
    expect = {
        "qwen2-vl-72b": 72e9, "phi3-medium-14b": 14e9,
        "grok-1-314b": 314e9, "qwen1.5-110b": 111e9,
        "deepseek-67b": 67e9, "qwen2-1.5b": 1.5e9,
        "deepseek-v2-236b": 236e9, "mamba2-370m": 370e6,
        "recurrentgemma-9b": 9e9,
    }
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * want < got < 1.6 * want, \
            f"{arch}: {got / 1e9:.1f}B vs expected {want / 1e9:.1f}B"


# ---------------------------------------------------------------------------
# graph-IR transformer block vs the plain-jax layers reference
# ---------------------------------------------------------------------------

def _block_reference_loss(cfg, L, ids, labels):
    """Plain-jax twin of ``models.graph_block.build_block``: the same
    pre-norm stack via ``models.layers`` (positions=None, no RoPE) and
    the same mean-picked-probability loss head."""
    from repro.models import layers

    eps = cfg.norm_eps

    def loss(params):
        x = params["embed"][ids]
        for i in range(L):
            p = {k.split("/", 1)[1]: v for k, v in params.items()
                 if k.startswith(f"l{i}/")}
            ap = {k: p[k] for k in ("wq", "wk", "wv", "wo")}
            for bn in ("bq", "bk", "bv"):
                if bn in p:
                    ap[bn] = p[bn]
            h = layers.rms_norm({"w": p["attn_norm"]}, x, eps)
            y, _ = layers.apply_attention(ap, h, cfg, positions=None,
                                          causal=True, use_rope=False)
            x = x + y
            h = layers.rms_norm({"w": p["mlp_norm"]}, x, eps)
            x = x + layers.apply_mlp(
                {"gate": p["w_gate"], "up": p["w_up"],
                 "down": p["w_down"]}, h, cfg.mlp)
        x = layers.rms_norm({"w": params["final_norm"]}, x, eps)
        lm = params["embed"].T if cfg.tie_embeddings \
            else params["lm_head"]
        probs = jax.nn.softmax(x @ lm, -1)
        pl = jnp.take_along_axis(probs, labels[..., None], -1)[..., 0]
        return pl.mean()

    return loss


def _block_fixture(arch, *, B=2, S=8, seed=0):
    from repro.models.graph_block import block_program

    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    labels = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    return cfg, rng, ids, labels


def _init_block_weights(prog, rng):
    ws = {}
    for t in prog.graph.parameters():
        shp = tuple(t.shape)
        ws[t.name] = np.ones(shp, np.float32) \
            if "norm" in t.name.split("/")[-1] \
            else (rng.standard_normal(shp) * 0.05).astype(np.float32)
    return ws


@pytest.mark.parametrize("arch,par", [
    ("qwen2_1_5b", dict(dp=2, tp=2, pp=1)),   # GQA + qkv bias + tied head
    ("llama_32b", dict(dp=1, tp=2, pp=2)),    # untied head, 2 pp stages
])
def test_graph_block_fwd_bwd_matches_layers_reference(arch, par):
    """The graph-IR block under a sharded TP x DP x PP strategy trains
    to the SAME loss and gradients as the unsharded plain-jax
    ``models.layers`` stack (float tolerance; the key-bias gradient is
    mathematically zero — softmax is shift-invariant along the key
    axis — so comparisons need the absolute floor, not pure rtol)."""
    from repro import api
    from repro.models.graph_block import block_program

    cfg, rng, ids, labels = _block_fixture(arch)
    prog = block_program(cfg, batch=2, seq=8, **par)
    ws = _init_block_weights(prog, rng)

    sess = api.Session(prog, 0, executor=api.SimulatorExecutor())
    sess.load(ws)
    r = sess.train_step({"ids": ids, "labels": labels},
                        num_microbatches=1)

    loss = _block_reference_loss(cfg, cfg.n_layers, ids, labels)
    want, grads = jax.value_and_grad(loss)(
        {n: jnp.asarray(v) for n, v in ws.items()})
    np.testing.assert_allclose(r.loss, float(want), rtol=1e-5, atol=1e-9)
    for n in ws:
        np.testing.assert_allclose(
            r.grad_value(n), np.asarray(grads[n]), atol=1e-6, rtol=2e-4,
            err_msg=f"{arch} grad {n}")


def test_graph_block_single_device_jax_matches_reference():
    """Same differential on the real JaxExecutor (single device, so it
    runs in-process without forced host devices)."""
    from repro import api
    from repro.models.graph_block import block_program

    cfg, rng, ids, labels = _block_fixture("qwen2_1_5b", seed=1)
    prog = block_program(cfg, batch=2, seq=8, dp=1, tp=1, pp=1)
    ws = _init_block_weights(prog, rng)

    sess = api.Session(prog, 0, executor=api.JaxExecutor())
    sess.load(ws)
    r = sess.train_step({"ids": ids, "labels": labels},
                        num_microbatches=1)

    loss = _block_reference_loss(cfg, cfg.n_layers, ids, labels)
    want, grads = jax.value_and_grad(loss)(
        {n: jnp.asarray(v) for n, v in ws.items()})
    np.testing.assert_allclose(r.loss, float(want), rtol=1e-5, atol=1e-9)
    for n in ws:
        np.testing.assert_allclose(
            r.grad_value(n), np.asarray(grads[n]), atol=1e-6, rtol=2e-4,
            err_msg=f"grad {n}")
