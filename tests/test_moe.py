"""MoE dispatch tests: the shard_map expert-parallel path must agree with
the GSPMD scatter/gather path; capacity semantics; property sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweep needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import Mesh

from repro.models.config import MoEConfig, ModelConfig
from repro.models.moe import _apply_moe_gspmd, apply_moe_ep_shmap, init_moe


def _cfg(n_experts=4, top_k=2, d=64, d_expert=32, n_shared=0, exact=True):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab=64,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=d_expert,
                      n_shared=n_shared, exact=exact))


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


@settings(max_examples=15, deadline=None)
@given(ne=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2, 3]),
       ns=st.sampled_from([0, 1]), seed=st.integers(0, 100))
def test_shmap_equals_gspmd(ne, k, ns, seed):
    cfg = _cfg(n_experts=ne, top_k=min(k, ne), n_shared=ns)
    p = init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (2, 8, cfg.d_model)) * 0.2
    mesh = _mesh()
    with mesh:
        y1, a1 = apply_moe_ep_shmap(p, x, cfg, mesh)
    y2, a2 = _apply_moe_gspmd(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), atol=1e-5)


def test_capacity_drops_are_masked():
    """With a tiny capacity, dropped assignments contribute zero (not
    garbage) to the combine."""
    cfg = _cfg(n_experts=2, top_k=1, exact=False)
    # capacity_factor tiny -> cap 1
    cfg = ModelConfig(**{**cfg.__dict__,
                         "moe": MoEConfig(n_experts=2, top_k=1, d_expert=32,
                                          capacity_factor=0.01)})
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    y, aux = _apply_moe_gspmd(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    # most tokens dropped: output mostly zero rows
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert int(jnp.sum(norms < 1e-6)) >= 8


def test_capacity_128_alignment():
    from repro.models.moe import _capacity
    m = MoEConfig(n_experts=160, top_k=6, d_expert=8, capacity_factor=1.0)
    cap = _capacity(131072, m)
    assert cap % 128 == 0 and cap >= 131072 * 6 / 160
    m2 = MoEConfig(n_experts=160, top_k=6, d_expert=8, exact=True)
    assert _capacity(100, m2) == 100


def test_grads_match_between_paths():
    cfg = _cfg(n_experts=4, top_k=2)
    p = init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, cfg.d_model)) * 0.2
    mesh = _mesh()

    def loss_sh(p, x):
        with mesh:
            y, a = apply_moe_ep_shmap(p, x, cfg, mesh)
        return jnp.sum(y ** 2) + a

    def loss_gs(p, x):
        y, a = _apply_moe_gspmd(p, x, cfg)
        return jnp.sum(y ** 2) + a

    g1 = jax.grad(loss_sh)(p, x)
    g2 = jax.grad(loss_gs)(p, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-3)
