"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles.

Shapes and dtypes are swept per the deliverable spec; tolerances scale
with dtype.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, rglru_ref, ssd_scan_ref
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[dtype]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 1, 128, 128),    # MQA, MXU-aligned head dim
    (1, 2, 2, 384, 32),     # non-pow2 seq (3 blocks of 128)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, b, h, kh, s, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=1e-2)


def test_flash_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 512, 64))
    k = jax.random.normal(ks[1], (1, 2, 512, 64))
    v = jax.random.normal(ks[2], (1, 2, 512, 64))
    for window in (64, 128, 256):
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-3)


def test_flash_attention_block_shape_invariance():
    """Different BlockSpec tilings must agree (tile-boundary bugs)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    o1 = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    o2 = flash_attention(q, k, v, block_q=64, block_k=128, interpret=True)
    o3 = flash_attention(q, k, v, block_q=128, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan (Mamba2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 64, 128, 64),
    (2, 256, 4, 64, 128, 128),
    (1, 192, 2, 32, 64, 64),      # 3 chunks, small head/state
])
def test_ssd_scan_sweep(dtype, b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = (jax.random.normal(ks[3], (b, s, n)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (b, s, n)) * 0.3).astype(dtype)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_scan_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype) * 10, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=_tol(dtype) * 10, rtol=5e-2)


def test_ssd_chunk_invariance():
    """The scan must be exactly chunk-size independent."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, s, h, p, n = 1, 256, 2, 32, 64
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    y64, _ = ssd_scan(x, dt, A, B, C, chunk=64, interpret=True)
    y128, _ = ssd_scan(x, dt, A, B, C, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y128),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,w,chunk", [
    (1, 128, 128, 64),
    (2, 256, 256, 128),
    (1, 384, 128, 128),
])
def test_rglru_sweep(dtype, b, s, w, chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = (jax.random.normal(ks[0], (b, s, w)) * 0.5).astype(dtype)
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w))).astype(dtype)
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w))).astype(dtype)
    lam = jax.random.normal(ks[3], (w,)) * 0.5
    y = rglru_pallas(x, r, i, lam, chunk=chunk, interpret=True)
    yr = rglru_ref(x, r, i, lam)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype) * 5, rtol=3e-2)


def test_rglru_matches_stepwise_decode():
    """Kernel scan == the model's one-step decode recurrence."""
    from repro.models.rglru import rglru_decode_step
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    b, s, w = 1, 32, 128
    x = jax.random.normal(ks[0], (b, s, w)) * 0.5
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, w)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, w)))
    lam = jax.random.normal(ks[3], (w,)) * 0.5
    y = rglru_pallas(x, r, i, lam, chunk=32, interpret=True)
    h = jnp.zeros((b, w))
    outs = []
    for t in range(s):
        yt, h = rglru_decode_step(x[:, t:t + 1], r[:, t:t + 1],
                                  i[:, t:t + 1], lam, h)
        outs.append(yt[:, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(outs, 1)),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# dispatch wrappers
# ---------------------------------------------------------------------------

def test_ops_dispatch_ref_vs_pallas():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    a = ops.attention(q, k, v, use_kernel="ref")
    b = ops.attention(q, k, v, use_kernel="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_set_policy_rejects_unknown_policy():
    """Regression: set_policy validated with a bare assert (stripped
    under ``python -O``); it must raise ValueError naming the valid
    policies."""
    from repro.kernels import policy

    with pytest.raises(ValueError, match="auto, pallas, ref"):
        policy.set_policy("fast")
    assert policy.get_policy() == "auto"  # unchanged on rejection
    policy.set_policy("ref")
    try:
        assert policy.get_policy() == "ref"
    finally:
        policy.set_policy("auto")


def test_select_attention_impl_honours_policy_and_eligibility():
    from repro.kernels import policy

    ok_q, ok_kv = (1, 4, 128, 64), (1, 2, 128, 64)
    bad_q, bad_kv = (1, 4, 128, 60), (1, 2, 128, 60)  # d % 8 != 0
    policy.set_policy("pallas")
    try:
        assert policy.select_attention_impl(ok_q, ok_kv) == "pallas"
        assert policy.select_attention_impl(bad_q, bad_kv) == "ref"
    finally:
        policy.set_policy("auto")
    # ref policy forces the reference even for eligible shards
    policy.set_policy("ref")
    try:
        assert policy.select_attention_impl(ok_q, ok_kv) == "ref"
    finally:
        policy.set_policy("auto")
