"""Automated strategy search subsystem (`repro.search`): enumeration
determinism, pruning soundness, cost-model ranking, and execution
validation on CPU fixtures (the simulator's re-priced parallel
makespans must order candidates the way the cost model predicted).

The sim <-> jax bit-exactness of the validated winners runs in the
subprocess selftest (``search:hetero/4`` in ``tests/test_runtime.py``);
everything here is single-process.
"""

import numpy as np
import pytest

from repro.core.costmodel import feasible, memory_per_rank
from repro.search import (CPU_A, SearchError, Searcher, balanced_stages,
                          cpu_cluster, cpu_hetero_cluster,
                          enumerate_candidates, executable_microbatches,
                          proportional_split, proxy_program, prune, rank,
                          tiny_spec, validate)


def homog_searcher(**kw):
    """The homogeneous CPU fixture grid validated in the selftest.

    TP=2 candidates are in the grid: class-vectorized simulator
    dispatch (one stacked numpy call per specialization class, timed
    once and attributed per device) prices a TP shard at its parallel
    share instead of n x python dispatch, so TP measurements carry a
    real ordering signal now."""
    args = dict(global_batch=8, seq_len=128, tp_options=(1, 2),
                pp_options=(1, 2, 4), virtual_options=(1, 2),
                include_hetero=False)
    args.update(kw)
    return Searcher(tiny_spec(), **args)


def hetero_searcher(**kw):
    args = dict(global_batch=8, seq_len=128, tp_options=(1, 2),
                pp_options=(1, 2), pipeline_options=(1, 2),
                virtual_options=(1,))
    args.update(kw)
    return Searcher(tiny_spec(), **args)


# -- space -------------------------------------------------------------------

def test_enumeration_deterministic():
    """Same inputs -> identical candidate sequence; option tuples are
    order-insensitive (sorted grids)."""
    cluster, model = cpu_hetero_cluster(2, 2), tiny_spec()
    a = enumerate_candidates(cluster, model, global_batch=8,
                             tp_options=(1, 2), pp_options=(1, 2, 4),
                             pipeline_options=(1, 2))
    b = enumerate_candidates(cluster, model, global_batch=8,
                             tp_options=(2, 1), pp_options=(4, 2, 1),
                             pipeline_options=(2, 1))
    assert [c.name for c in a] == [c.name for c in b]
    assert len({c.name for c in a}) == len(a)  # names are unique
    # and stable across calls
    c = enumerate_candidates(cluster, model, global_batch=8,
                             tp_options=(1, 2), pp_options=(1, 2, 4),
                             pipeline_options=(1, 2))
    assert [x.describe() for x in a] == [x.describe() for x in c]


def test_proportional_split_never_starves():
    assert proportional_split([100.0, 1.0, 1.0, 1.0], 4) == [1, 1, 1, 1]
    assert sum(proportional_split([3.0, 1.0], 8)) == 8
    assert min(proportional_split([100.0, 1.0], 3)) >= 1
    with pytest.raises(ValueError):
        proportional_split([1.0] * 5, 4)


def test_balanced_stages_regression():
    """The old ``scenarios.search._balanced_stages`` emitted zero-layer
    stages when the group count approached the layer count; the fixed
    version gives every stage >= 1 layer and covers exactly."""
    from repro.scenarios.search import _balanced_stages
    groups = [((0,), 100.0), ((1,), 1.0), ((2,), 1.0), ((3,), 1.0)]
    stages = _balanced_stages(groups, 4)
    assert [st.n_layers for st in stages] == [1, 1, 1, 1]
    covered = sorted(l for st in stages for l in range(*st.layers))
    assert covered == list(range(4))
    assert _balanced_stages is balanced_stages


# -- prune -------------------------------------------------------------------

def test_pruning_sound():
    """Every survivor is genuinely feasible (disjoint ranks, full layer
    cover, under the memory cap); every rejection carries a rule."""
    cluster, model = cpu_cluster(8), tiny_spec()
    cands = enumerate_candidates(cluster, model, global_batch=8,
                                 tp_options=(1, 2, 4),
                                 pp_options=(1, 2, 4, 8))
    report = prune(cluster, model, cands)
    assert report.n_candidates == len(cands)
    assert len(report.survivors) + len(report.rejections) == len(cands)
    for cand in report.survivors:
        strat = cand.strategy
        assert strat is not None
        assert feasible(cluster, model, strat)
        seen = set()
        for p in strat.pipelines:
            covered = sorted(l for st in p.stages
                             for l in range(*st.layers))
            assert covered == list(range(model.n_layers)), cand.name
            for st in p.stages:
                assert not (seen & set(st.ranks)), cand.name
                seen.update(st.ranks)
        for gb in memory_per_rank(model, strat).values():
            assert gb <= 0.85 * CPU_A.mem_gb
    for rej in report.rejections:
        assert rej.rule in ("divisibility", "layer-count", "memory")
        assert rej.reason
    assert "feasible" in report.summary()


def test_search_error_reports_per_rule_counts():
    """An infeasible search raises the structured SearchError (a
    RuntimeError subclass) with per-rule rejection counts."""
    searcher = homog_searcher(tp_options=(16,))
    with pytest.raises(SearchError) as ei:
        searcher.search(cpu_cluster(4))
    err = ei.value
    assert isinstance(err, RuntimeError)
    assert "divisibility" in str(err)
    counts = err.report.counts()
    assert counts["divisibility"] > 0
    assert sum(counts.values()) == len(err.report.rejections)


def test_scenarios_shim_raises_search_error():
    """The legacy scenarios.search entry point surfaces the structured
    error (old callers caught bare RuntimeError — still works)."""
    from repro.scenarios.search import search_hetero_strategy
    with pytest.raises(RuntimeError) as ei:
        search_hetero_strategy(cpu_hetero_cluster(2, 2), tiny_spec(),
                               list(range(4)), 8, 128,
                               tp_options=(32,))
    assert isinstance(ei.value, SearchError)
    assert ei.value.report.counts()["divisibility"] > 0


# -- rank --------------------------------------------------------------------

def test_rank_is_sorted_and_deterministic():
    cluster, model = cpu_cluster(4), tiny_spec()
    report = prune(cluster, model, enumerate_candidates(
        cluster, model, global_batch=8, tp_options=(1, 2),
        pp_options=(1, 2), include_hetero=False))
    ranked = rank(cluster, model, report.survivors, 128)
    times = [rc.predicted_step_s for rc in ranked]
    assert times == sorted(times)
    again = rank(cluster, model, report.survivors, 128)
    assert [rc.name for rc in again] == [rc.name for rc in ranked]
    for rc in ranked:
        assert rc.predicted_step_s == pytest.approx(
            rc.pipeline_s + rc.sync_s)
        assert rc.fwd_fraction is not None  # measured proxy fraction


def test_measured_fwd_fraction_changes_pricing():
    from repro.search.rank import proxy_fwd_fraction, resolve_fwd_fraction
    frac = proxy_fwd_fraction()
    assert 0.0 < frac < 1.0
    assert frac != pytest.approx(1.0 / 3.0)   # not the analytic split
    assert resolve_fwd_fraction(None) is None
    assert resolve_fwd_fraction("measured") == frac
    assert resolve_fwd_fraction(0.25) == 0.25


# -- execution validation ----------------------------------------------------

def test_hetero_proxy_exercises_splitar_grad_path():
    """A hetero (hsize>1) candidate's proxy trains through the SplitAR
    gradient reduction — the api:train/hetero4 path.  (tp pinned to 1:
    with TP=2 in the grid the predicted best reduces grads via plain
    AR, and this test is about the SplitAR plan kind.)"""
    result = hetero_searcher(tp_options=(1,)).search(
        cpu_hetero_cluster(2, 2))
    best = result.best.candidate
    assert best.kind == "hetero"
    proxy = proxy_program(best, n_pairs=8, d=16, f=32, batch=16)
    tplan = proxy.program.compile_train(best.name)
    kinds = {rc.plan.kind for rc in tplan.specialization.resolved}
    assert any("SplitAR" in k for k in kinds), kinds


def test_executable_microbatches_respects_shape():
    result = homog_searcher().search(cpu_cluster(4))
    by_name = {rc.name: rc.candidate for rc in result.ranked}
    assert executable_microbatches(by_name["dp4.tp1.pp1"], 64) <= 2
    v2 = by_name["dp1.tp1.pp4.v2"]
    m = executable_microbatches(v2, 64)
    assert m % v2.pp == 0 or m <= v2.pp
    assert 64 % m == 0


@pytest.mark.parametrize("n", [2, 4, 8])
def test_rank_agreement_homogeneous(n):
    """Predicted ordering vs re-priced executed makespans on an n-rank
    homogeneous CPU mesh: pairwise concordance must be high (ties within
    5% carry no ordering signal and are not counted against).

    Per-tier shapes keep the measurement in its valid regime: every
    candidate needs m >= 2 microbatches (a real timetable to re-price,
    so the global batch grows with the widest DP), and per-op compute
    must dominate python dispatch (n=2 packs the whole pair chain onto
    each device, so its proxy dims are larger)."""
    pp = tuple(p for p in (1, 2, 4) if p <= n)
    gb, d, f = {2: (4, 128, 256), 4: (8, 64, 128),
                8: (16, 64, 128)}[n]
    result = homog_searcher(pp_options=pp, global_batch=gb).search(
        cpu_cluster(n), validate_top=5, repeats=5, batch=64, d=d, f=f)
    val = result.validation
    assert val is not None
    executed = [e for e in val.executed if e.error is None]
    assert len(executed) >= 2, val.summary()
    for e in executed:
        assert e.loss is not None
        assert e.measured_makespan_s and e.measured_makespan_s > 0
    ag = val.agreement()
    assert ag is not None and ag >= 0.8, val.summary()


def test_rank_agreement_heterogeneous():
    """On the two-class fixture the ordering is checked on
    speed-PROJECTED makespans (the CPU mesh runs both classes at equal
    speed; projection reintroduces the priced tflops ratio)."""
    result = hetero_searcher().search(
        cpu_hetero_cluster(2, 2), validate_top=3, repeats=5, batch=64,
        d=64, f=128)
    val = result.validation
    assert val is not None and val.speed_projected
    executed = [e for e in val.executed if e.error is None]
    assert len(executed) == 3, val.summary()
    for e in executed:
        assert e.projected_makespan_s and e.projected_makespan_s > 0
    ag = val.agreement()
    assert ag is not None and ag >= 2 / 3, val.summary()
    assert "agreement" in val.summary()


def test_rank_agreement_tp_winner():
    """Predicted-vs-measured ordering with a TP>=2 WINNER: on a
    TP-only grid every candidate shards the pair chain across devices,
    and the re-priced makespans (stacked-dispatch timings, dt/n per
    device) must still order the candidates the way the cost model
    predicted — the regime the old per-device python dispatch drowned
    out (ROADMAP item 2 pinned ``tp_options=(1,)`` because of it)."""
    result = homog_searcher(tp_options=(2,), pp_options=(1, 2),
                            virtual_options=(1,)).search(
        cpu_cluster(4), validate_top=4, repeats=5, batch=64, d=64, f=128)
    assert result.best.candidate.tp >= 2
    val = result.validation
    assert val is not None
    executed = [e for e in val.executed if e.error is None]
    assert len(executed) >= 2, val.summary()
    for e in executed:
        assert e.loss is not None
        assert e.measured_makespan_s and e.measured_makespan_s > 0
    ag = val.agreement()
    assert ag is not None and ag >= 0.8, val.summary()


def test_interleaved_candidate_validates():
    """A v=2 candidate executes under the interleaved schedule (the only
    schedule a v>1 plan accepts)."""
    result = homog_searcher().search(cpu_cluster(4))
    v2 = next(rc for rc in result.ranked if rc.candidate.v == 2)
    report = validate(cpu_cluster(4), [v2], top_k=1, repeats=2,
                      batch=32, d=32, f=64)
    [e] = report.executed
    assert e.error is None, e.describe()
    assert e.schedule == "interleaved"
    assert e.loss is not None
    assert e.measured_makespan_s and e.measured_makespan_s > 0


def test_searcher_is_restart_free():
    """One Searcher instance serves topology changes without rebuild:
    nothing cluster-specific is cached (the elastic driver contract)."""
    searcher = hetero_searcher()
    r44 = searcher.search(cpu_hetero_cluster(2, 2))
    r2 = searcher.search(cpu_cluster(2))
    r44b = searcher.search(cpu_hetero_cluster(2, 2))
    assert [rc.name for rc in r44.ranked] == \
        [rc.name for rc in r44b.ranked]
    assert {rc.predicted_step_s for rc in r44.ranked} == \
        {rc.predicted_step_s for rc in r44b.ranked}
    # the 2-rank cluster admits a different (smaller) candidate set
    assert {rc.name for rc in r2.ranked} != \
        {rc.name for rc in r44.ranked}
    for rc in r2.ranked:
        assert rc.candidate.n_devices <= 2


def test_searcher_select_considers_extras():
    from repro.core.costmodel import step_time
    searcher = homog_searcher()
    cluster = cpu_cluster(4)
    best = searcher.select(cluster)
    searched = searcher.search(cluster).best
    assert step_time(cluster, searcher.model, best, searcher.seq_len) \
        == step_time(cluster, searcher.model,
                     searched.candidate.strategy, searcher.seq_len)
    # an extra strictly better than every searched candidate wins
    fake = searched.candidate.strategy
    assert searcher.select(cluster, extras=(fake,)) is not None


# -- session / plan measurement hooks ---------------------------------------

def test_measure_train_step_and_recorded_ticks():
    from repro import api
    from repro.api.testing import loss_pipeline_program, \
        loss_pipeline_values

    prog = loss_pipeline_program(2, name="pipe2")
    xv, ws, want_y = loss_pipeline_values(seed=11)
    sess = api.Session(prog, "pipe2",
                       executor=api.SimulatorExecutor(record_ticks=True))
    sess.load(ws)
    ms = sess.measure_train_step({"X": xv}, repeats=2,
                                 num_microbatches=4)
    assert ms.seconds > 0
    # the warmup step already applied an optimizer update, so the
    # measured step's loss has moved off the fresh-weights value
    assert np.isfinite(ms.result.loss)
    assert ms.tick_device_seconds
    for (stage, phase), occurrences in ms.tick_device_seconds.items():
        assert phase in ("fwd", "bwd")
        for devops in occurrences:
            for dev, samples in devops.items():
                assert all(s >= 0 for s in samples)


def test_predicted_step_seconds_units():
    from repro.api.testing import loss_pipeline_program

    prog = loss_pipeline_program(2, name="pipe2")
    tplan = prog.compile_train("pipe2")
    base = tplan.predicted_step_seconds(4, "1f1b")
    assert base > 0
    # FLOPs-derived: doubling device speed halves the makespan
    half = tplan.predicted_step_seconds(4, "1f1b",
                                        flops_per_second=2e12)
    assert half == pytest.approx(base / 2)


def test_simulator_executor_rejects_unknown_kwargs():
    from repro import api
    with pytest.raises(TypeError):
        api.get_executor("sim", bogus=True)
    ex = api.get_executor("sim", record_ticks=True)
    assert ex.record_ticks


# -- scenario integration ----------------------------------------------------

def test_priced_schedule_stats_measured_fraction():
    from repro.core.costmodel import LLAMA_32B, paper_cluster
    from repro.scenarios.hetero import (hetu_32b_16h800_16h20,
                                        priced_schedule_stats)
    cluster = paper_cluster(16, 16)
    strat = hetu_32b_16h800_16h20()
    analytic = priced_schedule_stats(cluster, LLAMA_32B, strat, 4096)
    measured = priced_schedule_stats(cluster, LLAMA_32B, strat, 4096,
                                     fwd_fraction="measured")
    assert len(analytic) == len(measured) == len(strat.pipelines)
    assert any(a.makespan != m.makespan
               for a, m in zip(analytic, measured))


def test_elastic_trace_with_searcher_reselection():
    """run_trace re-selects per config through Searcher.select (the
    hand-written layout competes as an extra) and measured pricing
    changes the step times."""
    from repro.core.costmodel import ClusterSpec, H20
    from repro.scenarios.elastic import run_trace
    cluster = ClusterSpec((H20,) * 8)
    trace = [("C1", list(range(8))), ("C2", list(range(6)))]
    base = run_trace(trace, cluster, tiny_spec(), global_batch=8,
                     seq_len=128)
    measured = run_trace(trace, cluster, tiny_spec(), global_batch=8,
                         seq_len=128, pricing="measured")
    assert [r.name for r in base] == ["C1", "C2"]
    assert any(b.step_time_s != m.step_time_s
               for b, m in zip(base, measured))
    searcher = Searcher(tiny_spec(), global_batch=8, seq_len=128,
                        tp_options=(1, 2), pp_options=(1, 2),
                        pipeline_options=(1, 2))
    picked = run_trace(trace, cluster, tiny_spec(), global_batch=8,
                       seq_len=128, searcher=searcher)
    # the searched strategies can only improve on the fixture layout
    for fix, srch in zip(base, picked):
        assert srch.step_time_s <= fix.step_time_s * 1.001
