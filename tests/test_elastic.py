"""Differential + property tests for the elastic trace driver.

The oracle throughout: the probe fixture's weight gradients are
weight-independent integers, so the weights / AdamW m/v trajectory of
ANY elastic run must be **bitwise identical** to an uninterrupted
single-strategy reference run of the same length (only the loss — a sum
of float activations — is reduction-order-dependent).  The jax-executor
side of the same traces is exercised by ``repro.runtime.selftest``
(``elastic:trace/*``, asserted in ``tests/test_runtime.py``).
"""

import os

import numpy as np
import pytest

from repro.checkpoint import store
from repro.checkpoint.store import CheckpointError
from repro.core.simulator import gather
from repro.elastic import (ElasticDriver, ElasticError, Fault, FaultError,
                           FaultPlan, TraceEvent, inject,
                           latest_checkpoint)
from repro.elastic.fixtures import (SearchProvider, probe_feeds,
                                    probe_graph, probe_layout,
                                    probe_provider, probe_values,
                                    reference_run)

REF_STRATEGY = probe_layout([0, 1, 2, 3], "dp")


def snap(session):
    """Gathered full weights + optimizer m/v (the bitwise-compared
    state)."""
    out = {n: gather(st) for n, st in session.weights.items()}
    for key in ("m", "v"):
        for n, st in session.opt_state[key].items():
            out[f"{key}/{n}"] = gather(st)
    return out


def assert_matches_reference(driver, losses, n_steps, m=1):
    ref, ref_losses = reference_run(REF_STRATEGY, n_steps,
                                    num_microbatches=m)
    want, got = snap(ref), snap(driver.session)
    for key in want:
        np.testing.assert_array_equal(
            got[key], want[key],
            err_msg=f"{key} drifted from the uninterrupted reference")
    np.testing.assert_allclose(losses, ref_losses, rtol=1e-5)


def make_driver(**kw):
    kw.setdefault("num_microbatches", 1)
    return ElasticDriver(probe_graph(), probe_values(),
                         kw.pop("provider", probe_provider()),
                         probe_feeds, **kw)


# -- per-transition-kind differential oracles -------------------------------

TRANSITION_TRACES = {
    "shrink": [(0, (0, 1, 2, 3), "dp"), (3, (0, 1), "dp")],
    "grow": [(0, (0, 1), "dp"), (3, (0, 1, 2, 3), "dp")],
    "class-change": [(0, (0, 1, 2, 3), "dp"), (3, (0, 1, 2, 3), "pp")],
    "no-op": [(0, (0, 1, 2, 3), "dp"), (3, (0, 1, 2, 3), "dp")],
}


@pytest.mark.parametrize("m", [1, 2])
@pytest.mark.parametrize("kind", sorted(TRANSITION_TRACES))
def test_transition_kind_differential(kind, m):
    """N driver steps through each transition kind == N uninterrupted
    reference steps, bitwise (weights, m, v), losses to tolerance."""
    n_steps = 6
    driver = make_driver(num_microbatches=m)
    run = driver.run([TraceEvent(*e) for e in TRANSITION_TRACES[kind]],
                     n_steps)
    assert run.transition_kinds() == [kind], run.summary()
    assert len(run.steps) == n_steps
    assert_matches_reference(driver, run.losses, n_steps, m=m)


def test_transition_reports_consumed():
    """The driver consumes Session.switch's SwitchReport: wall seconds,
    src/dst strategy names and fused-BSR stats land on the record."""
    driver = make_driver()
    run = driver.run([(0, (0, 1), "dp"), (2, (0, 1, 2, 3), "pp")], 4)
    (t,) = run.transitions
    assert t.kind == "grow" and t.trigger == "trace"
    assert t.report.src_name == "dp[0,1]"
    assert t.report.dst_name == "pp[0,1,2,3]"
    assert t.report.wall_seconds > 0
    assert t.select_seconds >= 0
    assert t.report.message_count >= 1  # W2 really moved to new devices
    assert "pp[0,1,2,3]" in t.describe()


def test_three_transition_trace_with_search_provider():
    """Acceptance: a >= 3-transition trace with real train_steps, the
    strategy re-SELECTED through repro.search.Searcher.select on every
    transition, trajectory bitwise == the dense reference."""
    n_steps = 8
    provider = SearchProvider(max_rank=4)
    driver = make_driver(provider=provider, num_microbatches=2)
    trace = [(0, (0, 1, 2, 3)), (2, (0, 1)), (4, (0, 1, 2, 3)),
             (6, (0, 1, 2, 3), "hetero")]
    run = driver.run(trace, n_steps)
    assert len(run.transitions) == 3
    assert run.transition_kinds() == ["shrink", "grow", "class-change"]
    # the searcher really ran: one Selection per non-hinted provider call
    assert len(provider.selections) >= 3
    assert all(s.predicted_step_s > 0 for s in provider.selections)
    assert_matches_reference(driver, run.losses, n_steps, m=2)


def test_fault_kill_join_and_mid_transition():
    """Kills/joins from the FaultPlan (including one landing MID
    transition, forcing a second re-select + migration in the same
    step) leave the trajectory bitwise on the reference."""
    n_steps = 6
    faults = FaultPlan((
        Fault(2, "kill", (2, 3)),
        Fault(4, "join", (2,)),
        Fault(4, "kill", (2,), phase="mid-transition"),
    ))
    driver = make_driver(faults=faults)
    run = driver.run([(0, (0, 1, 2, 3), "dp")], n_steps)
    kinds = {(t.step, t.trigger): t.kind for t in run.transitions}
    assert kinds[(2, "fault")] == "shrink"
    assert kinds[(4, "fault")] == "grow"
    assert kinds[(4, "mid-transition")] == "shrink"
    assert_matches_reference(driver, run.losses, n_steps)
    # the pure oracle agrees with what the driver executed
    effective = inject([(0, (0, 1, 2, 3))], faults, n_steps)
    assert [s.ranks for s in run.steps] == \
        [effective[s] for s in range(n_steps)]


def test_checkpoint_kill_resume_under_different_topology():
    """checkpoint -> crash (between the checkpoint and the next step)
    -> resume on a DIFFERENT device set reproduces the unkilled
    trajectory bitwise."""
    n_steps = 8
    tmp = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                       f"elastic-ck-{os.getpid()}")
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    faults = FaultPlan((Fault(4, "crash", phase="post-checkpoint"),))
    driver = make_driver(checkpoint_every=2, ckpt_dir=tmp, faults=faults)
    trace = [(0, (0, 1, 2, 3), "dp")]
    run = driver.run(trace, n_steps)
    assert run.interrupted_at == 4
    assert [s for s, _ in run.checkpoints] == [2, 4]
    # the 'cluster comes back different': resume on 2 other devices
    run2 = driver.resume(trace, n_steps, ranks=(4, 5), layout="pp")
    assert run2.resumed_from[0] == 4
    assert [s.step for s in run2.steps] == [4, 5, 6, 7]
    assert run2.steps[0].ranks == (4, 5)
    losses = run.losses + run2.losses
    assert_matches_reference(driver, losses, n_steps)


def test_resume_replays_lost_progress_deterministically():
    """Resume from a checkpoint OLDER than the last executed step:
    the lost steps are replayed bit-identically (deterministic feeds +
    optimizer), so the final state still equals the dense reference."""
    n_steps = 9
    tmp = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                       f"elastic-lost-{os.getpid()}")
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    driver = make_driver(checkpoint_every=3, ckpt_dir=tmp)
    trace = [(0, (0, 1, 2, 3), "dp")]
    run = driver.run(trace, 8)       # checkpoints at 3 and 6, steps 0..7
    assert [s for s, _ in run.checkpoints] == [3, 6]
    # simulate an unclean death after step 7: state on disk is step 6
    run2 = driver.resume(trace, n_steps, ranks=(0, 1), layout="dp")
    assert [s.step for s in run2.steps] == [6, 7, 8]  # 6, 7 replayed
    losses = run.losses[:6] + run2.losses
    assert_matches_reference(driver, losses, n_steps)


def test_resume_without_checkpoint_raises():
    driver = make_driver(checkpoint_every=2, ckpt_dir="/nonexistent-ck")
    with pytest.raises(ElasticError, match="no complete checkpoint"):
        driver.resume([(0, (0, 1))], 4)


def test_trace_must_cover_step_zero():
    driver = make_driver()
    with pytest.raises(ElasticError, match="step 0"):
        driver.run([(2, (0, 1))], 4)


def test_fault_validation():
    with pytest.raises(FaultError, match="kind"):
        Fault(0, "explode", (1,))
    with pytest.raises(FaultError, match="post-checkpoint"):
        Fault(0, "crash", phase="pre-step")
    with pytest.raises(FaultError, match="ranks"):
        Fault(0, "kill")
    with pytest.raises(FaultError, match="alive"):
        inject([(0, (0,))], FaultPlan((Fault(1, "kill", (0,)),)), 3)


# -- flat-buffer AdamW: switches trip the fallback, never corrupt -----------

def test_switch_trips_flat_adamw_fallback():
    """PR 8's in-place flat-buffer AdamW validates layout + buffer
    identity; a strategy switch migrates m/v to fresh arrays, so the
    next step must REBUILD the flat buffer (fallback), not crash or
    reuse stale views — and stay bitwise on the reference."""
    from repro import api
    program = api.Program(probe_graph(), [REF_STRATEGY])
    session = api.Session(program, 0)
    session.load(probe_values())
    session.train_step(probe_feeds(0))
    session.train_step(probe_feeds(1))
    f1 = session.opt_state["_flat"]["P"]
    session.train_step(probe_feeds(2))
    assert session.opt_state["_flat"]["P"] is f1  # steady-state reuse
    session.switch(probe_layout([0, 1], "dp"))
    assert session.opt_state.get("_flat") is not None  # stale cache kept
    session.train_step(probe_feeds(3))
    f2 = session.opt_state["_flat"]["P"]
    assert f2 is not f1                            # fallback rebuilt it
    ref, _ = reference_run(REF_STRATEGY, 4)
    want, got = snap(ref), snap(session)
    for key in want:
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)


# -- checkpoint atomicity (satellite regression) -----------------------------

def test_save_atomic_under_mid_save_fault(tmp_path, monkeypatch):
    """A fault injected mid-save never leaves a half-checkpoint that
    latest_checkpoint()/resume() can pick up; a previous complete
    checkpoint at the same path survives untouched."""
    ckdir = str(tmp_path / "cks")
    path = os.path.join(ckdir, "step-000002")
    tree = {"weights": {"W1": np.arange(4.0, dtype=np.float32)}}
    store.save(path, tree, step=2)

    class Boom(RuntimeError):
        pass

    def exploding_savez(*a, **kw):
        # the fault lands after save() decided to write but before any
        # byte of the new checkpoint is durable
        raise Boom("disk died mid-save")

    monkeypatch.setattr(store.np, "savez", exploding_savez)
    with pytest.raises(Boom):
        store.save(path, {"weights": {"W1": np.full(4, 9.0)}}, step=9)
    monkeypatch.undo()
    # the old checkpoint is still complete and wins
    found = latest_checkpoint(ckdir)
    assert found is not None and found[1]["step"] == 2
    restored, step = store.restore(
        path, {"weights": {"W1": np.zeros(4, np.float32)}})
    assert step == 2
    np.testing.assert_array_equal(restored["weights"]["W1"],
                                  np.arange(4.0, dtype=np.float32))
    # no temp litter was promoted to a checkpoint
    assert [d for d in os.listdir(ckdir) if d.startswith("step-")] == \
        ["step-000002"]


def test_save_crash_after_arrays_before_manifest(tmp_path, monkeypatch):
    """Dying between arrays.npz and manifest.json leaves NO pickable
    checkpoint (the stage directory never got renamed into place)."""
    ckdir = str(tmp_path / "cks")

    def exploding_dump(*a, **kw):
        raise KeyboardInterrupt  # even BaseException must stay atomic

    monkeypatch.setattr(store.json, "dump", exploding_dump)
    with pytest.raises(KeyboardInterrupt):
        store.save(os.path.join(ckdir, "step-000004"),
                   {"weights": {"W1": np.ones(2)}}, step=4)
    monkeypatch.undo()
    assert latest_checkpoint(ckdir) is None


# -- property: random traces never corrupt optimizer state ------------------
#
# Driven by hypothesis when available (randomized + shrinking); the same
# seed-based generator runs as a fixed parametrized sweep without it, so
# the property is exercised either way.

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

LAYOUT_OPTIONS = ("dp", "pp", "hetero", None)


def _random_faulted_trace(seed: int):
    """A random trace + FaultPlan over the 4-device pool: random kill /
    join points, random per-event layout hints, m in {1, 2, 4}."""
    rng = np.random.default_rng(seed)

    def pick(seq):
        return seq[int(rng.integers(len(seq)))]

    def rank_set(min_size=1, max_size=4):
        k = int(rng.integers(min_size, max_size + 1))
        return tuple(sorted(rng.choice(4, size=k, replace=False)
                            .astype(int).tolist()))

    n_steps = int(rng.integers(4, 9))
    events = [TraceEvent(0, (0, 1, 2, 3), pick(LAYOUT_OPTIONS))]
    for step in sorted(set(rng.integers(1, n_steps,
                                        size=int(rng.integers(0, 4)))
                           .astype(int).tolist())):
        events.append(TraceEvent(step, rank_set(), pick(LAYOUT_OPTIONS)))
    faults = []
    for step in sorted(set(rng.integers(1, n_steps,
                                        size=int(rng.integers(0, 3)))
                           .astype(int).tolist())):
        faults.append(Fault(step, pick(("kill", "join")),
                            rank_set(max_size=2),
                            phase=pick(("pre-step", "mid-transition"))))
    m = pick((1, 2, 4))
    return events, FaultPlan(tuple(faults)), n_steps, m


def _check_random_trace(seed: int):
    """Property: ANY random kill/join trace that keeps >= 1 device
    alive ends bitwise on the dense reference — optimizer state is
    never corrupted by migrations (the flat-buffer AdamW validation
    trips its fallback instead of crashing or reusing stale views)."""
    events, faults, n_steps, m = _random_faulted_trace(seed)
    try:
        effective = inject(events, faults, n_steps)
    except FaultError:
        return  # the plan killed every device — nothing to run
    driver = make_driver(num_microbatches=m, faults=faults)
    run = driver.run(events, n_steps)
    assert [s.ranks for s in run.steps] == \
        [effective[s] for s in range(n_steps)]
    assert_matches_reference(driver, run.losses, n_steps, m=m)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_random_traces_never_corrupt_optimizer_state(seed):
        _check_random_trace(seed)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_random_traces_never_corrupt_optimizer_state(seed):
        _check_random_trace(seed)
