"""Dynamic graph switching tests (paper §6, Fig 12)."""

import numpy as np

from repro.core.annotations import DS, DUP, HSPMD, spmd
from repro.core.graph import Graph
from repro.core.simulator import gather, scatter
from repro.core.switching import execute_switch, plan_switch
from repro.core.symbolic import Sym
from repro.core.topology import NvlinkIbTopology


def _two_strategy_graph():
    """One user graph, two annotated strategies (paper Fig 12):
    strategy 0 = TP over 4 devices; strategy 1 = DP-style over devices 4-7
    (e.g. after a reconfiguration)."""
    g = Graph()
    # strategy 0: Megatron pair — W1 column-parallel, W2 row-parallel
    s0_w1 = spmd([0, 1, 2, 3], DS({1: 4}))
    s1_w1 = spmd([4, 5, 6, 7], DS({DUP: 4}))
    s0_w2 = spmd([0, 1, 2, 3], DS({0: 4}))
    s1_w2 = spmd([4, 5, 6, 7], DS({DUP: 4}))
    x = g.placeholder("X", (8, 16, 32),
                      [spmd([0, 1, 2, 3], DS({DUP: 4})),
                       spmd([4, 5, 6, 7], DS({0: 4}))])
    w1 = g.parameter("W1", (32, 64), [s0_w1, s1_w1])
    w2 = g.parameter("W2", (64, 32), [s0_w2, s1_w2])
    h = g.dot(x, w1)
    h2 = g.gelu(h)
    g.dot(h2, w2)
    g.deduce()
    return g


def test_switch_plan_reports():
    g = _two_strategy_graph()
    rep = plan_switch(g, 0, 1, topology=NvlinkIbTopology())
    assert rep.total_bytes > 0
    assert rep.message_count > 0
    assert rep.planning_seconds < 5.0


def test_fused_beats_naive_and_unfused():
    g = _two_strategy_graph()
    topo = NvlinkIbTopology()
    fused = plan_switch(g, 0, 1, topology=topo, mode="fused")
    unfused = plan_switch(g, 0, 1, topology=topo, mode="unfused")
    naive = plan_switch(g, 0, 1, topology=topo, mode="naive")
    # identical total volume, fewer messages, no worse estimated time
    assert fused.total_bytes == unfused.total_bytes == naive.total_bytes
    assert fused.message_count <= unfused.message_count <= naive.message_count
    assert fused.est_transfer_seconds <= naive.est_transfer_seconds + 1e-9


def test_switch_execution_is_exact():
    """Weight migration reproduces exactly the dst-annotation shards."""
    g = _two_strategy_graph()
    rng = np.random.default_rng(0)
    values = {p.name: rng.normal(size=p.shape) for p in g.parameters()}
    weights = {name: scatter(v, g.tensors[name].annots[0])
               for name, v in values.items()}
    migrated = execute_switch(weights, g, 0, 1)
    for name, v in values.items():
        np.testing.assert_allclose(gather(migrated[name]), v, atol=1e-6)
        dst = g.tensors[name].annots[1]
        for dev in dst.devices:
            box = dst.device_box(dev, v.shape)
            want = v[tuple(slice(lo, hi) for lo, hi in box)]
            np.testing.assert_allclose(migrated[name].parts[dev], want,
                                       atol=1e-6)


def test_switch_roundtrip_back():
    """Switching A->B->A restores the original sharding exactly."""
    g = _two_strategy_graph()
    rng = np.random.default_rng(1)
    values = {p.name: rng.normal(size=p.shape) for p in g.parameters()}
    weights = {name: scatter(v, g.tensors[name].annots[0])
               for name, v in values.items()}
    there = execute_switch(weights, g, 0, 1)
    back = execute_switch(there, g, 1, 0)
    for name, v in values.items():
        for dev, arr in weights[name].parts.items():
            np.testing.assert_allclose(back[name].parts[dev], arr, atol=1e-6)


def test_switch_overlapping_devices_prefers_local():
    """Hetero strategy switch where device sets overlap: overlapping
    shards stay local (heuristic I at switch scale)."""
    g = Graph()
    s0 = spmd([0, 1, 2, 3], DS({0: 4}))
    s1 = HSPMD(dgs=[[0, 1], [2]], dss=[DS({0: 2}), DS({})], hdim=0,
               hsplits=[1, 1])
    g.parameter("W", (16, 8), [s0, s1])
    g.deduce()
    rep = plan_switch(g, 0, 1)
    # dst dev 0 needs rows 0-4 and owns 0-4 already: fully local
    local_dsts = {a.dst for a in rep.plan.local_copies()}
    assert 0 in local_dsts


def test_symbolic_shapes_bound_at_switch():
    B = Sym("B")
    g = Graph()
    g.parameter("W", (B, 8), [spmd([0, 1], DS({0: 2})),
                              spmd([2, 3], DS({1: 2}))])
    g.deduce()
    rep = plan_switch(g, 0, 1, shape_env={"B": 16})
    assert rep.total_bytes == 16 * 8 * 2  # full tensor moves, bf16
