"""Pipeline schedule engine: 1F1B/GPipe tick-table properties, microbatch
role propagation, Session.run(num_microbatches=m) semantics on the
SimulatorExecutor (multi-device JaxExecutor parity runs in the subprocess
selftest's ``api:pipeline/*`` cases), and the costmodel's overlap-aware
fill/drain calibration against the engine.
"""

import numpy as np
import pytest

from repro import api
# the zigzag (v=2 interleaved) fixture is shared with the subprocess
# runtime selftest — one definition, the two cannot drift
from repro.api.testing import (zigzag_program as zigzag_pipeline_program,
                               zigzag_values)
from repro.core.costmodel import fill_drain_count
from repro.core.op_semantics import (MB_DUP, MB_PARTIAL, MicrobatchError,
                                     microbatch_role)
from repro.core.schedule import (PipelineSchedule, ScheduleError, Tick,
                                 build_schedule, microbatch_roles, validate)


# ---------------------------------------------------------------------------
# fixtures: a 2-stage pipeline program ending in an accumulated loss
# ---------------------------------------------------------------------------

def loss_pipeline_program():
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"), name="H")
    g.comm(h, name="H2")
    g.parameter("W2", (12, 6))
    y = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    strat = api.Strategy("pipe", {
        "X": api.spmd([0, 1], api.DS({api.DUP: 2})),
        "W1": api.spmd([0, 1], api.DS({1: 2})),
        "H2": api.spmd([2, 3], api.DS({0: 2})),
        "W2": api.spmd([2, 3], api.DS({api.DUP: 2})),
    })
    return api.Program(g, [strat])


def loss_pipeline_values():
    rng = np.random.default_rng(3)
    xv = rng.integers(-4, 5, (16, 16)).astype(np.float32)
    w1v = rng.integers(-4, 5, (16, 12)).astype(np.float32)
    w2v = rng.integers(-4, 5, (12, 6)).astype(np.float32)
    want_y = np.maximum(xv @ w1v, 0) @ w2v
    return xv, w1v, w2v, want_y, want_y.sum()


# ---------------------------------------------------------------------------
# tick-table properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["1f1b", "gpipe"])
@pytest.mark.parametrize("n_stages,m", [(1, 1), (1, 4), (2, 2), (3, 4),
                                        (4, 8), (4, 2), (5, 16)])
def test_schedule_shape_and_validity(kind, n_stages, m):
    s = build_schedule(n_stages, m, kind)
    validate(s)  # deps + one-tick-per-stage-per-slot + completeness
    assert len(s.ticks) == 2 * n_stages * m
    # both schedules share the fill/drain makespan under uniform ticks
    assert s.n_slots == 2 * (m + n_stages - 1)
    assert s.fill_drain_slots == fill_drain_count(m, n_stages)
    assert s.stats().bubbles == n_stages * s.n_slots - len(s.ticks)
    assert s.stats().p2p_messages == 2 * m * (n_stages - 1)


def test_no_stage_runs_two_ticks_at_once():
    for kind in ("1f1b", "gpipe"):
        s = build_schedule(4, 8, kind)
        busy = set()
        for t in s.ticks:
            assert (t.stage, t.slot) not in busy
            busy.add((t.stage, t.slot))


def test_1f1b_bounds_in_flight_by_stage_depth():
    """1F1B's point: with m > S, at most S microbatches are in flight
    (stage s holds at most S - s), while GPipe holds all m."""
    n_stages, m = 4, 16
    f = build_schedule(n_stages, m, "1f1b")
    g = build_schedule(n_stages, m, "gpipe")
    for s in range(n_stages):
        assert f.peak_in_flight(s) == min(n_stages - s, m)
        assert f.peak_in_flight(s) <= n_stages < m
        assert g.peak_in_flight(s) == m
    # at most s-1 microbatches are queued (warmed up) ahead of steady
    # state at any stage; the steady-state fwd makes the in-flight peak
    for s in range(n_stages):
        warm = min(n_stages - 1 - s, m)
        assert warm <= n_stages - 1


def test_validate_rejects_broken_schedules():
    s = build_schedule(3, 2, "1f1b")
    # swap a fwd tick to before its producer stage
    bad = [Tick(0, 2, 0, "fwd") if (t.stage, t.microbatch, t.phase) ==
           (2, 0, "fwd") else t for t in s.ticks]
    with pytest.raises(ScheduleError, match="precedes"):
        validate(PipelineSchedule("1f1b", 3, 2, bad))
    with pytest.raises(ScheduleError, match="unknown schedule"):
        build_schedule(2, 2, "interleaved_typo")
    with pytest.raises(ScheduleError, match="at least one microbatch"):
        build_schedule(2, 0)
    # v > 1 is an interleaved-only knob
    with pytest.raises(ScheduleError, match="requires kind='interleaved'"):
        build_schedule(2, 2, "1f1b", virtual_stages_per_device=2)
    # Megatron's constraint: m divisible by S (or a single group)
    with pytest.raises(ScheduleError, match="divisible"):
        build_schedule(4, 5, "interleaved", virtual_stages_per_device=2)


def test_simulator_rejects_unexecutable_timetable():
    """The SimulatorExecutor genuinely interprets the timetable: a
    hand-built schedule that runs stage 1 before stage 0 fails on the
    missing stage-boundary input."""
    prog = loss_pipeline_program()
    xv, w1v, w2v, _, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    mplan = prog.compile_micro("pipe", 2)
    good = prog.compile("pipe").schedule(2)
    flipped = [Tick(t.slot, 1 - t.stage, t.microbatch, t.phase)
               for t in good.ticks]
    bad = PipelineSchedule("1f1b", 2, 2, sorted(
        flipped, key=lambda t: (t.slot, t.stage)))
    states = []
    for j in range(2):
        st = {"X": api.scatter(
            np.split(xv, 2)[j],
            mplan.graph.tensors["X"].annots[0])}
        st["W1"], st["W2"] = sess.weights["W1"], sess.weights["W2"]
        states.append(st)
    with pytest.raises(ScheduleError, match="ran before its input"):
        api.SimulatorExecutor().run_schedule(mplan, bad, states)


# ---------------------------------------------------------------------------
# interleaved (virtual-stage) 1F1B
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_stages,v,m", [(1, 2, 3), (2, 2, 2), (2, 2, 4),
                                          (2, 3, 8), (3, 2, 6), (4, 2, 8),
                                          (4, 3, 4), (2, 2, 1)])
def test_interleaved_shape_and_validity(n_stages, v, m):
    s = build_schedule(n_stages, m, "interleaved",
                       virtual_stages_per_device=v)
    validate(s)   # deps over S*v virtual stages + one tick/device/slot
    assert s.virtual_per_stage == v
    assert s.n_virtual == n_stages * v
    assert len(s.ticks) == 2 * n_stages * v * m
    # one tick per DEVICE per slot; every virtual stage maps to its
    # Megatron device (chunk c of device s at virtual index c*S + s)
    busy = set()
    for t in s.ticks:
        dev = s.device_of(t.stage)
        assert dev == t.stage % n_stages
        assert (dev, t.slot) not in busy
        busy.add((dev, t.slot))
    # uniform pricing reproduces the slot count exactly
    st = s.stats()
    assert st.makespan == float(s.n_slots)
    assert 0.0 <= st.bubble_fraction < 1.0
    assert st.bubbles == n_stages * s.n_slots - len(s.ticks)


def test_interleaved_v1_is_exactly_1f1b():
    for n_stages, m in [(1, 4), (2, 2), (3, 4), (4, 8)]:
        a = build_schedule(n_stages, m, "1f1b")
        b = build_schedule(n_stages, m, "interleaved",
                           virtual_stages_per_device=1)
        assert set(a.ticks) == set(b.ticks)
        assert b.kind == "interleaved" and b.n_virtual == n_stages


def test_interleaved_in_flight_bound():
    """Each device holds at most warmup+1 in-flight microbatches —
    Megatron's ``2*(S-1-s) + (v-1)*S + 1`` bound — strictly fewer than
    the m*v a GPipe-style run of the chunked model would hold."""
    n_stages, v, m = 4, 2, 8
    s = build_schedule(n_stages, m, "interleaved",
                       virtual_stages_per_device=v)
    for dev in range(n_stages):
        bound = min(2 * (n_stages - 1 - dev) + (v - 1) * n_stages,
                    m * v) + 1
        assert s.peak_in_flight_device(dev) <= bound
        assert s.peak_in_flight_device(dev) < m * v


def test_interleaved_shrinks_bubble_fraction():
    """The point of interleaving: at the same per-device work, splitting
    each stage into v chunks (ticks 1/v as long) cuts the fill/drain
    bubble share."""
    n_stages, m = 4, 8
    flat = build_schedule(n_stages, m, "1f1b")
    inter = build_schedule(n_stages, m, "interleaved",
                           virtual_stages_per_device=2)
    # price both in real time: a v=2 chunk tick is half a v=1 stage tick
    t_flat = flat.stats({(s, ph): 1.0 for s in range(n_stages)
                         for ph in ("fwd", "bwd")})
    t_inter = inter.stats({(s, ph): 0.5 for s in range(inter.n_virtual)
                           for ph in ("fwd", "bwd")})
    assert t_inter.makespan < t_flat.makespan
    assert t_inter.bubble_fraction < t_flat.bubble_fraction


# ---------------------------------------------------------------------------
# non-uniform (priced) ticks
# ---------------------------------------------------------------------------

def test_priced_uniform_reproduces_closed_form():
    """With equal tick durations the priced makespan is exactly the
    ``2*(m+S-1)`` uniform slot count, for every schedule kind."""
    from repro.core.schedule import price_schedule
    for kind in ("1f1b", "gpipe"):
        for n_stages, m in [(1, 1), (2, 4), (3, 4), (4, 8), (5, 16)]:
            s = build_schedule(n_stages, m, kind)
            priced = price_schedule(s)     # uniform 1.0 ticks
            assert priced.makespan == float(2 * (m + n_stages - 1))
            assert priced.makespan == float(s.n_slots)


def test_priced_makespan_monotone_in_any_tick():
    """Growing any single (stage, phase) duration never shrinks the
    makespan."""
    from repro.core.schedule import price_schedule
    s = build_schedule(3, 4, "1f1b")
    base = {(st, ph): 1.0 for st in range(3) for ph in ("fwd", "bwd")}
    m0 = price_schedule(s, base).makespan
    for key in base:
        bumped = dict(base)
        bumped[key] = 1.5
        assert price_schedule(s, bumped).makespan >= m0
    # and the steady-state bottleneck strictly grows it
    bumped = dict(base)
    bumped[(1, "bwd")] = 2.0
    assert price_schedule(s, bumped).makespan > m0


def test_priced_respects_dependencies_and_device_serialization():
    from repro.core.schedule import price_schedule
    s = build_schedule(2, 4, "interleaved", virtual_stages_per_device=2)
    durations = {(st, ph): 0.5 + 0.25 * st + (0.5 if ph == "bwd" else 0.0)
                 for st in range(s.n_virtual) for ph in ("fwd", "bwd")}
    priced = price_schedule(s, durations)
    starts, finishes = priced.starts, priced.finishes
    for (stage, j, phase), t0 in starts.items():
        if phase == "fwd" and stage > 0:
            assert finishes[(stage - 1, j, "fwd")] <= t0
        if phase == "bwd":
            assert finishes[(stage, j, "fwd")] <= t0
            if stage < s.n_virtual - 1:
                assert finishes[(stage + 1, j, "bwd")] <= t0
    # no device overlaps itself
    for dev in range(s.n_stages):
        spans = sorted((starts[(t.stage, t.microbatch, t.phase)],
                        finishes[(t.stage, t.microbatch, t.phase)])
                       for t in s.device_ticks(dev))
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0
    # busy time accounting
    assert priced.makespan == max(finishes.values())
    assert 0.0 <= priced.bubble_fraction < 1.0


def test_price_schedule_rejects_invalid_timetable():
    from repro.core.schedule import price_schedule
    bad = PipelineSchedule("1f1b", 2, 1, [
        Tick(0, 1, 0, "fwd"), Tick(1, 0, 0, "fwd"),
        Tick(2, 1, 0, "bwd"), Tick(3, 0, 0, "bwd")])
    with pytest.raises(ScheduleError, match="cannot price"):
        price_schedule(bad)


# ---------------------------------------------------------------------------
# microbatch role propagation
# ---------------------------------------------------------------------------

def test_roles_on_loss_pipeline():
    prog = loss_pipeline_program()
    roles = microbatch_roles(prog.graph)
    assert roles["X"] == 0            # batch-split feed
    assert roles["W1"] == roles["W2"] == MB_DUP
    assert roles["H"] == roles["H2"] == roles["Y"] == 0
    assert roles["L1"] == 0           # sum over features keeps batch dim
    assert roles["L"] == MB_PARTIAL   # sum over batch -> accumulate


def test_role_rules_reject_nonlinear_partial():
    with pytest.raises(MicrobatchError, match="nonlinear"):
        microbatch_role("relu", [MB_PARTIAL], {}, [2])
    with pytest.raises(MicrobatchError, match="nonlinear"):
        microbatch_role("mul", [MB_PARTIAL, MB_PARTIAL], {}, [2, 2])
    with pytest.raises(MicrobatchError, match="incompatible"):
        microbatch_role("add", [0, MB_DUP], {}, [2, 2])
    with pytest.raises(MicrobatchError):
        microbatch_role("dot", [MB_PARTIAL, MB_PARTIAL], {}, [2, 2])
    # linear combinations stay Partial
    assert microbatch_role("scale", [MB_PARTIAL], {}, [2]) == MB_PARTIAL
    assert microbatch_role("add", [MB_PARTIAL, MB_PARTIAL], {},
                           [2, 2]) == MB_PARTIAL
    assert microbatch_role("mul", [MB_PARTIAL, MB_DUP], {},
                           [2, 2]) == MB_PARTIAL
    assert microbatch_role("dot", [MB_PARTIAL, MB_DUP], {},
                           [2, 2]) == MB_PARTIAL
    # contraction split over microbatches accumulates
    assert microbatch_role("dot", [1, 0], {}, [2, 2]) == MB_PARTIAL
    assert microbatch_role("transpose", [0], {"perm": (1, 0)}, [2]) == 1
    assert microbatch_role("sum", [1], {"dim": 0}, [2]) == 0


def test_micro_plan_scales_batch_shapes_only():
    prog = loss_pipeline_program()
    mplan = prog.compile_micro("pipe", 4)
    assert mplan.shapes["X"] == (4, 16)
    assert mplan.shapes["Y"] == (4, 6)
    assert mplan.shapes["W1"] == (16, 12)     # Duplicate: unscaled
    assert mplan.shapes["L"] == ()            # Partial: unscaled
    assert mplan.num_microbatches == 4
    # memoized like compile(); m=1 IS the full plan
    assert prog.compile_micro("pipe", 4) is mplan
    assert prog.compile_micro("pipe", 1) is prog.compile("pipe")


def test_micro_plan_rejects_indivisible_batch():
    prog = loss_pipeline_program()
    with pytest.raises(MicrobatchError, match="not divisible"):
        prog.compile_micro("pipe", 3)


def test_micro_plan_binds_symbolic_batch_dim():
    """Regression: a symbolic batch dim bound through shape_env must
    microbatch (the env is in hand; only an UNBOUND symbol errors)."""
    from repro.core.symbolic import Sym
    g = api.Graph()
    g.placeholder("X", (Sym("B"), 8))
    g.parameter("W", (8, 4))
    g.sum(g.sum(g.dot(g.tensors["X"], g.tensors["W"], name="Y"), 1,
                name="L1"), 0, name="L")
    strat = api.Strategy("s", {"X": api.spmd([0], api.DS({})),
                               "W": api.spmd([0], api.DS({}))})
    prog = api.Program(g, [strat])
    mplan = prog.compile_micro("s", 2, shape_env={"B": 8})
    assert mplan.shapes["X"] == (4, 8)
    with pytest.raises(api.MicrobatchError, match="symbolic batch dim"):
        prog.compile_micro("s", 2)
    sess = api.Session(prog, "s", shape_env={"B": 8})
    sess.load({"W": np.ones((8, 4), np.float32)})
    out = sess.run({"X": np.ones((8, 8), np.float32)}, num_microbatches=2)
    assert float(out.value("L")) == 8 * 8 * 4


def test_validate_reports_incomplete_schedules():
    """Regression: a truncated timetable must raise ScheduleError, not
    leak a KeyError from the dependency lookup."""
    with pytest.raises(ScheduleError, match="ticks scheduled"):
        validate(PipelineSchedule("1f1b", 2, 1, [Tick(0, 1, 0, "fwd")]))
    bad = [Tick(0, 0, 0, "fwd"), Tick(1, 0, 0, "bwd"),
           Tick(0, 5, 0, "fwd"), Tick(1, 5, 0, "bwd")]  # stage 5 of 2
    with pytest.raises(ScheduleError):
        validate(PipelineSchedule("1f1b", 2, 1, bad))


def test_run_rejects_unknown_schedule_for_any_m():
    """Regression: a typo'd schedule kind used to pass silently when
    num_microbatches == 1."""
    prog = loss_pipeline_program()
    xv, w1v, w2v, _, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    for m in (1, 2):
        with pytest.raises(api.ScheduleError, match="unknown schedule"):
            sess.run({"X": xv}, num_microbatches=m, schedule="1f1b_typo")


# ---------------------------------------------------------------------------
# Session.run(num_microbatches=m) on the SimulatorExecutor
# ---------------------------------------------------------------------------

def test_run_num_microbatches_1_is_the_unpipelined_path():
    prog = loss_pipeline_program()
    xv, w1v, w2v, want_y, want_l = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    a = sess.run({"X": xv}, fetches=["Y", "L"])
    b = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=1)
    assert b.schedule is None and b.stats is None
    for name in ("Y", "L"):
        for dev, arr in a.shards(name).parts.items():
            np.testing.assert_array_equal(b.shards(name).parts[dev], arr)


@pytest.mark.parametrize("kind", ["1f1b", "gpipe"])
@pytest.mark.parametrize("m", [2, 4])
def test_run_microbatched_accumulates_loss_exactly(kind, m):
    prog = loss_pipeline_program()
    xv, w1v, w2v, want_y, want_l = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    r = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=m,
                 schedule=kind)
    # integer-valued data: the microbatched loss sum is exact -> the
    # result is bit-identical across m (and to the m=1 run)
    assert float(r.value("L")) == float(want_l)
    np.testing.assert_array_equal(r.value("Y"), want_y)
    assert r.schedule.kind == kind
    assert r.schedule.n_slots == 2 * (m + 2 - 1)
    assert r.stats.p2p_messages == 2 * m


def test_gpipe_and_1f1b_agree_bitwise():
    prog = loss_pipeline_program()
    xv, w1v, w2v, _, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    a = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=4,
                 schedule="1f1b")
    b = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=4,
                 schedule="gpipe")
    for name in ("Y", "L"):
        for dev, arr in a.shards(name).parts.items():
            np.testing.assert_array_equal(b.shards(name).parts[dev], arr)


def test_run_microbatched_validates_feeds():
    prog = loss_pipeline_program()
    xv, w1v, w2v, _, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    with pytest.raises(ValueError, match="GLOBAL arrays"):
        sess.run({"X": api.scatter(
            xv, prog.graph.tensors["X"].annots[0])},
            num_microbatches=2)
    with pytest.raises(ValueError, match="unknown feeds"):
        sess.run({"X": xv, "Z": xv}, num_microbatches=2)
    with pytest.raises(ValueError, match="missing feed"):
        sess.run({}, num_microbatches=2)


def test_run_interleaved_degenerate_matches_1f1b_bitwise():
    """On a v=1 plan ``schedule="interleaved"`` IS 1F1B — outputs are
    bit-identical for every microbatch count."""
    prog = loss_pipeline_program()
    xv, w1v, w2v, _, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": w1v, "W2": w2v})
    for m in (1, 2, 4):
        a = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=m,
                     schedule="1f1b")
        b = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=m,
                     schedule="interleaved")
        for name in ("Y", "L"):
            for dev, arr in a.shards(name).parts.items():
                np.testing.assert_array_equal(b.shards(name).parts[dev],
                                              arr)
        if m > 1:
            assert b.schedule.kind == "interleaved"
            assert b.schedule.virtual_per_stage == 1


@pytest.mark.parametrize("m", [1, 2, 4])
def test_run_interleaved_zigzag(m):
    """The SimulatorExecutor interprets the virtual-stage timetable on a
    plan whose dataflow wraps the device ring twice (v=2)."""
    prog = zigzag_pipeline_program()
    xv, ws, want_y = zigzag_values()
    plan = prog.compile("zig")
    assert plan.n_stages == 2
    assert plan.virtual_stages_per_device == 2
    sess = api.Session(prog, "zig")
    sess.load(ws)
    r = sess.run({"X": xv}, fetches=["Y", "L"], num_microbatches=m,
                 schedule="interleaved")
    np.testing.assert_array_equal(r.value("Y"), want_y)
    assert float(r.value("L")) == float(want_y.sum())
    if m > 1:
        assert r.schedule.virtual_per_stage == 2
        assert r.schedule.n_virtual == 4
        assert r.stats.makespan == float(r.schedule.n_slots)


def test_run_rejects_flat_schedules_on_interleaved_plan():
    """A wrapped (v=2) plan cannot run plain 1F1B/GPipe — the timetable
    would tick chunk-1 ops before their chunk-0 producers."""
    prog = zigzag_pipeline_program()
    xv, ws, _ = zigzag_values()
    sess = api.Session(prog, "zig")
    sess.load(ws)
    for kind in ("1f1b", "gpipe"):
        with pytest.raises(api.ScheduleError, match="interleave"):
            sess.run({"X": xv}, num_microbatches=2, schedule=kind)
    # and an explicit v below the plan's chunk count is rejected too
    with pytest.raises(api.ScheduleError, match="too small"):
        sess.run({"X": xv}, num_microbatches=2, schedule="interleaved",
                 virtual_stages_per_device=1)
    with pytest.raises(api.ScheduleError, match="interleaved"):
        sess.run({"X": xv}, num_microbatches=2, schedule="1f1b",
                 virtual_stages_per_device=2)


def test_compiled_plan_surfaces_schedule():
    prog = loss_pipeline_program()
    plan = prog.compile("pipe")
    assert plan.n_stages == 2
    sched = plan.schedule(4)
    assert plan.schedule(4) is sched          # memoized
    assert sched.fill_drain_slots == fill_drain_count(4, plan.n_stages)
    assert "stage 0" in sched.describe()


def test_search_schedule_report():
    """The strategy searcher surfaces the timetable its winner runs."""
    from repro.core.costmodel import uniform_strategy, LLAMA_32B
    from repro.scenarios.search import schedule_report
    strat = uniform_strategy(list(range(16)), LLAMA_32B, dp=2, tp=2, pp=4,
                             global_batch=64)
    rep = schedule_report(strat)
    assert "pipeline 0 [1f1b]" in rep and "pipeline 1" in rep
    assert "bubbles" in rep


# ---------------------------------------------------------------------------
# costmodel calibration
# ---------------------------------------------------------------------------

def test_pipeline_time_overlaps_p2p_with_compute():
    """Regression: stage-boundary P2P used to be serialized on top of the
    fill/drain term (p2p * n_micro).  The overlap-aware estimate pays
    max(compute, p2p) per slot plus each boundary's latency once."""
    from repro.core.costmodel import (LLAMA_32B, PipelineSpec, Stage,
                                      paper_cluster, pipeline_time,
                                      stage_micro_time)
    cluster = paper_cluster(16, 16)
    stages = (Stage(tuple(range(8)), (0, 30)),
              Stage(tuple(range(8, 16)), (30, 60)))
    for m in (4, 16, 64):
        p = PipelineSpec(stages, m, 1)
        seq = 4096
        micro_tokens = p.micro_bs * seq
        times = [stage_micro_time(cluster, LLAMA_32B, st, micro_tokens, seq)
                 for st in stages]
        act = 2 * micro_tokens * LLAMA_32B.d_model
        p2p = act / (cluster.link_gbps(7, 8) * 1e9)
        got = pipeline_time(cluster, LLAMA_32B, p, seq)
        slot = max(max(times), p2p)
        want = fill_drain_count(m, 2) * slot + p2p
        assert got == pytest.approx(want)
        # strictly cheaper than the old double-counting formula
        old = fill_drain_count(m, 2) * max(times) + p2p * m
        assert got < old or p2p == 0


def test_uniform_closed_form_equals_priced_timetable():
    """Regression (the `fill_drain_count` uniform assumption): on
    uniform stage costs the closed-form fast path and the priced
    timetable must agree exactly — pinned here so the two definitions
    cannot drift."""
    from repro.core.costmodel import (LLAMA_32B, PipelineSpec, Stage,
                                      _stage_p2p_times, paper_cluster,
                                      pipeline_tick_durations,
                                      pipeline_time, stage_micro_time)
    from repro.core.schedule import build_schedule, price_schedule
    cluster = paper_cluster(16, 16)
    stages = (Stage(tuple(range(8)), (0, 30)),
              Stage(tuple(range(8, 16)), (30, 60)))
    for kind in ("1f1b", "gpipe"):
        for m in (1, 4, 16):
            p = PipelineSpec(stages, m, 1)
            seq = 4096
            priced = price_schedule(
                build_schedule(2, m, kind),
                pipeline_tick_durations(cluster, LLAMA_32B, p, seq))
            p2p = sum(_stage_p2p_times(cluster, LLAMA_32B, p, seq))
            t_closed = pipeline_time(cluster, LLAMA_32B, p, seq, kind=kind)
            assert priced.makespan + p2p == pytest.approx(t_closed,
                                                          rel=1e-9)
            # and the closed form still is fill * slot + p2p latency
            micro_tokens = seq
            slot = max(stage_micro_time(cluster, LLAMA_32B, stages[0],
                                        micro_tokens, seq), p2p)
            assert t_closed == pytest.approx(
                fill_drain_count(m, 2) * slot + p2p)


def test_nonuniform_stages_priced_below_bottleneck_closed_form():
    """A heterogeneous stage split no longer pays bottleneck price for
    its whole fill ramp: the priced timetable sits strictly below the
    uniform closed form evaluated at the bottleneck, but never below the
    bottleneck's steady-state floor."""
    from repro.core.costmodel import (LLAMA_32B, PipelineSpec, Stage,
                                      paper_cluster, pipeline_time,
                                      stage_micro_time)
    cluster = paper_cluster(16, 16)
    # rank 0-7 H800 carry many layers, ranks 16-23 (H20) carry few:
    # stage times differ -> non-uniform pricing path
    stages = (Stage(tuple(range(16, 24)), (0, 14)),
              Stage(tuple(range(0, 8)), (14, 60)))
    m, seq = 8, 4096
    p = PipelineSpec(stages, m, 1)
    times = [stage_micro_time(cluster, LLAMA_32B, st, seq, seq)
             for st in stages]
    assert times[0] != times[1]
    got = pipeline_time(cluster, LLAMA_32B, p, seq)
    bottleneck = max(times)
    closed_at_bottleneck = fill_drain_count(m, 2) * bottleneck
    assert got < closed_at_bottleneck
    assert got > m * bottleneck    # steady state alone costs this much

# ---------------------------------------------------------------------------
# overlap-aware pricing (async executor cost model)
# ---------------------------------------------------------------------------

def test_price_schedule_comm_none_is_unchanged():
    """The comm/overlap knobs default to today's exact pricing: with
    comm=None the overlap flag is inert, and zero comm is the same as
    no comm."""
    from repro.core.schedule import price_schedule
    dur = lambda s, ph: 1.0 + 0.25 * s + (0.5 if ph == "bwd" else 0.0)
    for kind in ("1f1b", "gpipe"):
        for m in (1, 3, 8):
            sched = build_schedule(4, m, kind)
            base = price_schedule(sched, dur)
            for overlap in (False, True):
                got = price_schedule(sched, dur, comm=None,
                                     overlap=overlap)
                assert got.starts == base.starts
                assert got.finishes == base.finishes
                assert got.makespan == base.makespan
            zero = price_schedule(sched, dur,
                                  comm=lambda s, ph: 0.0, overlap=True)
            assert zero.makespan == base.makespan


def test_overlap_pricing_never_worse_and_strictly_better_when_comm_bound():
    """max(compute, comm) <= compute + comm per tick, so the overlap
    makespan can never exceed the sync makespan of the same split; when
    every tick carries comm equal to its compute, overlap halves the
    tick and the makespan strictly drops."""
    from repro.core.schedule import price_schedule
    dur = lambda s, ph: 2.0 if ph == "bwd" else 1.0
    comm = lambda s, ph: 0.3 + 0.1 * (s % 2)
    for kind in ("1f1b", "gpipe"):
        for m in (1, 4, 8):
            sched = build_schedule(3, m, kind)
            sync = price_schedule(sched, dur, comm=comm).makespan
            over = price_schedule(sched, dur, comm=comm,
                                  overlap=True).makespan
            assert over <= sync
    sched = build_schedule(2, 4, "1f1b")
    sync = price_schedule(sched, dur, comm=dur).makespan
    over = price_schedule(sched, dur, comm=dur, overlap=True).makespan
    assert over == pytest.approx(sync / 2)


def test_pipeline_tick_split_reconstructs_sync_pricing():
    """pipeline_tick_split decomposes each sync tick into compute+comm
    with compute + comm == pipeline_tick_durations exactly, so pricing
    the split WITHOUT overlap reproduces the sync makespan bit-for-bit
    — the invariant that makes `overlap=True` trustworthy (same costs,
    only the combining rule changes)."""
    from repro.core.costmodel import (LLAMA_32B, PipelineSpec, Stage,
                                      paper_cluster,
                                      pipeline_tick_durations,
                                      pipeline_tick_split)
    from repro.core.schedule import price_schedule
    cluster = paper_cluster(16, 16)
    stages = (Stage(tuple(range(16, 24)), (0, 14)),
              Stage(tuple(range(0, 8)), (14, 60)))
    p = PipelineSpec(stages, 8, 1)
    seq = 4096
    sync = pipeline_tick_durations(cluster, LLAMA_32B, p, seq)
    comp, comm = pipeline_tick_split(cluster, LLAMA_32B, p, seq)
    assert set(comp) == set(sync) == set(comm)
    for key in sync:
        assert comp[key] + comm[key] == pytest.approx(sync[key], rel=1e-12)
        assert comm[key] >= 0.0
    sched = build_schedule(2, 8, "1f1b")
    assert price_schedule(sched, comp, comm=comm).makespan == \
        pytest.approx(price_schedule(sched, sync).makespan, rel=1e-12)


def test_pipeline_time_overlap_never_worse():
    """pipeline_time(..., overlap=True) <= sync pricing across kinds,
    microbatch counts, and hetero/interleaved shapes; step_time and the
    search ranking pass the flag through."""
    from repro.core.costmodel import (LLAMA_32B, PipelineSpec, Stage,
                                      paper_cluster, pipeline_time)
    cluster = paper_cluster(16, 16)
    cases = [
        ((Stage(tuple(range(8)), (0, 30)),
          Stage(tuple(range(8, 16)), (30, 60))), "1f1b", 1),
        ((Stage(tuple(range(8)), (0, 30)),
          Stage(tuple(range(8, 16)), (30, 60))), "gpipe", 1),
        ((Stage(tuple(range(16, 24)), (0, 14)),
          Stage(tuple(range(0, 8)), (14, 60))), "1f1b", 1),
        ((Stage(tuple(range(8)), (0, 30)),
          Stage(tuple(range(8, 16)), (30, 60))), "interleaved", 2),
    ]
    for stages, kind, v in cases:
        for m in (2, 8):
            p = PipelineSpec(stages, m, 1)
            sync = pipeline_time(cluster, LLAMA_32B, p, 4096, kind=kind,
                                 virtual_stages_per_device=v)
            over = pipeline_time(cluster, LLAMA_32B, p, 4096, kind=kind,
                                 virtual_stages_per_device=v,
                                 overlap=True)
            assert over <= sync * (1 + 1e-12)


def test_step_time_and_rank_accept_overlap():
    from repro.core.costmodel import (LLAMA_32B, paper_cluster, step_time,
                                      uniform_strategy)
    from repro.search.rank import predict_step_time
    from repro.search.space import Candidate
    cluster = paper_cluster(16, 16)
    strat = uniform_strategy(list(range(16)), LLAMA_32B, dp=1, tp=8,
                             pp=2, global_batch=8)
    sync = step_time(cluster, LLAMA_32B, strat, 4096)
    over = step_time(cluster, LLAMA_32B, strat, 4096, overlap=True)
    assert over <= sync * (1 + 1e-12)
    cand = Candidate(name="u-dp1tp8pp2", kind="uniform", dp=1, tp=8,
                     pp=2, v=1, micro_bs=1, n_micro=8, schedule="1f1b",
                     strategy=strat)
    r_sync = predict_step_time(cluster, LLAMA_32B, cand, 4096)
    r_over = predict_step_time(cluster, LLAMA_32B, cand, 4096,
                               overlap=True)
    assert r_over.predicted_step_s <= r_sync.predicted_step_s * (1 + 1e-12)
