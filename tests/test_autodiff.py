"""Reverse-mode autodiff in the graph IR (core.graph.backward).

Differential tests per VJP rule against jax.grad on random shapes, plus
the annotation-level properties the paper's deduction rules imply for
gradients: Split params' grads arrive Partial and are reduce-scattered,
Duplicate(DP) params' grads all-reduce, and the backward half of the
graph is phase-tagged for the schedule engine.
"""

import numpy as np
import pytest

from repro.core.annotations import DS, DUP, PARTIAL, HSPMD, spmd
from repro.core.graph import (Graph, GradError, VJP_RULES, annots_equal,
                              cotangent_annot, departialize)
from repro.core.simulator import gather, scatter

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.api.executors import SimulatorExecutor  # noqa: E402
from repro.api.program import Program  # noqa: E402


def _run_grads(g, values, fetches):
    """Deduce + backward + execute on the SimulatorExecutor; returns
    gathered global arrays for ``fetches`` (gradient names included)."""
    g.deduce()
    gm = g.backward()
    prog = Program.from_annotated(g)
    plan = prog.compile(0)
    state = {name: scatter(np.asarray(v), g.tensors[name].annots[0],
                           rng=np.random.default_rng(0))
             for name, v in values.items()}
    ex = SimulatorExecutor()
    outs = ex.run(plan, state, [gm.get(f, f) for f in fetches])
    return gm, {f: gather(outs[gm.get(f, f)]) for f in fetches}


# ---------------------------------------------------------------------------
# per-VJP differential tests vs jax.grad (random shapes, single device)
# ---------------------------------------------------------------------------

def _scalarize(g, t):
    """Reduce tensor ``t`` to a scalar loss by summing every dim."""
    ndim = len(t.shape)
    for i in range(ndim):
        t = g.sum(t, 0, name="L" if i == ndim - 1 else f"L{i}")
    return t


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kind", ["dot", "add", "mul", "relu", "gelu",
                                  "scale", "transpose", "reshape", "sum"])
def test_vjp_matches_jax_grad(kind, seed):
    rng = np.random.default_rng(seed)
    m, k, n = rng.integers(2, 7, 3)
    g = Graph()
    one = [spmd([0], DS({}))]
    if kind == "dot":
        a = g.placeholder("A", (int(m), int(k)), one)
        b = g.parameter("B", (int(k), int(n)), one)
        out = g.dot(a, b)
        ref = lambda av, bv: av @ bv                      # noqa: E731
    elif kind in ("add", "mul"):
        a = g.placeholder("A", (int(m), int(n)), one)
        b = g.parameter("B", (int(m), int(n)), one)
        out = getattr(g, kind)(a, b)
        ref = (lambda av, bv: av + bv) if kind == "add" \
            else (lambda av, bv: av * bv)
    elif kind in ("relu", "gelu", "scale"):
        a = g.placeholder("A", (int(m), int(n)), one)
        b = g.parameter("B", (int(m), int(n)), one)
        h = g.mul(a, b)
        if kind == "relu":
            out = g.relu(h)
            ref = lambda av, bv: jax.nn.relu(av * bv)     # noqa: E731
        elif kind == "gelu":
            out = g.gelu(h)
            ref = lambda av, bv: jax.nn.gelu(av * bv, approximate=True)  # noqa: E731,E501
        else:
            out = g._compute("scale", [h], h.shape, factor=1.7)
            ref = lambda av, bv: 1.7 * (av * bv)          # noqa: E731
    elif kind == "transpose":
        a = g.placeholder("A", (int(m), int(k), int(n)), one)
        b = g.parameter("B", (int(n), int(m), int(k)), one)
        out = g.mul(g.transpose(a, (2, 0, 1)), b)
        ref = lambda av, bv: jnp.transpose(av, (2, 0, 1)) * bv  # noqa: E731
    elif kind == "reshape":
        a = g.placeholder("A", (int(m), int(k) * int(n)), one)
        b = g.parameter("B", (int(m) * int(k), int(n)), one)
        new = (int(m) * int(k), int(n))
        out = g.mul(g.reshape(a, new), b)
        ref = lambda av, bv: jnp.reshape(av, new) * bv    # noqa: E731
    else:  # sum
        a = g.placeholder("A", (int(m), int(k), int(n)), one)
        b = g.parameter("B", (int(m), int(n)), one)
        out = g.mul(g.sum(a, 1), b)
        ref = lambda av, bv: jnp.sum(av, 1) * bv          # noqa: E731
    _scalarize(g, out)

    av = rng.normal(size=g.tensors["A"].shape).astype(np.float32)
    bv = rng.normal(size=g.tensors["B"].shape).astype(np.float32)
    gm, got = _run_grads(g, {"A": av, "B": bv}, ["A", "B"])
    ja, jb = jax.grad(lambda a_, b_: jnp.sum(ref(a_, b_)),
                      argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(got["A"], ja, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got["B"], jb, atol=1e-4, rtol=1e-4)


def test_vjp_dot_3d_operand():
    rng = np.random.default_rng(3)
    g = Graph()
    one = [spmd([0], DS({}))]
    a = g.placeholder("A", (2, 3, 4), one)
    b = g.parameter("B", (4, 5), one)
    _scalarize(g, g.dot(a, b))
    av = rng.normal(size=(2, 3, 4)).astype(np.float32)
    bv = rng.normal(size=(4, 5)).astype(np.float32)
    gm, got = _run_grads(g, {"A": av, "B": bv}, ["A", "B"])
    ja, jb = jax.grad(lambda a_, b_: jnp.sum(a_ @ b_),
                      argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(got["A"], ja, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got["B"], jb, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("kind", ["silu", "rsqrt", "div", "softmax",
                                  "rmsnorm", "layernorm", "gather",
                                  "bcast", "attention"])
def test_new_op_vjp_matches_jax_grad(kind, seed):
    """Per-new-op VJP differentials vs jax.grad (the transformer-block
    op set added with the graph-IR block)."""
    rng = np.random.default_rng(100 + seed)
    m, n = (int(v) for v in rng.integers(3, 7, 2))
    g = Graph()
    one = [spmd([0], DS({}))]
    eps = 1e-5
    extra = {}
    if kind == "silu":
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (m, n), one)
        out = g.silu(g.mul(a, b))
        ref = lambda av, bv: jax.nn.silu(av * bv)         # noqa: E731
    elif kind == "rsqrt":
        # rsqrt(a*a + b*b): positive input, and each operand feeds mul
        # twice (multi-consumer accumulation through the new VJP)
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (m, n), one)
        out = g.rsqrt(g.add(g.mul(a, a), g.mul(b, b)))
        ref = lambda av, bv: jax.lax.rsqrt(av * av + bv * bv)  # noqa: E731
    elif kind == "div":
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (m, n), one)
        out = g.div(a, b)
        ref = lambda av, bv: av / bv                      # noqa: E731
    elif kind == "softmax":
        # softmax alone scalarizes to a constant (rows sum to 1), so
        # weight the probabilities to keep the loss sensitive
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (m, n), one)
        out = g.mul(g.softmax(a), b)
        ref = lambda av, bv: jax.nn.softmax(av, axis=-1) * bv  # noqa: E731
    elif kind == "rmsnorm":
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (n,), one)
        out = g.rmsnorm(a, b, eps=eps)
        ref = lambda av, bv: av * jax.lax.rsqrt(          # noqa: E731
            jnp.mean(av * av, -1, keepdims=True) + eps) * bv
    elif kind == "layernorm":
        # bias reuses the gain tensor: accumulation through both roles
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (n,), one)
        out = g.layernorm(a, b, b, eps=eps)

        def ref(av, bv):
            mu = jnp.mean(av, -1, keepdims=True)
            var = jnp.mean((av - mu) ** 2, -1, keepdims=True)
            return (av - mu) * jax.lax.rsqrt(var + eps) * bv + bv
    elif kind == "gather":
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (m, n), one)
        ids = g.placeholder("ids", (m,), one)
        iv = rng.integers(0, n, (m,)).astype(np.int32)
        extra["ids"] = iv
        out = g.gather(g.mul(a, b), ids)
        ref = lambda av, bv: jnp.take_along_axis(         # noqa: E731
            av * bv, iv[:, None], axis=-1)[:, 0]
    elif kind == "bcast":
        a = g.placeholder("A", (m, n), one)
        b = g.parameter("B", (3, m, n), one)
        out = g.mul(g.bcast(a, 0, 3), b)
        ref = lambda av, bv: jnp.broadcast_to(av, (3, m, n)) * bv  # noqa: E731,E501
    else:  # attention (k and v share a tensor: accumulation again)
        B_, H, S, D = 2, 2, 4, 3
        a = g.placeholder("A", (B_, H, S, D), one)
        b = g.parameter("B", (B_, H, S, D), one)
        out = g.attention(a, b, b, causal=True)

        def ref(av, bv):
            s = jnp.einsum("bhqd,bhkd->bhqk", av, bv) / np.sqrt(D)
            mask = jnp.tril(jnp.ones((S, S), bool))
            s = jnp.where(mask, s, -jnp.inf)
            return jnp.einsum("bhqk,bhkd->bhqd",
                              jax.nn.softmax(s, axis=-1), bv)
    _scalarize(g, out)

    if kind in ("rsqrt", "div"):
        av = rng.uniform(0.5, 2.0, g.tensors["A"].shape).astype(np.float32)
        bv = rng.uniform(0.5, 2.0, g.tensors["B"].shape).astype(np.float32)
    else:
        av = rng.normal(size=g.tensors["A"].shape).astype(np.float32)
        bv = rng.normal(size=g.tensors["B"].shape).astype(np.float32)
    gm, got = _run_grads(g, {"A": av, "B": bv, **extra}, ["A", "B"])
    ja, jb = jax.grad(lambda a_, b_: jnp.sum(ref(a_, b_)),
                      argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(got["A"], ja, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(got["B"], jb, atol=1e-4, rtol=1e-3)


def test_vjp_dot_symbolic_leading_dims():
    """Regression: the dot VJP used to reject symbolic leading dims
    (the dw = flatten(x)^T @ flatten(dy) reshape needed concrete
    products); it now carries prod_dims expression trees and binds at
    compile time."""
    from repro.core.symbolic import Sym

    rng = np.random.default_rng(5)
    g = Graph()
    one = [spmd([0], DS({}))]
    a = g.placeholder("A", (Sym("B"), Sym("S"), 4), one)
    b = g.parameter("W", (4, 5), one)
    _scalarize(g, g.dot(a, b))
    g.deduce()
    gm = g.backward()
    prog = Program.from_annotated(g)
    plan = prog.compile(0, shape_env={"B": 2, "S": 3})
    av = rng.normal(size=(2, 3, 4)).astype(np.float32)
    bv = rng.normal(size=(4, 5)).astype(np.float32)
    state = {name: scatter(np.asarray(v), g.tensors[name].annots[0],
                           rng=np.random.default_rng(0))
             for name, v in (("A", av), ("W", bv))}
    outs = SimulatorExecutor().run(plan, state, [gm["A"], gm["W"]])
    ja, jw = jax.grad(lambda a_, b_: jnp.sum(a_ @ b_),
                      argnums=(0, 1))(av, bv)
    np.testing.assert_allclose(gather(outs[gm["A"]]), ja,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gather(outs[gm["W"]]), jw,
                               atol=1e-4, rtol=1e-4)


def test_vjp_embedding_scatter_add():
    rng = np.random.default_rng(4)
    g = Graph()
    tab = g.parameter("T", (11, 5), [spmd([0], DS({}))])
    ids = g.placeholder("ids", (7,), [spmd([0], DS({}))])
    _scalarize(g, g.gelu(g.embedding(tab, ids)))
    iv = rng.integers(0, 11, (7,)).astype(np.int32)
    tv = rng.normal(size=(11, 5)).astype(np.float32)
    gm, got = _run_grads(g, {"T": tv, "ids": iv}, ["T"])
    jt = jax.grad(lambda t_: jnp.sum(
        jax.nn.gelu(t_[iv], approximate=True)))(tv)
    np.testing.assert_allclose(got["T"], jt, atol=1e-4, rtol=1e-4)
    # repeated indices accumulate (the np.add.at / .at[].add path)
    assert len(set(iv.tolist())) < len(iv) or True


# ---------------------------------------------------------------------------
# sharded gradient annotations (the tentpole's deduction property)
# ---------------------------------------------------------------------------

def _loss_mlp(g):
    x = g.tensors["X"]
    w = g.tensors["W'"] if "W'" in g.tensors else g.tensors["W"]
    y = g.dot(x, w, name="Y")
    g.sum(g.sum(g.relu(y, name="R"), 1, name="L1"), 0, name="L")


def test_dp_param_grad_is_partial_then_allreduced():
    """Duplicate-over-DP weights: the deduced grad is PARTIAL over the
    DP dim; the grad-reduce CommOp resolves to AR back onto the
    parameter's own Duplicate placement."""
    g = Graph()
    g.placeholder("X", (8, 6), [spmd([0, 1], DS({0: 2}))])
    g.parameter("W", (6, 4), [spmd([0, 1], DS({DUP: 2}))])
    _loss_mlp(g)
    g.deduce()
    gm = g.backward()
    dw = g.tensors[gm["W"]]
    assert dw.producer.kind == "comm"
    pre = dw.producer.inputs[0]
    assert pre.annots[0].dss[0].get(PARTIAL) == 2
    assert annots_equal(dw.annots[0], g.tensors["W"].annots[0])
    from repro.core.specialize import resolve_comm_ops
    kinds = {rc.op.outputs[0].name: [s.kind for s in rc.plan.steps]
             for rc in resolve_comm_ops(g)}
    assert kinds[gm["W"]] == ["AR"]


def test_split_param_grad_is_reduce_scattered():
    """FSDP-style Split params (resharded to Duplicate for compute):
    gradients come out PARTIAL and the grad-reduce comm is a
    reduce-scatter over the DP dim — the ISSUE's headline property."""
    g = Graph()
    g.placeholder("X", (8, 6), [spmd([0, 1], DS({0: 2}))])
    g.parameter("W", (6, 4), [spmd([0, 1], DS({0: 2}))])
    g.comm(g.tensors["W"], spmd([0, 1], DS({DUP: 2})), name="W'")
    _loss_mlp(g)
    g.deduce()
    gm = g.backward()
    dw = g.tensors[gm["W"]]
    assert annots_equal(dw.annots[0], g.tensors["W"].annots[0])
    from repro.core.specialize import resolve_comm_ops
    kinds = {rc.op.outputs[0].name: [s.kind for s in rc.plan.steps]
             for rc in resolve_comm_ops(g)}
    assert kinds[gm["W"]] == ["RS"]
    # numerics still match jax
    rng = np.random.default_rng(5)
    xv = rng.normal(size=(8, 6)).astype(np.float32)
    wv = rng.normal(size=(6, 4)).astype(np.float32)
    prog = Program.from_annotated(g)
    plan = prog.compile(0)
    ex = SimulatorExecutor()
    state = {"X": scatter(xv, g.tensors["X"].annots[0]),
             "W": scatter(wv, g.tensors["W"].annots[0])}
    outs = ex.run(plan, state, [gm["W"]])
    ref = jax.grad(lambda w_: jnp.sum(jax.nn.relu(xv @ w_)))(wv)
    np.testing.assert_allclose(gather(outs[gm["W"]]), ref, atol=1e-4)


def test_tp_param_grad_stays_split():
    g = Graph()
    g.placeholder("X", (8, 6), [spmd([0, 1], DS({DUP: 2}))])
    g.parameter("W", (6, 4), [spmd([0, 1], DS({1: 2}))])
    _loss_mlp(g)
    g.deduce()
    gm = g.backward()
    dw = g.tensors[gm["W"]]
    # no grad-reduce needed: the deduced grad is already Split(1)
    assert dw.producer.kind != "comm"
    assert annots_equal(dw.annots[0], g.tensors["W"].annots[0])


def test_backward_ops_are_phase_tagged_and_anchored():
    g = Graph()
    g.placeholder("X", (8, 6), [spmd([0], DS({}))])
    g.parameter("W", (6, 4), [spmd([0], DS({}))])
    _loss_mlp(g)
    g.deduce()
    n_fwd = len(g.ops)
    g.backward()
    bwd = [op for op in g.ops if op.attrs.get("phase") == "bwd"]
    assert len(bwd) == len(g.ops) - n_fwd and bwd
    for op in bwd:
        anchor = op.attrs["fwd_anchor"]
        assert anchor in g.tensors
        assert g.tensors[anchor].producer.attrs.get("phase") != "bwd"


# ---------------------------------------------------------------------------
# cotangent annotation algebra
# ---------------------------------------------------------------------------

def test_cotangent_swaps_dup_and_partial():
    a = HSPMD([[0, 1, 2, 3]], [DS({0: 2, DUP: 2})])
    c = cotangent_annot(a)
    assert c.dss[0].get(0) == 2
    assert c.dss[0].get(PARTIAL) == 2 and c.dss[0].get(DUP) == 1
    assert annots_equal(cotangent_annot(c), a)  # involution


def test_cotangent_keeps_splits_and_hsplits():
    a = HSPMD([[0, 1], [2, 3]], [DS({0: 2}), DS({0: 2})],
              hdim=0, hsplits=[1, 3])
    c = cotangent_annot(a)
    assert annots_equal(c, a)  # pure splits are self-cotangent


def test_departialize_merges_into_duplicate():
    a = HSPMD([[0, 1, 2, 3]], [DS({DUP: 2, PARTIAL: 2})])
    d = departialize(a)
    assert d.dss[0].get(DUP) == 4 and not d.has_partial


# ---------------------------------------------------------------------------
# error surfaces
# ---------------------------------------------------------------------------

def test_backward_requires_scalar_loss():
    g = Graph()
    g.placeholder("X", (4, 3), [spmd([0], DS({}))])
    g.parameter("W", (3, 2), [spmd([0], DS({}))])
    g.dot(g.tensors["X"], g.tensors["W"], name="Y")
    g.deduce()
    with pytest.raises(GradError, match="scalar"):
        g.backward(loss="Y")


def test_backward_requires_deduction():
    g = Graph()
    g.placeholder("X", (4,), [spmd([0], DS({}))])
    g.sum(g.tensors["X"], 0, name="L")
    with pytest.raises(GradError, match="deduce"):
        g.backward()


def test_backward_rejects_off_path_parameter():
    g = Graph()
    g.placeholder("X", (4, 3), [spmd([0], DS({}))])
    g.parameter("W", (3, 2), [spmd([0], DS({}))])
    g.parameter("U", (5, 5), [spmd([0], DS({}))])  # unused
    _loss_mlp(g)
    g.deduce()
    with pytest.raises(GradError, match="U"):
        g.backward()


def test_backward_twice_raises():
    g = Graph()
    g.placeholder("X", (4, 3), [spmd([0], DS({}))])
    g.parameter("W", (3, 2), [spmd([0], DS({}))])
    _loss_mlp(g)
    g.deduce()
    g.backward()
    with pytest.raises(GradError, match="already"):
        g.backward()


def test_every_forward_kind_has_a_vjp_rule():
    from repro.core.graph import DEDUCTION_RULES
    fwd_kinds = {"gelu", "relu", "scale", "add", "mul", "dot", "sum",
                 "transpose", "reshape", "embedding", "comm"}
    assert fwd_kinds <= set(VJP_RULES) | {"comm"}
    assert set(VJP_RULES) - {"comm"} <= set(DEDUCTION_RULES)


def test_multi_consumer_grads_accumulate():
    """A tensor consumed twice gets the SUM of both contributions."""
    g = Graph()
    g.placeholder("X", (4, 4), [spmd([0], DS({}))])
    g.parameter("W", (4, 4), [spmd([0], DS({}))])
    x, w = g.tensors["X"], g.tensors["W"]
    y1 = g.dot(x, w, name="Y1")
    y2 = g.mul(x, g.relu(x, name="R"), name="Y2")   # X used 3 times total
    s = g.add(y1, y2, name="S")
    g.sum(g.sum(s, 1, name="L1"), 0, name="L")
    rng = np.random.default_rng(6)
    xv = rng.normal(size=(4, 4)).astype(np.float32)
    wv = rng.normal(size=(4, 4)).astype(np.float32)
    gm, got = _run_grads(g, {"X": xv, "W": wv}, ["X", "W"])
    ref = jax.grad(lambda x_, w_: jnp.sum(
        x_ @ w_ + x_ * jax.nn.relu(x_)), argnums=(0, 1))(xv, wv)
    np.testing.assert_allclose(got["X"], ref[0], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got["W"], ref[1], atol=1e-4, rtol=1e-4)
