"""Scenario-level tests: elastic traces, mixed-length policies, and the
paper-claim validations EXPERIMENTS.md cites."""

import numpy as np
import pytest

from repro.core.costmodel import (LLAMA_32B, ClusterSpec, H20, paper_cluster)
from repro.scenarios.elastic import (TRACE_HETERO, TRACE_HOMOG,
                                     checkpoint_restart_baseline, run_trace,
                                     two_pipeline_strategy)
from repro.scenarios.mixed_length import run_mixed_length


def test_two_pipeline_strategy_uses_all_ranks():
    for name, ranks in TRACE_HOMOG + TRACE_HETERO:
        s = two_pipeline_strategy(ranks, LLAMA_32B)
        used = sorted(r for p in s.pipelines for st in p.stages
                      for r in st.ranks)
        assert used == sorted(ranks), name
        # every layer covered exactly once per pipeline
        for p in s.pipelines:
            covered = []
            for st in p.stages:
                covered.extend(range(*st.layers))
            assert sorted(covered) == list(range(LLAMA_32B.n_layers))


def test_elastic_trace_reconfig_cheaper_than_restart():
    """Paper §7.2: Hetu's restart-free transition beats checkpoint+restart."""
    homog = ClusterSpec((H20,) * 32)
    hetu = run_trace(TRACE_HOMOG, homog)
    base = checkpoint_restart_baseline(TRACE_HOMOG, homog)
    for h, b in zip(hetu[1:], base[1:]):
        assert h.reconfigure_s < b.reconfigure_s


def test_elastic_gpu_failure_keeps_survivors():
    """Paper §7.2: on a 1-GPU failure the uniform baseline discards the
    whole node while Hetu keeps all survivors -> Hetu's C2 step wins."""
    homog = ClusterSpec((H20,) * 32)
    hetu = run_trace(TRACE_HOMOG, homog)
    base = checkpoint_restart_baseline(TRACE_HOMOG, homog)
    c2_h = next(r for r in hetu if r.name == "C2")
    c2_b = next(r for r in base if r.name == "C2")
    assert c2_h.step_time_s < c2_b.step_time_s


def test_mixed_length_ordering_matches_paper():
    """Fig 15: baseline > HotSPa >= Hetu-B on mean step time."""
    means = {}
    for policy in ("baseline", "hotspa", "hetu_b"):
        reps = run_mixed_length(policy, n_steps=10, seed=3)
        means[policy] = np.mean([r.seconds for r in reps])
    assert means["baseline"] > means["hotspa"]
    assert means["hetu_b"] < means["baseline"]
    assert means["hetu_b"] <= means["hotspa"] * 1.05


def test_hetu_b_switches_on_regime_change_only():
    reps = run_mixed_length("hetu_b", n_steps=15, seed=7)
    regimes = ["long" if r.max_len > 16384 else "short" for r in reps]
    for prev, cur, r in zip(regimes, regimes[1:], reps[1:]):
        assert r.switched == (prev != cur)


def test_bsr_fusion_ordering():
    """Fig 18: fused <= heuristic-unfused <= naive in estimated time."""
    import benchmarks.bench_bsr_fusion as bb
    rows = {n.split("/")[-1]: t for n, t, _ in bb.rows()
            if n.startswith("fig18")}
    assert rows["fused"] <= rows["heuristic_unfused"] <= rows["naive_unfused"]


def test_strategy_search_beats_or_matches_uniform():
    """The searcher must find a hetero strategy at least as good as the
    best uniform one on the paper's mixed cluster (it can express
    everything uniform can, plus asymmetric layouts)."""
    from repro.core.costmodel import best_uniform
    from repro.scenarios.search import search_hetero_strategy
    cluster = paper_cluster(16, 16)
    ranks = list(range(32))
    _, t_uni = best_uniform(cluster, LLAMA_32B, ranks, 64, 4096)
    strat, t_het = search_hetero_strategy(cluster, LLAMA_32B, ranks, 64,
                                          4096)
    assert t_het <= t_uni * 1.001
    # searched strategy must cover every layer exactly once per pipeline
    for p in strat.pipelines:
        covered = sorted(l for st in p.stages for l in range(*st.layers))
        assert covered == list(range(LLAMA_32B.n_layers))


def test_strategy_search_homogeneous_sanity():
    """On a homogeneous cluster the search result stays within 25% of the
    best uniform strategy (it explores a coarser grid)."""
    from repro.core.costmodel import best_uniform
    from repro.scenarios.search import search_hetero_strategy
    cluster = ClusterSpec((H20,) * 16)
    ranks = list(range(16))
    _, t_uni = best_uniform(cluster, LLAMA_32B, ranks, 64, 4096)
    _, t_het = search_hetero_strategy(cluster, LLAMA_32B, ranks, 64, 4096)
    assert t_het <= t_uni * 1.25


def test_hetero_strategies_scored_by_priced_timetable():
    """The Table 5 strategies' step time comes from the priced timetable
    they'd execute: `priced_schedule_stats` per pipeline agrees exactly
    with `pipeline_time`'s non-uniform scoring (makespan + boundary
    latencies)."""
    import pytest
    from repro.core.costmodel import (_stage_p2p_times, pipeline_time,
                                      stage_micro_time)
    from repro.scenarios.hetero import (hetu_32b_16h800_16h20,
                                        priced_schedule_stats)
    cluster = paper_cluster(16, 16)
    strat = hetu_32b_16h800_16h20()
    stats = priced_schedule_stats(cluster, LLAMA_32B, strat, 4096)
    assert len(stats) == len(strat.pipelines)
    for st, p in zip(stats, strat.pipelines):
        # heterogeneous split -> genuinely non-uniform stage ticks
        times = [stage_micro_time(cluster, LLAMA_32B, stage, 4096, 4096)
                 for stage in p.stages]
        assert len(set(times)) > 1
        assert st.makespan > 0.0
        assert 0.0 <= st.bubble_fraction < 1.0
        p2p = sum(_stage_p2p_times(cluster, LLAMA_32B, p, 4096))
        assert pipeline_time(cluster, LLAMA_32B, p, 4096) == \
            pytest.approx(st.makespan + p2p, rel=1e-9)


def test_search_schedule_report_priced():
    """With cluster + model the searcher's schedule report prices the
    ticks (non-uniform makespan in seconds, not slots)."""
    from repro.core.costmodel import uniform_strategy
    from repro.scenarios.search import schedule_report
    cluster = paper_cluster(16, 16)
    strat = uniform_strategy(list(range(16)), LLAMA_32B, dp=2, tp=2, pp=4,
                             global_batch=64)
    plain = schedule_report(strat)
    priced = schedule_report(strat, cluster, LLAMA_32B, seq_len=4096)
    assert "makespan" in plain and "makespan" in priced
    assert plain != priced
