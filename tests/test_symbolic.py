"""Symbolic shape tests (paper §5.5)."""

import pytest

from repro.core.symbolic import Sym, bind_shape, free_symbols, is_concrete


def test_bind_basic():
    B, S = Sym("B"), Sym("S")
    assert bind_shape((B, S, 128), {"B": 4, "S": 16}) == (4, 16, 128)


def test_constraint_preserving_arithmetic():
    B = Sym("B")
    half = B // 2
    assert bind_shape((half,), {"B": 8}) == (4,)
    with pytest.raises(ValueError):
        bind_shape((half,), {"B": 9})  # non-divisible -> rejected (§5.5)


def test_compound_expressions():
    B, S = Sym("B"), Sym("S")
    e = (B * S) // 4 + 1
    assert bind_shape((e,), {"B": 2, "S": 8}) == (5,)


def test_unbound_symbol_rejected():
    with pytest.raises(KeyError):
        bind_shape((Sym("Z"),), {})


def test_nonpositive_rejected():
    B = Sym("B")
    with pytest.raises(ValueError):
        bind_shape((B - 4,), {"B": 4})


def test_free_symbols_and_concrete():
    B = Sym("B")
    assert free_symbols((B // 2, 7)) == {"B"}
    assert not is_concrete((B, 4))
    assert is_concrete((3, 4))
