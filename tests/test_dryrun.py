"""Dry-run infrastructure tests.

The full 40-combo sweeps run via ``python -m repro.launch.dryrun --all``
(and --multi-pod); results land in dryrun_results.jsonl /
dryrun_multipod.jsonl.  Here we test the pieces cheaply and run ONE real
lower+compile in a subprocess (the 512-device env must not leak into this
process — smoke tests see 1 device per spec)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch.specs import (INPUT_SHAPES, batch_specs_for, input_specs,
                                param_structs, shape_applicable)
from repro.configs import ARCHS, get_config

ASSIGNED = [a for a in ARCHS if not a.startswith("llama")]


def test_cost_analysis_counts_while_bodies_once():
    """The §Roofline methodology hinges on this XLA behaviour."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    single = jax.jit(lambda a, b: a @ b).lower(x, w).compile()

    def scanned(a, b):
        y, _ = jax.lax.scan(lambda c, _: (c @ b, None), a, None, length=10)
        return y

    looped = jax.jit(scanned).lower(x, w).compile()
    from repro.launch.hloparse import normalize_cost_analysis
    f1 = normalize_cost_analysis(single.cost_analysis())["flops"]
    f10 = normalize_cost_analysis(looped.cost_analysis())["flops"]
    assert f10 < 2 * f1, "XLA started trip-counting: update roofline.py"


def test_input_specs_no_allocation():
    """input_specs must be pure ShapeDtypeStructs (no device arrays)."""
    for arch in ASSIGNED[:4]:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if not shape_applicable(cfg, shape)[0]:
                continue
            kind, specs = input_specs(cfg, shape.name)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_input_shapes_match_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long_500k_gating():
    """Sub-quadratic archs run long_500k; full-attention archs skip."""
    runs = {a: shape_applicable(get_config(a), INPUT_SHAPES["long_500k"])[0]
            for a in ASSIGNED}
    assert runs["mamba2_370m"] and runs["recurrentgemma_9b"]
    assert not runs["qwen1_5_110b"] and not runs["deepseek_v2_236b"]
    assert sum(runs.values()) == 2


def test_collective_bytes_parser():
    from repro.launch.hloparse import collective_bytes
    hlo = """
      %ar = bf16[16,512]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = f32[4,128]{1,0} all-gather(%y), dimensions={0}
      %rs = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) reduce-scatter(%a, %b)
      %cp = u32[2]{0} collective-permute-start(%c)
      %notacoll = bf16[9,9]{1,0} add(%p, %q)
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 512 * 2
    assert got["all-gather"] == 4 * 128 * 4
    assert got["reduce-scatter"] == 2 * 8 * 8 * 2
    assert got["collective-permute"] == 2 * 4
    assert set(got) == {"all-reduce", "all-gather", "reduce-scatter",
                        "collective-permute"}


def test_smoke_tests_see_one_device():
    """The 512-device XLA flag must NOT leak into the test env."""
    assert len(jax.devices()) < 16


@pytest.mark.slow
def test_real_dryrun_subprocess():
    """One real (arch x shape) lower+compile on the 16x16 mesh, in a
    subprocess (where the 512-host-device flag is set by dryrun.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-1.5b", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=560,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1/1 combinations OK" in proc.stdout


def test_sweep_artifacts_if_present():
    """When the full sweeps have run, every (arch x shape) must be OK or
    an explicitly documented skip — on BOTH meshes."""
    for fname in ("dryrun_results.jsonl", "dryrun_multipod.jsonl"):
        if not os.path.exists(fname):
            pytest.skip(f"{fname} not generated yet")
        rows = [json.loads(l) for l in open(fname)]
        combos = {(r["arch"], r["shape"]) for r in rows}
        assert len(combos) == 40, f"{fname}: {len(combos)} combos"
        errors = [r for r in rows if "error" in r]
        assert not errors, errors[:2]
        skips = [r for r in rows if "skipped" in r]
        assert len(skips) == 8  # 8 full-attention archs x long_500k
