"""Tests for the BSR planner (paper §4.3, Fig 8) and fused BSR (§6.2)."""

import numpy as np
import pytest

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd
from repro.core.bsr import (PartialBsrError, build_table, plan_bsr,
                            plan_bsr_naive, plan_fused_bsr, plan_unfused_bsr)
from repro.core.plan import CommPlan
from repro.core.simulator import apply_plan, roundtrip_check, scatter
from repro.core.topology import NvlinkIbTopology, UniformTopology

RNG = np.random.default_rng(7)


def _exec_check(src, dst, shape, plan):
    cp = CommPlan(src=src, dst=dst, kind="BSR")
    cp.add(plan.to_step(), dst)
    roundtrip_check(RNG.normal(size=shape), src, dst, cp,
                    rng=np.random.default_rng(5))


def test_local_copy_heuristic():
    # receiver already owns its slice -> zero transfers
    src = spmd([0, 1], DS({0: 2}))
    dst = spmd([0, 1], DS({0: 2}))
    plan = plan_bsr(src, dst, (8, 4))
    assert plan.transfers() == []
    assert len(plan.local_copies()) == 2


def test_fig8_style_case():
    """Paper Fig 8: src sharded over one group, dst over another with
    overlap; owned slices are locally copied, the rest transferred."""
    # src: devices 0-3 split dim0 into 4; dst: devices {1, 8, 9} split into 3
    # (sizes 12 so both 4 and 3 divide)
    src = spmd([0, 1, 2, 3], DS({0: 4}))
    dst = spmd([1, 8, 9], DS({0: 3}))
    shape = (12, 4)
    plan = plan_bsr(src, dst, shape, NvlinkIbTopology(gpus_per_node=8))
    _exec_check(src, dst, shape, plan)
    # device 1 owns rows 3..6; its dst shard is rows 4..8 -> rows 4..6 local
    locals_dev1 = [a for a in plan.local_copies() if a.dst == 1]
    assert locals_dev1, "heuristic I must keep owned slices local"


def test_bandwidth_preference():
    # slice owned by devices 1 (remote node) and 9 (same node as receiver 8):
    # heuristic II must pick 9.
    src = HSPMD(dgs=[[1], [9]], dss=[DS({}), DS({})], hdim=DUP)
    dst = spmd([8], DS({}))
    topo = NvlinkIbTopology(gpus_per_node=8)
    plan = plan_bsr(src, dst, (4, 4), topo)
    assert all(a.src == 9 for a in plan.transfers())


def test_load_balance_tiebreak():
    # 4 owners with equal bandwidth, 2 receivers needing 2 slices each:
    # heuristic III spreads senders instead of hammering device 0.
    src = spmd([0, 1, 2, 3], DS({DUP: 4}))
    dst = spmd([4, 5], DS({0: 2}))
    plan = plan_bsr(src, dst, (8, 4), UniformTopology())
    senders = {a.src for a in plan.transfers()}
    assert len(senders) >= 2, f"load not balanced: {senders}"


def test_naive_min_rank():
    src = spmd([0, 1, 2, 3], DS({DUP: 4}))
    dst = spmd([4, 5], DS({0: 2}))
    plan = plan_bsr_naive(src, dst, (8, 4))
    assert {a.src for a in plan.transfers()} == {0}
    _exec_check(src, dst, (8, 4), plan)


def test_partial_rejected():
    src = spmd([0, 1], DS({PARTIAL: 2}))
    dst = spmd([2, 3], DS({0: 2}))
    with pytest.raises(PartialBsrError):
        plan_bsr(src, dst, (4, 4))


def test_table_owner_merge():
    src = spmd([0, 1], DS({DUP: 2}))
    dst = spmd([2], DS({}))
    table = build_table(src, dst, (4, 4))
    assert len(table) == 1
    assert table[0].owners == (0, 1)
    assert table[0].needers == (2,)


def test_fused_vs_unfused_message_count():
    """Fusion coalesces per-pair messages across tensors (paper Fig 18)."""
    tensors = []
    for i in range(6):
        src = spmd([0, 1, 2, 3], DS({0: 4}))
        dst = spmd([4, 5, 6, 7], DS({0: 4}))
        tensors.append((f"w{i}", src, dst, (16, 8), 2))
    fused = plan_fused_bsr(tensors)
    unfused = plan_unfused_bsr(tensors)
    assert fused.total_bytes() == unfused.total_bytes()  # same volume...
    assert fused.message_count() < unfused.message_count()  # ...fewer launches
    assert fused.message_count() == 4  # one fused message per (src,dst) pair


def test_fused_load_balance_spans_tensors():
    """The shared cumulative-load state balances across the whole switch."""
    # every tensor is replicated on 0..3 and needed by device 4
    tensors = [(f"w{i}", spmd([0, 1, 2, 3], DS({DUP: 4})),
                spmd([4], DS({})), (8, 8), 2) for i in range(8)]
    fused = plan_fused_bsr(tensors, UniformTopology())
    senders = [a.src for a in fused.transfers()]
    # perfect balance: each of the 4 owners sends 2 of the 8 tensors
    assert sorted(senders.count(d) for d in range(4)) == [2, 2, 2, 2]
    per_tensor = plan_unfused_bsr(tensors, UniformTopology())
    senders_u = [a.src for a in per_tensor.transfers()]
    # without shared state every tensor independently picks the same sender
    assert len(set(senders_u)) == 1


def test_est_time_fusion_wins():
    tensors = [(f"w{i}", spmd([0, 1, 2, 3], DS({DUP: 4})),
                spmd([4], DS({})), (64, 64), 2) for i in range(8)]
    topo = NvlinkIbTopology(gpus_per_node=8)
    t_fused = plan_fused_bsr(tensors, topo).est_time(topo)
    t_naive = plan_unfused_bsr(tensors, topo).est_time(topo)
    assert t_fused < t_naive


def test_bsr_numerical_roundtrip_random():
    rng = np.random.default_rng(11)
    for trial in range(10):
        n_src = int(rng.integers(1, 5))
        n_dst = int(rng.integers(1, 5))
        src = spmd(list(range(n_src)), DS({0: n_src}))
        dst = spmd(list(range(10, 10 + n_dst)), DS({1: n_dst}))
        shape = (n_src * n_dst * 2, n_src * n_dst * 2)
        plan = plan_bsr(src, dst, shape)
        _exec_check(src, dst, shape, plan)
