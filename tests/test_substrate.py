"""Tests for the substrate layers: data pipeline, optimizer, checkpoint,
sharding rules, cost model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (Bucket, CorpusConfig, SyntheticCorpus,
                                 bucketize, pack_batch, step_stream)
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_corpus_length_distribution():
    c = SyntheticCorpus(CorpusConfig("commoncrawl", max_len=32768))
    lens = c.sample_lengths(5000)
    # paper Fig 16: ~97% of sequences under 8K
    assert (lens < 8192).mean() > 0.9
    assert lens.max() <= 32768 and lens.min() >= 8


def test_pack_batch_masks_and_positions():
    c = SyntheticCorpus(CorpusConfig("commoncrawl", max_len=512))
    seqs = c.sample_sequences(8)
    b = pack_batch(seqs, batch=2, context=256)
    assert b["tokens"].shape == (2, 256)
    assert b["loss_mask"].max() <= 1.0
    # positions reset at document boundaries: every position <= its index
    assert (b["positions"] <= np.arange(256)[None]).all()


def test_bucketize_covers_everything():
    c = SyntheticCorpus(CorpusConfig("github", max_len=32768))
    seqs = c.sample_sequences(200)
    buckets = (Bucket(0, 4096), Bucket(4096, 16384), Bucket(16384, 32768))
    by = bucketize(seqs, buckets)
    assert sum(len(v) for v in by.values()) == len(seqs)
    for b, ss in by.items():
        for s in ss:
            assert len(s) <= b.hi


def test_step_stream_token_budget():
    c = SyntheticCorpus(CorpusConfig("commoncrawl"))
    for seqs in step_stream(c, 50_000, 3):
        assert sum(len(s) for s in seqs) >= 50_000


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.ones((4,)) * 5.0}
    opt = init_opt_state(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, opt, m = apply_updates(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0
    assert int(opt["count"]) == 50


def test_adamw_grad_clip():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    opt = init_opt_state(params)
    _, _, m = apply_updates(params, {"w": jnp.full((4,), 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.store import restore, save
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "l": [jnp.zeros(()), jnp.ones((2,))]}
    save(str(tmp_path / "ck"), tree, step=7, meta={"arch": "test"})
    restored, step = restore(str(tmp_path / "ck"), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_cross_strategy_restore(tmp_path):
    """A checkpoint written under one 'strategy' restores under another
    (the §7.2 baseline path)."""
    from repro.checkpoint.store import restore, save
    from repro.configs import get_config
    from repro.models.model import init_params
    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    save(str(tmp_path / "ck"), params, step=1)
    restored, _ = restore(str(tmp_path / "ck"), params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_bf16_roundtrip_restores_dtype(tmp_path):
    """bf16 leaves survive the npz trip (stored widened, narrowed back
    on restore) — bitwise, not just approximately."""
    from repro.checkpoint.store import restore, save
    vals = jnp.asarray([1.0, -2.5, 3.0e4, 1.0 / 3.0], jnp.bfloat16)
    tree = {"w": vals, "f": jnp.arange(3, dtype=jnp.float32)}
    save(str(tmp_path / "ck"), tree, step=0)
    restored, _ = restore(str(tmp_path / "ck"), tree)
    assert restored["w"].dtype == jnp.bfloat16
    assert restored["f"].dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(restored["w"], np.float32), np.asarray(vals, np.float32))


def test_checkpoint_key_mismatch_structured_error(tmp_path):
    """Restoring into a skeleton whose keys disagree with the manifest
    raises CheckpointError naming BOTH the missing and the extra keys."""
    from repro.checkpoint.store import CheckpointError, restore, save
    save(str(tmp_path / "ck"), {"w1": jnp.ones(2), "w2": jnp.zeros(2)},
         step=3)
    with pytest.raises(CheckpointError) as exc:
        restore(str(tmp_path / "ck"), {"w1": jnp.ones(2),
                                       "w3": jnp.ones(2)})
    msg = str(exc.value)
    assert "w2" in msg and "w3" in msg


def test_checkpoint_manifest_npz_disagreement(tmp_path):
    """A manifest that lists keys the npz doesn't carry (or the
    reverse) is a structured CheckpointError, not a KeyError."""
    import json

    from repro.checkpoint.store import CheckpointError, restore, save
    save(str(tmp_path / "ck"), {"w": jnp.ones(2)}, step=1)
    mpath = tmp_path / "ck" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["keys"]["ghost"] = {"shape": [2], "dtype": "float32"}
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(CheckpointError, match="ghost"):
        restore(str(tmp_path / "ck"), {"w": jnp.ones(2)})


def test_checkpoint_corrupted_npz_detected(tmp_path):
    """Flipped bytes in the middle of arrays.npz trip zlib's CRC and
    surface as CheckpointError (every member is force-decompressed)."""
    from repro.checkpoint.store import CheckpointError, restore, save
    tree = {"w": jnp.arange(4096, dtype=jnp.float32)}
    save(str(tmp_path / "ck"), tree, step=2)
    npz = tmp_path / "ck" / "arrays.npz"
    blob = bytearray(npz.read_bytes())
    mid = len(blob) // 2
    blob[mid:mid + 16] = bytes(16)
    npz.write_bytes(bytes(blob))
    with pytest.raises(CheckpointError):
        restore(str(tmp_path / "ck"), tree)


def test_checkpoint_truncated_npz_detected(tmp_path):
    """A half-written arrays.npz (torn copy / full disk) is detected,
    as is one missing entirely."""
    from repro.checkpoint.store import CheckpointError, peek, restore, save
    tree = {"w": jnp.arange(1024, dtype=jnp.float32)}
    save(str(tmp_path / "ck"), tree, step=5)
    npz = tmp_path / "ck" / "arrays.npz"
    blob = npz.read_bytes()
    npz.write_bytes(blob[:len(blob) // 3])
    with pytest.raises(CheckpointError):
        restore(str(tmp_path / "ck"), tree)
    npz.unlink()
    with pytest.raises(CheckpointError):
        restore(str(tmp_path / "ck"), tree)
    # peek still reads the (intact) manifest without touching arrays
    assert peek(str(tmp_path / "ck"))["step"] == 5


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_annot_spec_bridge_roundtrip():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core.annotations import DS, DUP, spmd
    from repro.sharding.rules import annot_to_spec, spec_to_annot
    a = spmd([0, 1, 2, 3], DS([(0, 2), (1, 2)]))
    spec = annot_to_spec(a, ("data", "model"))
    assert spec == P("data", "model")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    back = spec_to_annot(P("data", "model"), mesh, (8, 8))
    assert back.dss[0].get(0) == 1  # 1x1 mesh: trivial


def test_annot_to_spec_rejects_partial():
    from repro.core.annotations import DS, PARTIAL, spmd
    from repro.sharding.rules import annot_to_spec
    a = spmd([0, 1], DS({PARTIAL: 2}))
    with pytest.raises(ValueError):
        annot_to_spec(a, ("model",))


def test_param_specs_cover_all_archs():
    """Every leaf of every reduced arch gets a valid spec (ndim match)."""
    from jax.sharding import Mesh, PartitionSpec
    from repro.configs import ARCHS, get_config
    from repro.launch.specs import param_structs
    from repro.sharding.rules import param_specs
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    for arch in ARCHS:
        cfg = get_config(arch)
        struct = param_structs(cfg)
        specs = param_specs(struct, cfg, mesh)
        leaves_s = jax.tree.leaves(struct)
        leaves_p = jax.tree.leaves(specs,
                                   is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(leaves_s) == len(leaves_p)
        for s, p in zip(leaves_s, leaves_p):
            assert len(p) <= len(s.shape), (arch, s.shape, p)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_cost_model_monotonic_in_devices():
    from repro.core.costmodel import (LLAMA_32B, ClusterSpec, H20,
                                      best_uniform)
    c32 = ClusterSpec((H20,) * 32)
    c16 = ClusterSpec((H20,) * 16)
    _, t32 = best_uniform(c32, LLAMA_32B, list(range(32)), 64, 4096)
    _, t16 = best_uniform(c16, LLAMA_32B, list(range(16)), 64, 4096)
    assert t32 < t16


def test_cost_model_hetero_beats_uniform():
    from repro.core.costmodel import LLAMA_32B, best_uniform, paper_cluster, step_time
    from repro.scenarios.hetero import hetu_32b_16h800_16h20
    cluster = paper_cluster(16, 16)
    _, t_uni = best_uniform(cluster, LLAMA_32B, list(range(32)), 64, 4096)
    t_het = step_time(cluster, LLAMA_32B, hetu_32b_16h800_16h20(), 4096)
    assert t_het < t_uni


def test_memory_feasibility_check():
    from repro.core.costmodel import (LLAMA_70B, ClusterSpec, H20,
                                      feasible, uniform_strategy)
    cluster = ClusterSpec((H20,) * 8)
    # 70B pure-DP on 8 GPUs cannot fit
    s = uniform_strategy(list(range(8)), LLAMA_70B, dp=8, tp=1, pp=1,
                         global_batch=64)
    assert not feasible(cluster, LLAMA_70B, s)
