"""Property-based tests (hypothesis) for the HSPMD invariants.

The system's core invariant: for ANY pair of valid annotations (src, dst)
over the same global shape, the resolved communication plan — whatever
operator mix it chose — must transform the src decomposition into exactly
the dst decomposition of the same global value.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweep needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd
from repro.core.comm_resolve import UnsupportedCommError, resolve
from repro.core.simulator import roundtrip_check

MAX_DEV = 16
DIMS = 2
SIZE = 24  # divisible by 1,2,3,4,6,8,12 — plenty of shard factorizations


def _factor_pairs(n):
    return [(a, n // a) for a in range(1, n + 1) if n % a == 0]


@st.composite
def ds_strategy(draw, n_devices: int, allow_partial: bool):
    """Random DS over exactly n_devices, factored over dims/dup/partial."""
    kinds = [0, 1, DUP] + ([PARTIAL] if allow_partial else [])
    # random ordered factorization of n_devices
    entries = []
    rem = n_devices
    dims_avail = list(kinds)
    while rem > 1 and dims_avail:
        d = draw(st.sampled_from(dims_avail))
        dims_avail.remove(d)
        divisors = [k for k in range(2, rem + 1)
                    if rem % k == 0 and (d < 0 or SIZE % k == 0)]
        if not divisors:
            continue
        n = draw(st.sampled_from(divisors))
        entries.append((d, n))
        rem //= n
    if rem != 1:
        # couldn't factor: dump remainder into dup
        entries.append((DUP, rem * (dict(entries).get(DUP, 1))))
        entries = [(d, n) for d, n in entries if d != DUP or n > 1]
        m = {}
        for d, n in entries:
            m[d] = m.get(d, 1) * n
        entries = list(m.items())
    return DS(entries)


@st.composite
def annot_strategy(draw, devices: tuple[int, ...], allow_partial: bool,
                   allow_hetero: bool):
    n = len(devices)
    hsize = draw(st.sampled_from([1, 2] if (allow_hetero and n % 2 == 0) else [1]))
    if hsize == 1:
        ds = draw(ds_strategy(n, allow_partial))
        return HSPMD([devices], [ds])
    half = n // 2
    dgs = [devices[:half], devices[half:]]
    dss = [draw(ds_strategy(half, allow_partial)) for _ in range(2)]
    hdim = draw(st.sampled_from([DUP, 0, 1] + ([PARTIAL] if allow_partial else [])))
    return HSPMD(dgs, dss, hdim=hdim)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_resolution_roundtrip_no_partial(data):
    """Any non-Partial src/dst pair must be resolvable and exact."""
    n_src = data.draw(st.sampled_from([1, 2, 3, 4, 6, 8]))
    n_dst = data.draw(st.sampled_from([1, 2, 3, 4, 6, 8]))
    src_devs = tuple(range(n_src))
    # dst devices may overlap src or not
    offset = data.draw(st.sampled_from([0, 2, 8]))
    dst_devs = tuple(range(offset, offset + n_dst))
    src = data.draw(annot_strategy(src_devs, False, True))
    dst = data.draw(annot_strategy(dst_devs, False, True))
    shape = (SIZE, SIZE)
    plan = resolve(src, dst, shape)
    value = np.random.default_rng(0).normal(size=shape)
    roundtrip_check(value, src, dst, plan, rng=np.random.default_rng(1))


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_resolution_roundtrip_partial_src(data):
    """Partial sources resolve whenever the decision tree admits them;
    UnsupportedCommError is acceptable only on the paper's stated limits
    (Partial + cross-union / non-collective patterns)."""
    n = data.draw(st.sampled_from([2, 4, 8]))
    devs = tuple(range(n))
    src = data.draw(annot_strategy(devs, True, True))
    dst = data.draw(annot_strategy(devs, False, True))
    shape = (SIZE, SIZE)
    try:
        plan = resolve(src, dst, shape)
    except UnsupportedCommError:
        assert src.has_partial or dst.has_partial
        return
    value = np.random.default_rng(2).normal(size=shape)
    roundtrip_check(value, src, dst, plan, rng=np.random.default_rng(3))


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_hsplits_nonuniform_roundtrip(data):
    """Non-uniform top-tier splits (mixed-length workloads) stay exact."""
    w1 = data.draw(st.sampled_from([1, 2, 3]))
    w2 = data.draw(st.sampled_from([1, 2, 3]))
    src = HSPMD(dgs=[[0, 1], [2, 3]], dss=[DS({0: 2}), DS({1: 2})],
                hdim=0, hsplits=[w1, w2])
    dst_kind = data.draw(st.sampled_from(["uniform", "flip", "gather"]))
    if dst_kind == "uniform":
        dst = HSPMD(dgs=[[0, 1], [2, 3]], dss=[DS({0: 2}), DS({1: 2})],
                    hdim=0, hsplits=[1, 1])
    elif dst_kind == "flip":
        dst = HSPMD(dgs=[[0, 1], [2, 3]], dss=[DS({0: 2}), DS({1: 2})],
                    hdim=0, hsplits=[w2, w1])
    else:
        dst = spmd([0, 1, 2, 3], DS({0: 4}))
    shape = ((w1 + w2) * 8, 8)
    plan = resolve(src, dst, shape)
    value = np.random.default_rng(4).normal(size=shape)
    roundtrip_check(value, src, dst, plan, rng=np.random.default_rng(5))
