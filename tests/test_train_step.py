"""End-to-end sharded training steps (Session.train_step).

The joint fwd+bwd plan must: be bit-identical across microbatch counts
and schedule kinds (integer leaves), match jax.grad + the jax AdamW on
the single-device graph to float tolerance, expose backward ExecItems /
measured tick durations, survive a restart-free strategy switch with its
optimizer state, and fail loudly on unknown schedule strings.
"""

import numpy as np
import pytest

from repro import api
from repro.api.testing import (loss_pipeline_program, loss_pipeline_values,
                               zigzag_program, zigzag_values)
from repro.core.annotations import DS, DUP, spmd
from repro.optim.adamw import (AdamWConfig, init_sharded_state,
                               sharded_apply_updates)


def _fresh(prog, name, ws, **kw):
    sess = api.Session(prog, name, **kw)
    sess.load(ws)
    return sess


# ---------------------------------------------------------------------------
# bitwise invariance across schedules and microbatch counts
# ---------------------------------------------------------------------------

def test_train_step_bit_identical_across_m_and_kind():
    prog = loss_pipeline_program(4)
    xv, ws, want_y = loss_pipeline_values()
    runs = {}
    for m, kind in [(1, "1f1b"), (2, "1f1b"), (4, "1f1b"), (4, "gpipe"),
                    (2, "interleaved"), (4, "interleaved")]:
        sess = _fresh(prog, "pipe", ws)
        r = sess.train_step({"X": xv}, num_microbatches=m, schedule=kind)
        runs[(m, kind)] = (r, {n: sess.weight_value(n) for n in ws})
    base, base_w = runs[(1, "1f1b")]
    assert base.loss == float(want_y.sum())
    for key, (r, w) in runs.items():
        assert r.loss == base.loss, key
        assert r.metrics == base.metrics, key
        for n in ws:
            np.testing.assert_array_equal(r.grad_value(n),
                                          base.grad_value(n),
                                          err_msg=f"{key} grad {n}")
            np.testing.assert_array_equal(w[n], base_w[n],
                                          err_msg=f"{key} weight {n}")


def test_train_step_interleaved_zigzag_matches_flat_m1():
    prog = zigzag_program(4)
    xv, ws, want_y = zigzag_values(seed=13)
    base = _fresh(prog, "zig", ws).train_step(
        {"X": xv}, num_microbatches=1)
    for m in (2, 4):
        r = _fresh(prog, "zig", ws).train_step(
            {"X": xv}, num_microbatches=m, schedule="interleaved")
        assert r.loss == base.loss
        for n in ws:
            np.testing.assert_array_equal(r.grad_value(n),
                                          base.grad_value(n))


def test_train_step_pipelined_schedule_surfaced():
    prog = loss_pipeline_program(4)
    xv, ws, _ = loss_pipeline_values()
    r = _fresh(prog, "pipe", ws).train_step({"X": xv}, num_microbatches=4)
    assert r.schedule is not None and r.schedule.kind == "1f1b"
    assert r.stats.n_ticks == 2 * 2 * 4   # 2 stages x 4 microbatches


# ---------------------------------------------------------------------------
# numerics: jax.grad + jax AdamW reference on the single-device graph
# ---------------------------------------------------------------------------

def test_train_matches_jax_reference_over_steps():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.optim.adamw import apply_updates, init_opt_state

    g = api.Graph()
    one = [spmd([0], DS({}))]
    g.placeholder("X", (8, 6))
    g.parameter("W1", (6, 5))
    g.parameter("W2", (5, 3))
    h = g.gelu(g.dot(g.tensors["X"], g.tensors["W1"]), name="H")
    y = g.dot(h, g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1), 0, name="L")
    strat = api.Strategy("one", {"X": one[0], "W1": one[0], "W2": one[0]})
    prog = api.Program(g, [strat])

    rng = np.random.default_rng(7)
    xv = rng.normal(size=(8, 6)).astype(np.float32)
    w1 = rng.normal(size=(6, 5)).astype(np.float32)
    w2 = rng.normal(size=(5, 3)).astype(np.float32)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=2, weight_decay=0.1)

    sess = _fresh(prog, "one", {"W1": w1, "W2": w2}, optimizer=cfg)

    def loss_fn(params):
        hh = jax.nn.gelu(xv @ params["W1"], approximate=True)
        return jnp.sum(hh @ params["W2"])

    params = {"W1": jnp.asarray(w1), "W2": jnp.asarray(w2)}
    opt = init_opt_state(params)
    for step in range(3):
        r = sess.train_step({"X": xv})
        (lv, _), grads = jax.value_and_grad(
            lambda p: (loss_fn(p), 0.0), has_aux=True)(params)
        params, opt, om = apply_updates(params, grads, opt, cfg)
        assert np.allclose(r.loss, float(lv), rtol=1e-5, atol=1e-5), step
        assert np.allclose(r.metrics["grad_norm"], float(om["grad_norm"]),
                           rtol=1e-4), step
        assert np.allclose(r.metrics["lr"], float(om["lr"]), rtol=1e-6)
        for n in ("W1", "W2"):
            np.testing.assert_allclose(sess.weight_value(n), params[n],
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"step {step} {n}")


def test_train_step_loss_decreases():
    """The pipelined sharded trainer actually LEARNS a regression task."""
    prog = loss_pipeline_program(4)
    _, ws, _ = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws,
                  optimizer=AdamWConfig(lr=3e-3, warmup_steps=1,
                                        weight_decay=0.0))
    rng = np.random.default_rng(0)
    xv = rng.normal(size=(16, 16)).astype(np.float32)
    losses = [sess.train_step({"X": xv}, num_microbatches=2).loss
              for _ in range(25)]
    # loss L = sum(relu(X@W1)@W2) is unbounded below; AdamW must drive
    # it monotonically-ish down
    assert losses[-1] < losses[0] - 1.0, losses[::6]
    assert all(np.isfinite(losses))


# ---------------------------------------------------------------------------
# optimizer state: sharded AdamW + restart-free switch
# ---------------------------------------------------------------------------

def test_sharded_adamw_state_mirrors_weight_sharding():
    prog = loss_pipeline_program(4)
    xv, ws, _ = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws)
    sess.train_step({"X": xv})
    assert sess.opt_state["count"] == 1
    for n, st in sess.weights.items():
        m_st = sess.opt_state["m"][n]
        assert set(m_st.parts) == set(st.parts)
        for dev, arr in m_st.parts.items():
            assert arr.shape == st.parts[dev].shape
            assert arr.dtype == np.float32


def test_sharded_adamw_rejects_mismatched_grads():
    prog = loss_pipeline_program(4)
    _, ws, _ = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws)
    state = init_sharded_state(sess.weights)
    with pytest.raises(ValueError, match="do not match"):
        sharded_apply_updates(sess.weights, {"W1": sess.weights["W1"]},
                              state, AdamWConfig())


def test_switch_migrates_optimizer_state():
    """Training -> switch -> training continues from EXACTLY the same
    optimizer state (restart-free, paper §6)."""
    shapes = {"W1": (16, 12), "W2": (12, 6)}
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", shapes["W1"])
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"), name="H")
    g.parameter("W2", shapes["W2"])
    y = g.dot(h, g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    s_a = api.Strategy("a", {
        "X": spmd([0, 1], DS({0: 2})), "W1": spmd([0, 1], DS({DUP: 2})),
        "W2": spmd([0, 1], DS({DUP: 2}))})
    s_b = api.Strategy("b", {   # Megatron MLP: col-parallel then row
        "X": spmd([0, 1], DS({DUP: 2})), "W1": spmd([0, 1], DS({1: 2})),
        "W2": spmd([0, 1], DS({0: 2}))})
    prog = api.Program(g, [s_a, s_b])
    rng = np.random.default_rng(3)
    xv = rng.normal(size=(16, 16)).astype(np.float32)
    ws = {n: rng.normal(size=s).astype(np.float32)
          for n, s in shapes.items()}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1, weight_decay=0.0)

    ref = _fresh(prog, "a", ws, optimizer=cfg)
    switched = _fresh(prog, "a", ws, optimizer=cfg)
    for step in range(4):
        r0 = ref.train_step({"X": xv})
        r1 = switched.train_step({"X": xv})
        assert np.allclose(r0.loss, r1.loss, rtol=1e-5), step
        if step == 1:
            switched.switch("b")
            assert {d for st in switched.opt_state["m"].values()
                    for d in st.parts}  # state moved with the weights
    for n in shapes:
        np.testing.assert_allclose(switched.weight_value(n),
                                   ref.weight_value(n), atol=1e-4)
        from repro.core.simulator import gather
        np.testing.assert_allclose(gather(switched.opt_state["m"][n]),
                                   gather(ref.opt_state["m"][n]),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# schedule-kind validation (run AND train_step)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 2])
def test_unknown_schedule_raises_clear_error(m):
    prog = loss_pipeline_program(4)
    xv, ws, _ = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws)
    for call in (sess.run, sess.train_step):
        with pytest.raises(api.ScheduleError) as ei:
            call({"X": xv}, num_microbatches=m, schedule="diagonal")
        msg = str(ei.value)
        assert "'diagonal'" in msg
        for kind in ("1f1b", "gpipe", "interleaved"):
            assert kind in msg, msg


def test_virtual_stages_knob_requires_interleaved():
    prog = loss_pipeline_program(4)
    xv, ws, _ = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws)
    with pytest.raises(api.ScheduleError, match="interleaved"):
        sess.train_step({"X": xv}, num_microbatches=2,
                        virtual_stages_per_device=2)


# ---------------------------------------------------------------------------
# the train plan itself: backward ExecItems + measured tick durations
# ---------------------------------------------------------------------------

def test_train_plan_has_backward_exec_items():
    prog = loss_pipeline_program(4)
    tplan = prog.compile_train("pipe")
    for dev in tplan.devices:
        phases = {i.phase for i in tplan.exec_items(dev)}
        assert phases == {"fwd", "bwd"}, (dev, phases)
    # forward-only plans stay pure fwd
    fplan = prog.compile("pipe")
    assert all(i.phase == "fwd" for d in fplan.devices
               for i in fplan.exec_items(d))
    assert tplan.grad_map and tplan.loss_name == "L"
    assert set(tplan.grad_map) >= {"W1", "W2", "L"}


def test_measured_tick_durations_price_bwd_heavier():
    prog = loss_pipeline_program(4)
    tplan = prog.compile_train("pipe")
    d = tplan.tick_durations()
    for s in range(2):
        assert d[(s, "bwd")] > d[(s, "fwd")] > 0.0
    frac = tplan.fwd_fraction()
    assert 0.2 < frac < 0.5
    # forward-only plans price bwd ticks as zero and fall back to the
    # analytic 1/3 fraction
    fplan = prog.compile("pipe")
    df = fplan.tick_durations()
    assert all(df[(s, "bwd")] == 0.0 for s in range(2))
    assert fplan.fwd_fraction() == pytest.approx(1.0 / 3.0)
    # the measured durations re-time the executable schedule
    sched = tplan.schedule(4)
    priced = sched.stats(d)
    assert priced.makespan > 0.0


def test_interleaved_chunk_pricing_beats_flat():
    """The ROADMAP item: per-chunk tick durations give interleaved its
    real ~1/v bubble advantage in the analytic cost model."""
    from repro.core import costmodel as cm
    cluster = cm.paper_cluster(0, 32)
    strat = cm.uniform_strategy(list(range(32)), cm.LLAMA_32B, dp=1,
                                tp=4, pp=8, global_batch=16)
    p = strat.pipelines[0]
    t_flat = cm.pipeline_time(cluster, cm.LLAMA_32B, p, 4096, "1f1b")
    t_v2 = cm.pipeline_time(cluster, cm.LLAMA_32B, p, 4096,
                            "interleaved", virtual_stages_per_device=2)
    t_v4 = cm.pipeline_time(cluster, cm.LLAMA_32B, p, 4096,
                            "interleaved", virtual_stages_per_device=4)
    assert t_v4 < t_v2 < t_flat
    # v=1 interleaved still degenerates to the 1F1B price
    t_v1 = cm.pipeline_time(cluster, cm.LLAMA_32B, p, 4096, "interleaved")
    assert t_v1 == pytest.approx(t_flat)
    with pytest.raises(ValueError, match="interleaved"):
        cm.pipeline_time(cluster, cm.LLAMA_32B, p, 4096, "1f1b",
                         virtual_stages_per_device=2)


def test_measured_fwd_fraction_feeds_tick_durations():
    from repro.core import costmodel as cm
    prog = loss_pipeline_program(4)
    tplan = prog.compile_train("pipe")
    frac = tplan.fwd_fraction()
    cluster = cm.paper_cluster(0, 16)
    strat = cm.uniform_strategy(list(range(16)), cm.LLAMA_32B, dp=1,
                                tp=4, pp=4, global_batch=8)
    p = strat.pipelines[0]
    d = cm.pipeline_tick_durations(cluster, cm.LLAMA_32B, p, 4096,
                                   fwd_fraction=frac)
    for s in range(4):
        total = d[(s, "fwd")] + d[(s, "bwd")]
        assert d[(s, "fwd")] == pytest.approx(total * frac)


def test_train_step_rejects_unloaded_params():
    prog = loss_pipeline_program(4)
    xv, ws, _ = loss_pipeline_values()
    sess = api.Session(prog, "pipe")
    sess.load({"W1": ws["W1"]})
    with pytest.raises(ValueError, match="W2"):
        sess.train_step({"X": xv})


def test_train_step_extra_fetches():
    prog = loss_pipeline_program(4)
    xv, ws, want_y = loss_pipeline_values()
    sess = _fresh(prog, "pipe", ws)
    tplan = prog.compile_train("pipe")
    r = sess.train_step({"X": xv}, num_microbatches=2,
                        fetches=["Y", tplan.grad_map["H2"]])
    from repro.core.simulator import gather
    np.testing.assert_array_equal(gather(r.outputs["Y"]), want_y)
    assert tplan.grad_map["H2"] in r.outputs
