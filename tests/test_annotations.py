"""Unit tests for HSPMD annotation algebra (paper §3, Figs 2-3)."""

import numpy as np
import pytest

from repro.core.annotations import DG, DS, DUP, HSPMD, PARTIAL, replicated, spmd


def test_ds_canonical_form():
    ds = DS({0: 2, 1: 1, DUP: 4})
    assert ds.get(0) == 2
    assert ds.get(1) == 1  # trivial entries dropped
    assert ds.get(DUP) == 4
    assert ds.num_devices == 8


def test_ds_coords_row_major():
    ds = DS([(0, 2), (DUP, 2)])  # dim0 slow, dup fast
    assert ds.coords(0) == {0: 0, DUP: 0}
    assert ds.coords(1) == {0: 0, DUP: 1}
    assert ds.coords(2) == {0: 1, DUP: 0}
    assert ds.coords(3) == {0: 1, DUP: 1}


def test_ds_local_box():
    ds = DS([(0, 2), (1, 2)])
    assert ds.local_box(0, (8, 4)) == ((0, 4), (0, 2))
    assert ds.local_box(3, (8, 4)) == ((4, 8), (2, 4))


def test_ds_positions_varying_groups():
    ds = DS([(0, 2), (PARTIAL, 2)])
    groups = ds.positions_varying(PARTIAL)
    assert sorted(map(tuple, groups)) == [(0, 1), (2, 3)]


def test_dg_validation():
    with pytest.raises(ValueError):
        DG([0, 0, 1])


def test_hspmd_basic_figure2_left():
    # paper Fig 2 left: X split dim0 over {0,1}x{2,3} dup, W split dim1
    x = spmd([0, 1, 2, 3], DS([(0, 2), (DUP, 2)]))
    w = spmd([0, 1, 2, 3], DS([(DUP, 2), (1, 2)]))
    assert x.hsize == 1 and x.hdim == DUP
    assert x.device_box(3, (8, 16)) == ((4, 8), (0, 16))
    assert w.device_box(1, (16, 32)) == ((0, 16), (16, 32))


def test_hspmd_union_figure3():
    # two subgroups with different internal sharding, hdim=0 split
    a = HSPMD(
        dgs=[[0, 3], [5, 6], [2, 4], [1]],
        dss=[DS({1: 2}), DS({1: 2}), DS({0: 2}), DS({})],
        hdim=0,
    )
    assert a.hsize == 4
    shape = (8, 4)
    # subgroup slabs: rows 0-2, 2-4, 4-6, 6-8
    assert a.device_box(0, shape) == ((0, 2), (0, 2))
    assert a.device_box(3, shape) == ((0, 2), (2, 4))
    assert a.device_box(5, shape) == ((2, 4), (0, 2))
    assert a.device_box(2, shape) == ((4, 5), (0, 4))
    assert a.device_box(4, shape) == ((5, 6), (0, 4))
    assert a.device_box(1, shape) == ((6, 8), (0, 4))


def test_hspmd_nonuniform_hsplits():
    a = HSPMD(dgs=[[0, 1], [2]], dss=[DS({0: 2}), DS({})], hdim=0,
              hsplits=[3, 1])
    shape = (16, 4)
    assert a.device_box(0, shape) == ((0, 6), (0, 4))
    assert a.device_box(1, shape) == ((6, 12), (0, 4))
    assert a.device_box(2, shape) == ((12, 16), (0, 4))


def test_hspmd_disjoint_subgroups_enforced():
    with pytest.raises(ValueError):
        HSPMD(dgs=[[0, 1], [1, 2]], dss=[DS({0: 2}), DS({0: 2})], hdim=0)


def test_partial_degree():
    a = HSPMD(dgs=[[0, 1], [2, 3]],
              dss=[DS({PARTIAL: 2}), DS({PARTIAL: 2})], hdim=PARTIAL)
    assert a.partial_degree(0) == 4
    b = replicated([0, 1])
    assert b.partial_degree(0) == 1


def test_single_group_hdim_canonicalized():
    a = HSPMD(dgs=[[0, 1]], dss=[DS({0: 2})], hdim=0)
    assert a.hdim == DUP
