"""Execution-backend tests: simulator <-> shard_map differential equivalence.

The heavy sweep runs ONCE in a subprocess with 8 forced host CPU devices
(``repro.runtime.selftest``, keeping this process at its default device
count per the dry-run spec); the parametrized tests then assert each
case's bit-exact verdict from the machine-readable report.  Cheap
single-device and pure-planning paths run in-process.
"""

import json

import numpy as np
import pytest

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd

KINDS = ["ID", "SR", "AR", "RS", "AG", "SplitAR", "SplitRS", "SplitAG",
         "BSR", "Slice"]
NDEVS = [2, 4, 8]


@pytest.fixture(scope="module")
def report():
    from repro.runtime.harness import run_subprocess
    proc = run_subprocess("repro.runtime.selftest", n_devices=8)
    for line in proc.stdout.splitlines():
        if line.startswith("RUNTIME_SELFTEST_JSON "):
            return json.loads(line[len("RUNTIME_SELFTEST_JSON "):])
    pytest.fail(f"selftest produced no report (rc={proc.returncode}):\n"
                f"{proc.stdout}\n{proc.stderr}")


def _case(report, key):
    case = report["cases"].get(key)
    assert case is not None, f"selftest never ran case {key}"
    assert case["ok"], f"{key}: {case.get('error')}\n{case.get('trace', '')}"
    return case


@pytest.mark.parametrize("ndev", NDEVS)
@pytest.mark.parametrize("kind", KINDS)
def test_commstep_kind_matches_simulator(report, kind, ndev):
    """Every CommStep kind executes under shard_map on real devices and is
    bit-exact against simulator.apply_plan."""
    case = _case(report, f"{kind}/{ndev}")
    assert kind in case["step_kinds"], case


@pytest.mark.parametrize("kind", ["AR", "RS", "SplitAR", "SplitRS"])
def test_fast_psum_reduction_path(report, kind):
    """The native-dtype psum path is exact for order-insensitive shards."""
    _case(report, f"fast:{kind}/8")


def test_heterogeneous_hsplits_bsr(report):
    assert _case(report, "hetero:hsplits/4")["plan_kind"] == "fallback:BSR"


def test_fig9_multistep_stage(report):
    """The paper's Fig 9 CommOp id=2 (RS on {0,3}, BSR toward {5,6}, ID on
    {1}) runs as ONE stage of parallel steps on real devices."""
    case = _case(report, "hetero:fig9/7")
    assert case["plan_kind"] == "bottom:BSR+ID+RS"
    assert set(case["step_kinds"]) == {"RS", "BSR"}


@pytest.mark.parametrize("ndev", NDEVS)
def test_resharding_roundtrip(report, ndev):
    """src -> dst -> src on real devices restores every shard exactly."""
    _case(report, f"roundtrip:split/{ndev}")


def test_resharding_roundtrip_hetero(report):
    _case(report, "roundtrip:hetero/4")


def test_switch_migration_jax_backend(report):
    """execute_switch(backend="jax") migrates weights through the fused-BSR
    path on real devices: exact dst shards, bit-equal to the simulator
    backend, and reversible."""
    _case(report, "switch:jax/8")


@pytest.mark.parametrize("ndev", NDEVS)
def test_api_session_executor_parity(report, ndev):
    """repro.api acceptance: Session.run on JaxExecutor executes a
    specialized pipeline stage's compute + comm ExecItems end-to-end under
    shard_map, bit-exact against SimulatorExecutor."""
    case = _case(report, f"api:session/{ndev}")
    assert case["devices"] == ndev


@pytest.mark.parametrize("ndev", NDEVS)
def test_api_pipeline_schedule_parity(report, ndev):
    """Microbatched pipeline acceptance: Session.run(num_microbatches=m)
    is bit-exact sim vs jax (one scanned shard_map program) per
    microbatch, bit-identical across m in {1,2,4} for the accumulated
    loss, GPipe == 1F1B bitwise, timetable matching the analytic
    (m + s - 1) fill/drain count."""
    case = _case(report, f"api:pipeline/{ndev}")
    assert case["n_stages"] == 2
    assert case["slots"] == 2 * (4 + case["n_stages"] - 1)


@pytest.mark.parametrize("ndev", NDEVS)
def test_api_interleaved_schedule_parity(report, ndev):
    """Interleaved virtual-stage acceptance: a v=2 zigzag plan
    (s0 -> s1 -> s0 -> s1) runs Megatron's interleaved timetable on the
    simulator and ONE scanned shard_map program on jax — bit-exact per
    microbatch shard, bit-identical outputs across m in {1,2,4}, flat
    1F1B/GPipe rejected, and the lowered jax program deduces the same
    S*v=4 virtual-stage structure."""
    case = _case(report, f"api:pipeline/interleaved{ndev}")
    assert case["v"] == 2
    assert 0.0 <= case["bubble_fraction"] < 1.0


@pytest.mark.parametrize("ndev", NDEVS)
def test_api_train_step_bit_exact(report, ndev):
    """End-to-end TRAINING regression on the specialization-class
    lowering: losses, gradient shards and updated weight shards
    bit-exact sim vs jax and bit-identical across m x {1f1b, gpipe}
    (integer leaves) — the segment/class emission on the jax side and
    the class-vectorized numpy dispatch on the sim side must agree to
    the last bit."""
    case = _case(report, f"api:train/{ndev}")
    assert np.isfinite(case["loss"])


@pytest.mark.parametrize("ndev", NDEVS)
def test_api_train_interleaved_bit_exact(report, ndev):
    """Interleaved (v=2 zigzag) training: bit-exact sim vs jax and
    across m in {1,2,4} on the refactored path — covers segments whose
    participant classes alternate between the two device halves."""
    _case(report, f"api:train/interleaved{ndev}")


def test_api_train_hetero_bit_exact(report):
    """hsize=2 training (two specialization classes per segment): the
    two-tier grad reduction still resolves and executes bit-exact."""
    case = _case(report, "api:train/hetero4")
    assert "SplitAR" in case["grad_comms"]["W1"]


@pytest.mark.parametrize("ndev", NDEVS)
def test_async_pipeline_bit_exact(report, ndev):
    """Async MPMD executor acceptance: per-(virtual stage, phase) XLA
    programs with double-buffered P2P channels and eager grad-reduce
    stay BITWISE equal to the simulator and the scanned jax program
    across m in {1,2,4} x {1f1b, gpipe, interleaved} — one fwd + one
    bwd program per virtual stage, comm hoisted into channels."""
    case = _case(report, f"async:pipeline/{ndev}")
    assert case["programs"] == 4            # 2 virtual stages x 2 phases
    assert case["channels"] >= 2            # boundary P2P both phases


def test_async_train_bit_exact(report):
    """Async TRAINING: losses, gradient shards and updated weight
    shards bit-exact vs sim and jax across m x {1f1b, gpipe}, plus the
    v=2 interleaved zigzag (per-chunk programs on one device)."""
    case = _case(report, "async:train/4")
    assert np.isfinite(case["loss"])
    assert np.isfinite(case["zigzag_loss"])


def test_search_validation_bit_exact_and_concordant(report):
    """The automated strategy search's execution validation: the top-3
    candidates for the 2-fast + 2-slow CPU fixture train bit-exact sim
    vs jax, the winner is a heterogeneous (hsize>1) candidate, and the
    speed-projected measured ordering agrees with the cost model's."""
    case = _case(report, "search:hetero/4")
    assert case["winner"].startswith("het"), case
    assert case["agreement"] >= 2 / 3, case


@pytest.mark.parametrize("key, want_kinds", [
    ("elastic:trace/4to2", ["shrink", "class-change"]),
    ("elastic:trace/2to4", ["grow", "class-change"]),
    ("elastic:trace/hetero", ["class-change", "shrink"]),
])
def test_elastic_trace_bit_exact(report, key, want_kinds):
    """The elastic trace driver: real train_steps through device
    loss/join, weights + AdamW m/v migrated restart-free — the whole
    trajectory bitwise equal sim vs jax AND to an uninterrupted
    single-strategy reference run."""
    case = _case(report, key)
    assert case["kinds"] == want_kinds, case


def test_grouped_reduce_collectives(report):
    """Reduce groups lower onto axis_index_groups subgroup collectives
    (SplitAR's cross-subgroup groups), bit-exact vs the simulator."""
    case = _case(report, "grouped:reduce/4")
    assert case["grouped"] == case["reduce_groups"] > 0


def test_ppermute_fusion_reduces_launches(report):
    """Per-(src,dst) ppermute pairs are fused into batched permutes: the
    AG/8 multicast lowers to strictly fewer collective launches than
    point-to-point pairs, same bits (the kind sweep re-proves exactness)."""
    case = _case(report, "fusion:stats/8")
    assert case["ppermute_calls"] < case["copy_pairs"], case


# ---------------------------------------------------------------------------
# in-process paths (single device / pure planning)
# ---------------------------------------------------------------------------

def test_execute_plan_single_device_identity():
    from repro.core.comm_resolve import resolve
    from repro.launch.mesh import make_runtime_mesh
    from repro.runtime import execute_plan

    a = spmd([0], DS({}))
    value = np.arange(12.0, dtype=np.float32).reshape(3, 4)
    plan = resolve(a, a, value.shape)
    out = execute_plan(plan, {0: value}, value.shape, make_runtime_mesh(1))
    np.testing.assert_array_equal(out[0], value)


def test_execute_plan_rejects_bad_shard_shape():
    from repro.core.comm_resolve import resolve
    from repro.launch.mesh import make_runtime_mesh
    from repro.runtime import execute_plan

    a = spmd([0], DS({}))
    plan = resolve(a, a, (3, 4))
    with pytest.raises(ValueError, match="shard shape"):
        execute_plan(plan, {0: np.zeros((4, 4), np.float32)}, (3, 4),
                     make_runtime_mesh(1))


def test_device_items_matches_specialize():
    """The runtime's per-device view of a plan lists exactly the comm
    ExecItems progressive specialization gives that device (Fig 9)."""
    from repro.core.graph import Graph
    from repro.core.specialize import resolve_comm_ops, specialize
    from repro.runtime import device_items

    g = Graph()
    x_annot = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                    dss=[DS({2: 2}), DS({0: 2}), DS({})], hdim=0)
    w_dup = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                  dss=[DS({DUP: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    w_tp = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                 dss=[DS({0: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    x = g.placeholder("X", (12, 16, 32), [x_annot])
    w = g.parameter("W", (32, 64), [w_dup])
    w2 = g.comm(w, w_tp)
    y = g.dot(g.gelu(x), w2, name="Y")
    y_next = HSPMD(dgs=[[0, 3], [5, 6], [1]],
                   dss=[DS({0: 2}), DS({1: 2}), DS({})], hdim=0)
    g.comm(y, y_next, name="Y2")
    g.deduce()

    plan = resolve_comm_ops(g)[1].plan
    for dev in range(7):
        mine = [i.kind for i in device_items(plan, dev, "comm2")]
        via_specialize = [i.kind for i in specialize(g, dev).items
                          if i.role == "comm" and i.name == "comm2"]
        assert mine == via_specialize, (dev, mine, via_specialize)


def test_build_switch_step_sim_backend():
    """train.steps.build_switch_step wires the dynamic-switch migration
    (simulator backend runs in-process; the jax backend is covered by the
    subprocess selftest)."""
    from repro.core.graph import Graph
    from repro.core.simulator import gather, scatter
    from repro.train.steps import build_switch_step

    g = Graph()
    g.parameter("W", (16, 8), [spmd([0, 1], DS({0: 2})),
                               spmd([2, 3], DS({1: 2}))])
    g.deduce()
    rng = np.random.default_rng(0)
    value = rng.normal(size=(16, 8)).astype(np.float32)
    weights = {"W": scatter(value, g.tensors["W"].annots[0])}
    step = build_switch_step(g, 0, 1)
    out = step(weights)
    np.testing.assert_allclose(gather(out["W"]), value, atol=1e-6)


def test_fusion_round_schedule_is_valid_and_complete():
    """Static check of the batched-permute schedule: every point-to-point
    delivery lands in exactly one round, and no round reuses a source or
    a destination (ppermute's partial-permutation contract)."""
    from repro.core.comm_resolve import resolve
    from repro.runtime.lowering import DeviceOrder, PlanLowering

    src = spmd([0, 1, 2, 3], DS({0: 4}))
    dst = spmd([0, 1, 2, 3], DS({DUP: 4}))  # AG: all-to-all multicast
    plan = resolve(src, dst, (16, 8))
    lowering = PlanLowering(plan, (16, 8), DeviceOrder.for_plan(plan),
                            "dev", 4)
    pairs = set()
    for rounds in lowering._stage_rounds:
        for r in rounds:
            srcs = [s for s, _, _ in r.pairs]
            dsts = [d for _, d, _ in r.pairs]
            assert len(set(srcs)) == len(srcs), srcs
            assert len(set(dsts)) == len(dsts), dsts
            for s, d, g in r.pairs:
                assert (s, d, id(g)) not in pairs
                pairs.add((s, d, id(g)))
    assert len(pairs) == 12  # 4 x 3 multicast
    assert sum(len(r) for r in lowering._stage_rounds) == 3  # in-degree
    # the full-mesh AG itself lowers on the uniform gather path, so the
    # stats report ZERO emitted pairs/permutes — the fused schedule is
    # the fallback (see selftest fusion:stats for the narrow-plan case)
    assert lowering.stats.uniform_copy_stages == 1
    assert lowering.stats.copy_pairs == lowering.stats.ppermute_calls == 0


def test_scatter_integer_decompose_partials_sum_exactly():
    """The differential layer's integer decomposition: partial summands
    are integers and reassemble without rounding."""
    from repro.core.simulator import gather, scatter
    from repro.runtime import integer_decompose

    value = np.arange(32.0, dtype=np.float32).reshape(8, 4)
    annot = spmd([0, 1, 2, 3], DS({PARTIAL: 4}))
    st = scatter(value, annot, decompose=integer_decompose)
    for arr in st.parts.values():
        np.testing.assert_array_equal(arr, np.round(arr))
    np.testing.assert_array_equal(gather(st), value)
