"""End-to-end trainability: losses must decrease on a learnable task, and
graph switching mid-training must not perturb the trajectory."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step


def _learnable_batch(rng, cfg, B=8, S=32):
    """A memorizable pattern: next token = (token + 1) % 64."""
    start = rng.integers(0, 64, (B, 1))
    tokens = (start + np.arange(S)[None]) % 64
    return {"tokens": jnp.asarray(tokens, jnp.int32),
            "labels": jnp.asarray((tokens + 1) % 64, jnp.int32)}


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m"])
def test_loss_decreases(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=5, weight_decay=0.0)))
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, _learnable_batch(rng, cfg))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
    assert all(np.isfinite(losses))


def test_switch_mid_training_is_transparent():
    """Training with a simulated strategy switch (reshard + reshard back)
    produces the exact same loss trajectory as training without."""
    from repro.core.annotations import DS, spmd
    from repro.core.bsr import plan_fused_bsr
    from repro.core.plan import CommPlan
    from repro.core.simulator import apply_plan, gather, scatter

    cfg = get_config("qwen2-1.5b").reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3)))
    rng1 = np.random.default_rng(7)
    rng2 = np.random.default_rng(7)

    # run A: uninterrupted
    pa, oa = params, opt
    la = []
    for _ in range(8):
        pa, oa, m = step(pa, oa, _learnable_batch(rng1, cfg))
        la.append(float(m["loss"]))

    # run B: at step 4, round-trip every 2D weight through a strategy
    # switch (shard -> migrate to other devices -> gather back)
    pb, ob = params, opt
    lb = []
    for i in range(8):
        if i == 4:
            flat = {}
            def walk(t, path=""):
                if isinstance(t, dict):
                    for k, v in t.items():
                        walk(v, f"{path}{k}/")
                elif hasattr(t, "ndim") and t.ndim == 2 \
                        and t.shape[0] % 4 == 0:
                    flat[path[:-1]] = t
            walk(pb)
            src = {k: spmd([0, 1, 2, 3], DS({0: 4})) for k in flat}
            dst = {k: spmd([4, 5], DS({1: 2})) for k, v in flat.items()
                   if v.shape[1] % 2 == 0}
            tensors = [(k, src[k], dst[k], tuple(flat[k].shape), 2)
                       for k in dst]
            plan = plan_fused_bsr(tensors)
            by_t = {}
            for a_ in plan.assignments:
                by_t.setdefault(a_.tensor, []).append(a_)
            for k in dst:
                st = scatter(np.asarray(flat[k], np.float64), src[k])
                from repro.core.bsr import BsrPlan
                cp = CommPlan(src=src[k], dst=dst[k], kind="sw")
                cp.add(BsrPlan(by_t.get(k, []), fused=True).to_step(),
                       dst[k])
                out = apply_plan(st, cp)
                # weights reconstructed exactly -> write back
                rec = gather(out).astype(np.float32)
                np.testing.assert_allclose(rec, np.asarray(flat[k]),
                                           atol=1e-6)
        pb, ob, m = step(pb, ob, _learnable_batch(rng2, cfg))
        lb.append(float(m["loss"]))

    np.testing.assert_allclose(la, lb, rtol=1e-6)
