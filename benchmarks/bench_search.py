"""Automated strategy search: enumeration throughput, search wall time,
and predicted-vs-measured winner step time per CPU fixture.

The search subsystem's cost (`repro.search`): how fast the candidate
grid enumerates, how long a full enumerate -> prune -> rank search
takes, and — with execution validation — how the winner's cost-model
prediction compares against its re-priced executed makespan (plus the
top-3 ordering agreement).  Emits ``BENCH_search.json``::

    PYTHONPATH=src python -m benchmarks.bench_search [--smoke]

``--smoke`` (what CI runs) keeps the homogeneous fixture and fewer
measurement rounds — a liveness check for the whole search -> validate
path, not a measurement.
"""

from __future__ import annotations

import argparse
import json
import time


def _configs(smoke: bool):
    from repro.search import cpu_cluster, cpu_hetero_cluster

    out = [("homog4", cpu_cluster(4),
            dict(tp_options=(1, 2), pp_options=(1, 2, 4),
                 virtual_options=(1, 2), include_hetero=False))]
    if not smoke:
        out.append(("hetero2x2", cpu_hetero_cluster(2, 2),
                    dict(tp_options=(1, 2), pp_options=(1, 2),
                         pipeline_options=(1, 2),
                         virtual_options=(1,))))
    return out


def bench(smoke: bool = False) -> dict:
    from repro.search import Searcher, tiny_spec

    repeats = 2 if smoke else 5
    out: dict = {"smoke": smoke, "cases": {}}
    for label, cluster, grid in _configs(smoke):
        searcher = Searcher(tiny_spec(), global_batch=8, seq_len=128,
                            **grid)
        t0 = time.perf_counter()
        cands = searcher.candidates(cluster)
        t_enum = time.perf_counter() - t0
        t0 = time.perf_counter()
        result = searcher.search(cluster)
        t_search = time.perf_counter() - t0
        t0 = time.perf_counter()
        validated = searcher.search(cluster, validate_top=3,
                                    repeats=repeats, batch=64, d=64,
                                    f=128)
        t_validate = time.perf_counter() - t0
        val = validated.validation
        best = next(e for e in val.executed
                    if e.name == validated.best.name)
        measured = best.projected_makespan_s or best.measured_makespan_s
        out["cases"][label] = {
            "n_candidates": len(cands),
            "enumerate_seconds": t_enum,
            "candidates_per_second": len(cands) / t_enum,
            "search_seconds": t_search,
            "n_survivors": len(result.ranked),
            "validate_seconds": t_validate,
            "winner": validated.best.name,
            "winner_predicted_s": validated.best.predicted_step_s,
            "winner_measured_s": measured,
            "agreement": val.agreement(),
            "speed_projected": val.speed_projected,
        }
    return out


def rows(report: dict | None = None):
    report = report or bench()
    out = []
    for label, case in sorted(report["cases"].items()):
        out.append((f"search/{label}/enumerate",
                    case["enumerate_seconds"],
                    f"candidates_per_s={case['candidates_per_second']:.0f} "
                    f"n={case['n_candidates']}"))
        out.append((f"search/{label}/search", case["search_seconds"],
                    f"survivors={case['n_survivors']}"))
        out.append((f"search/{label}/validate",
                    case["validate_seconds"],
                    f"winner={case['winner']} "
                    f"predicted={case['winner_predicted_s']:.3f}s "
                    f"measured={case['winner_measured_s'] * 1e3:.3f}ms "
                    f"agreement={case['agreement']:.2f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="homogeneous fixture only, fewer rounds (CI)")
    args = ap.parse_args()
    report = bench(smoke=args.smoke)
    for name, seconds, derived in rows(report):
        print(f"{name},{seconds * 1e6:.0f},{derived}")
    with open("BENCH_search.json", "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print("wrote BENCH_search.json")


if __name__ == "__main__":
    main()
