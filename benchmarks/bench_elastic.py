"""Fig 14 reproduction: elastic training traces.

Hetu (two fault-isolated pipelines + fused-BSR reconfiguration, no
restart) vs the checkpoint-and-restart uniform baseline, on both the
homogeneous (32 H20) and heterogeneous (16 H800 + 32 H20) traces."""

from __future__ import annotations

from repro.core.costmodel import ClusterSpec, H20, LLAMA_32B, paper_cluster
from repro.scenarios.elastic import (TRACE_HETERO, TRACE_HOMOG,
                                     checkpoint_restart_baseline, run_trace)


def rows():
    out = []
    homog = ClusterSpec((H20,) * 32)
    hetero = paper_cluster(16, 32)
    for label, trace, cluster in (("homog", TRACE_HOMOG, homog),
                                  ("hetero", TRACE_HETERO, hetero)):
        hetu = run_trace(trace, cluster)
        base = checkpoint_restart_baseline(trace, cluster)
        for h, b in zip(hetu, base):
            out.append((f"fig14/{label}/{h.name}/hetu_step", h.step_time_s,
                        f"reconfig={h.reconfigure_s:.2f}s"))
            out.append((f"fig14/{label}/{h.name}/baseline_step",
                        b.step_time_s,
                        f"restart={b.reconfigure_s:.0f}s"))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
