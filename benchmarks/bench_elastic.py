"""Fig 14 reproduction + the live elastic-driver recovery benchmark.

Two halves, matching ``repro.elastic``:

* the ANALYTIC half (``rows()``, consumed by ``benchmarks.run``):
  Hetu (two fault-isolated pipelines + fused-BSR reconfiguration, no
  restart) vs the checkpoint-and-restart uniform baseline on the
  homogeneous (32 H20) and heterogeneous (16 H800 + 32 H20) cost-model
  traces.
* the LIVE half (``bench()``): a real :class:`repro.elastic.
  ElasticDriver` run over a shrink / grow / class-change trace with
  durable checkpoints.  Per transition it measures what the elastic
  path actually paid (strategy re-selection + fused-BSR migration wall
  seconds, zero lost steps) against what a checkpoint-restart baseline
  would pay at the same point: a MEASURED ``store.restore`` of the
  checkpoint it would reload, a MEASURED cold-session first-step
  (recompile) overhead, plus the steps since that checkpoint replayed
  at the median measured step wall.  The headline is
  ``recovered_seconds`` — baseline minus elastic, summed over
  transitions.

::

    PYTHONPATH=src python -m benchmarks.bench_elastic [--smoke]

``--smoke`` (what CI runs) asserts the driver beats the restart
baseline on recovered seconds and leaves ``BENCH_elastic.json``
untouched; the default run rewrites the JSON.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import statistics
import tempfile
import time

from repro.core.costmodel import ClusterSpec, H20, paper_cluster
from repro.scenarios.elastic import (TRACE_HETERO, TRACE_HOMOG,
                                     checkpoint_restart_baseline, run_trace)

# the live trace: shrink at 3, grow at 6, class-change at 8
LIVE_TRACE = [(0, (0, 1, 2, 3), "dp"), (3, (0, 1), "dp"),
              (6, (0, 1, 2, 3), "dp"), (8, (0, 1, 2, 3), "pp")]
LIVE_STEPS = 10
CHECKPOINT_EVERY = 2


def rows():
    out = []
    homog = ClusterSpec((H20,) * 32)
    hetero = paper_cluster(16, 32)
    for label, trace, cluster in (("homog", TRACE_HOMOG, homog),
                                  ("hetero", TRACE_HETERO, hetero)):
        hetu = run_trace(trace, cluster)
        base = checkpoint_restart_baseline(trace, cluster)
        for h, b in zip(hetu, base):
            out.append((f"fig14/{label}/{h.name}/hetu_step", h.step_time_s,
                        f"reconfig={h.reconfigure_s:.2f}s"))
            out.append((f"fig14/{label}/{h.name}/baseline_step",
                        b.step_time_s,
                        f"restart={b.reconfigure_s:.0f}s"))
    return out


def _measure_cold_start() -> tuple[float, float]:
    """(restore_s, compile_s): what a restart pays before its first
    useful step — reload the checkpoint and recompile the train step.
    Both measured, not modeled."""
    from repro.checkpoint import store
    from repro.elastic.fixtures import (probe_feeds, probe_graph,
                                        probe_layout, probe_values,
                                        reference_run)

    tmp = tempfile.mkdtemp(prefix="bench-elastic-ck-")
    try:
        sess, _ = reference_run(probe_layout([0, 1], "dp"), 1)
        from repro.core.simulator import gather
        tree = {"weights": {n: gather(st)
                            for n, st in sess.weights.items()}}
        store.save(os.path.join(tmp, "ck"), tree, step=1)
        t0 = time.perf_counter()
        store.restore(os.path.join(tmp, "ck"), tree)
        restore_s = time.perf_counter() - t0

        # cold first step (program build + plan compile) vs warm step
        t0 = time.perf_counter()
        sess2, _ = reference_run(probe_layout([0, 1], "dp"), 1)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        sess2.train_step(probe_feeds(1))
        warm = time.perf_counter() - t0
        return restore_s, max(cold - warm, 0.0)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench(smoke: bool = False) -> dict:
    from repro.elastic import ElasticDriver
    from repro.elastic.fixtures import (probe_feeds, probe_graph,
                                        probe_provider, probe_values)

    ckdir = tempfile.mkdtemp(prefix="bench-elastic-run-")
    try:
        driver = ElasticDriver(probe_graph(), probe_values(),
                               probe_provider(), probe_feeds,
                               num_microbatches=2,
                               checkpoint_every=CHECKPOINT_EVERY,
                               ckpt_dir=ckdir)
        run = driver.run(LIVE_TRACE, LIVE_STEPS)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    step_s = statistics.median(s.wall_seconds for s in run.steps)
    restore_s, compile_s = _measure_cold_start()

    transitions = []
    recovered = 0.0
    for t in run.transitions:
        elastic_s = t.select_seconds + t.report.wall_seconds
        # the baseline restarts from the newest checkpoint <= t.step and
        # replays everything since it at the measured step wall
        ck_step = (t.step // CHECKPOINT_EVERY) * CHECKPOINT_EVERY
        lost = t.step - ck_step
        baseline_s = restore_s + compile_s + lost * step_s
        recovered += baseline_s - elastic_s
        transitions.append({
            "step": t.step, "kind": t.kind,
            "src": t.report.src_name, "dst": t.report.dst_name,
            "elastic_s": elastic_s, "baseline_s": baseline_s,
            "lost_steps_replayed": lost,
            "bsr_messages": t.report.message_count,
        })

    report = {
        "smoke": smoke,
        "trace": [[s, list(r), lay] for s, r, lay in LIVE_TRACE],
        "n_steps": LIVE_STEPS,
        "checkpoint_every": CHECKPOINT_EVERY,
        "median_step_s": step_s,
        "restore_s": restore_s,
        "compile_s": compile_s,
        "transitions": transitions,
        "recovered_seconds": recovered,
        "transition_kinds": run.transition_kinds(),
        "fig14": [{"name": n, "seconds": s, "derived": d}
                  for n, s, d in rows()],
    }
    assert report["recovered_seconds"] > 0, (
        "elastic reconfiguration must beat checkpoint-restart on "
        f"recovered seconds, got {report['recovered_seconds']:.4f}s")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="liveness check only; do not rewrite the JSON")
    args = ap.parse_args()
    report = bench(smoke=args.smoke)
    for t in report["transitions"]:
        print(f"step {t['step']:2d} {t['kind']:<12s} "
              f"elastic={t['elastic_s'] * 1e3:7.2f}ms  "
              f"baseline={t['baseline_s'] * 1e3:7.2f}ms  "
              f"(replays {t['lost_steps_replayed']} steps)")
    print(f"recovered_seconds={report['recovered_seconds']:.4f}")
    if args.smoke:
        print("smoke ok (BENCH_elastic.json left untouched)")
        return
    with open("BENCH_elastic.json", "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print("wrote BENCH_elastic.json")


if __name__ == "__main__":
    main()
