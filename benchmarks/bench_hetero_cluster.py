"""Fig 13 reproduction: per-step training time across cluster configs.

Systems: best-uniform (DeepSpeed/Megatron-style tuner) vs Hetu HSPMD
heterogeneous strategies (paper Appendix A.2 Table 5), on the calibrated
H800/H20 cost model.  Homogeneous clusters are included to show parity
(paper: "On homogeneous clusters, all four systems exhibit comparable
performance").
"""

from __future__ import annotations

from repro.core.costmodel import (LLAMA_32B, LLAMA_70B, best_uniform,
                                  paper_cluster, step_time, ClusterSpec,
                                  H800, H20)
from repro.scenarios.hetero import HETU_STRATEGIES


def rows():
    out = []
    # homogeneous parity cases
    for name, dev, n in (("32B_16xH800", H800, 16), ("32B_16xH20", H20, 16)):
        cluster = ClusterSpec((dev,) * n)
        _, t = best_uniform(cluster, LLAMA_32B, list(range(n)), 64, 4096)
        out.append((f"fig13/{name}/uniform", t, "parity"))
        out.append((f"fig13/{name}/hetu", t, "parity (hetero==uniform here)"))
    # heterogeneous cases
    for model, n800, n20 in ((LLAMA_32B, 16, 16), (LLAMA_32B, 16, 32),
                             (LLAMA_70B, 16, 16)):
        cluster = paper_cluster(n800, n20)
        _, t_uni = best_uniform(cluster, model,
                                list(range(n800 + n20)), 64, 4096)
        strat = HETU_STRATEGIES[(model.name, n800, n20)]()
        t_het = step_time(cluster, model, strat, 4096)
        tag = f"{model.name}_16H800_{n20}H20"
        out.append((f"fig13/{tag}/uniform", t_uni, ""))
        out.append((f"fig13/{tag}/hetu", t_het,
                    f"speedup={t_uni / t_het:.2f}x"))
        # automated hetero strategy search (the paper's cost-model tuner)
        from repro.scenarios.search import search_hetero_strategy
        try:
            _, t_srch = search_hetero_strategy(
                cluster, model, list(range(n800 + n20)), 64, 4096)
            out.append((f"fig13/{tag}/hetu_searched", t_srch,
                        f"speedup={t_uni / t_srch:.2f}x"))
        except RuntimeError:
            pass
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
