"""Training-step throughput: Session.train_step on both executors.

Seeds the perf trajectory for the graph-IR trainer: steps/s of the
2-stage loss pipeline (fwd -> bwd -> grad-reduce -> AdamW) on the
numpy simulator and — when enough host devices are forced — the jax
shard_map backend, swept over microbatch counts and schedule kinds.
Emits ``BENCH_train_step.json`` next to the repo root::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_train_step
"""

from __future__ import annotations

import json
import time


def _steps_per_second(sess, feeds, m, kind, warmup=1, iters=3) -> float:
    for _ in range(warmup):
        sess.train_step(feeds, num_microbatches=m, schedule=kind)
    t0 = time.perf_counter()
    for _ in range(iters):
        sess.train_step(feeds, num_microbatches=m, schedule=kind)
    return iters / (time.perf_counter() - t0)


def bench(n_devices: int = 4) -> dict:
    import jax

    from repro import api
    from repro.api.testing import loss_pipeline_program, loss_pipeline_values

    prog = loss_pipeline_program(n_devices, name="pipe")
    xv, ws, _ = loss_pipeline_values()
    executors = {"sim": api.SimulatorExecutor()}
    if len(jax.devices()) >= n_devices:
        executors["jax"] = api.JaxExecutor()

    out: dict = {"devices": n_devices, "cases": {}}
    for exn, ex in executors.items():
        for m, kind in [(1, "1f1b"), (2, "1f1b"), (4, "1f1b"),
                        (4, "gpipe")]:
            # step-0 loss from a FRESH session (comparable across runs
            # and to the api:train selftest reference), then re-load to
            # time steady-state steps
            sess = api.Session(prog, "pipe", executor=ex)
            sess.load(ws)
            loss0 = sess.train_step({"X": xv}, num_microbatches=m,
                                    schedule=kind).loss
            sess = api.Session(prog, "pipe", executor=ex)
            sess.load(ws)
            sps = _steps_per_second(sess, {"X": xv}, m, kind)
            out["cases"][f"{exn}/m{m}/{kind}"] = {
                "steps_per_second": sps,
                "loss_step0": loss0,
            }
    # plan-level accounting rides along: measured fwd fraction + priced
    # timetable of the train plan
    tplan = prog.compile_train("pipe")
    sched = tplan.schedule(4)
    priced = sched.stats(tplan.tick_durations())
    out["fwd_fraction"] = tplan.fwd_fraction()
    out["priced_makespan_s"] = priced.makespan
    out["bubble_fraction"] = priced.bubble_fraction
    return out


def rows(report: dict | None = None):
    report = report or bench()
    out = []
    for name, c in sorted(report["cases"].items()):
        sps = c["steps_per_second"]
        out.append((f"train_step/{name}", 1.0 / sps,
                    f"steps_per_s={sps:.2f} loss0={c['loss_step0']:g}"))
    out.append(("train_step/fwd_fraction", 0.0,
                f"measured={report['fwd_fraction']:.4f}"))
    return out


def main() -> None:
    report = bench()
    for name, seconds, derived in rows(report):
        print(f"{name},{seconds * 1e6:.0f},{derived}")
    with open("BENCH_train_step.json", "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print("wrote BENCH_train_step.json")


if __name__ == "__main__":
    main()
