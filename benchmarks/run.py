"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig13,fig15,...]
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("fig13_hetero_cluster", "benchmarks.bench_hetero_cluster"),
    ("fig14_elastic", "benchmarks.bench_elastic"),
    ("fig15_mixed_length", "benchmarks.bench_mixed_length"),
    ("fig18_bsr_fusion", "benchmarks.bench_bsr_fusion"),
    ("fig17_case_study", "benchmarks.bench_case_study"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("train_step", "benchmarks.bench_train_step"),
    ("graph_block", "benchmarks.bench_graph_block"),
    ("search", "benchmarks.bench_search"),
    ("overlap", "benchmarks.bench_overlap"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for label, modname in MODULES:
        if filters and not any(f in label for f in filters):
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["rows"])
            for name, seconds, derived in mod.rows():
                print(f"{name},{seconds * 1e6:.0f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures.append((label, e))
            print(f"{label}/ERROR,0,{type(e).__name__}: {e}")
        finally:
            sys.stderr.write(f"[{label}: {time.time() - t0:.1f}s]\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
