"""Async MPMD executor overlap benchmark + permute-fusion micro-bench.

Two halves, matching the PR's two perf claims:

* **pipeline overlap** (``cases``): fwd+bwd training steps/s of the
  transformer bench configs under the async MPMD executor
  (``repro.runtime.async_program``) vs its own ``serialize=True``
  baseline — the SAME per-stage programs and channels, but blocking
  after every issue — and vs the scanned single-program ``JaxExecutor``.
  The measured overlap fraction is ``1 - t_async / t_serialized``: the
  share of wall time the double-buffered channels and eager grad-reduce
  actually hid.  Losses are asserted bit-equal across all three, so the
  numbers compare identical computations.  On forced host-CPU devices
  at toy sizes the scanned program usually stays ahead of per-stage
  dispatch (XLA fuses across the whole step; python dispatch is the
  async bottleneck, recorded as ``dispatch_bound``) — the JSON records
  whatever is true.

* **permute fusion** (``micro``): batched-permute rounds
  (``PlanLowering`` default) vs GSPMD-style per-pair resharding
  (``lower_plan(..., fuse_permutes=False)`` — one ppermute per
  (src, dst) pair, uniform fast paths off) on resharding-heavy plans.
  Outputs are asserted bitwise equal; the JSON records collective
  launches and µs per call for both lowerings.

::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_overlap [--smoke]

``--smoke`` (what CI runs) keeps one pipeline config and single-shot
timings, asserts bit-equality plus the structural invariants (fused
launches < unfused pairs; per-stage program count), and leaves
``BENCH_overlap.json`` untouched; the default run rewrites the JSON.
"""

from __future__ import annotations

import argparse
import json
import time

# (config, parallelism, num_microbatches): the pipelined llama case is
# the one overlap can help; qwen dp2tp2 is the no-pipeline control
# (m=1: its qkv-bias add breaks microbatch role propagation for m>1,
# same restriction as bench_graph_block)
CASES = [
    ("qwen2_1_5b", dict(dp=2, tp=2, pp=1), 1),
    ("llama_32b", dict(dp=1, tp=2, pp=2), 2),
]
B, S = 2, 8
MICRO_SHAPE = (256, 256)


def _init_weights(prog, rng):
    import numpy as np

    ws = {}
    for t in prog.graph.parameters():
        shp = tuple(t.shape)
        ws[t.name] = np.ones(shp, np.float32) \
            if "norm" in t.name.split("/")[-1] \
            else (rng.standard_normal(shp) * 0.05).astype(np.float32)
    return ws


def _time_calls(fn, warmup, iters):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def _micro_plans(n: int):
    """Resharding-heavy (src, dst) pairs over ``n`` devices: a pure
    ring permutation (n pairs -> 1 fused round) and the row->column
    reshard (n*(n-1) pairs -> n-1 fused rounds)."""
    from repro.core.annotations import DS, spmd

    devs = list(range(n))
    return {
        "permute": (spmd(devs, DS({0: n})),
                    spmd(devs[1:] + devs[:1], DS({0: n}))),
        "reshard": (spmd(devs, DS({0: n})), spmd(devs, DS({1: n}))),
    }


def micro(n: int, warmup: int, iters: int) -> dict:
    import jax
    import numpy as np

    from repro.core.comm_resolve import resolve
    from repro.core.simulator import scatter
    from repro.launch.mesh import make_runtime_mesh
    from repro.runtime.lowering import (DeviceOrder, LoweringStats,
                                        lower_plan, pack_shards)

    mesh = make_runtime_mesh(n)
    rng = np.random.default_rng(0)
    value = rng.standard_normal(MICRO_SHAPE).astype(np.float32)
    out: dict = {}
    for name, (src, dst) in _micro_plans(n).items():
        plan = resolve(src, dst, MICRO_SHAPE)
        order = DeviceOrder.for_plan(plan)
        st = scatter(value, src, rng=np.random.default_rng(5))
        packed = pack_shards(st.parts, plan.src, MICRO_SHAPE,
                             int(mesh.devices.size), order)
        entry: dict = {"kind": plan.kind}
        outs = {}
        for mode, fuse in (("fused", True), ("gspmd_per_pair", False)):
            stats = LoweringStats()
            fn = lower_plan(plan, MICRO_SHAPE, mesh, order,
                            stats_out=stats, fuse_permutes=fuse)
            call = lambda fn=fn: jax.block_until_ready(fn(packed))
            outs[mode] = np.asarray(call())
            entry[mode] = {
                "seconds_per_call": _time_calls(call, warmup, iters),
                "copy_pairs": stats.copy_pairs,
                "ppermute_calls": stats.ppermute_calls,
                "uniform_copy_stages": stats.uniform_copy_stages,
            }
        np.testing.assert_array_equal(
            outs["fused"], outs["gspmd_per_pair"],
            err_msg=f"{name}: fused and per-pair lowerings diverged")
        assert entry["fused"]["ppermute_calls"] <= \
            entry["gspmd_per_pair"]["ppermute_calls"], entry
        entry["launch_ratio"] = (
            entry["gspmd_per_pair"]["ppermute_calls"]
            / max(entry["fused"]["ppermute_calls"], 1))
        entry["speedup"] = (
            entry["gspmd_per_pair"]["seconds_per_call"]
            / entry["fused"]["seconds_per_call"])
        out[name] = entry
    return out


def bench(smoke: bool = False) -> dict:
    import jax
    import numpy as np

    from repro import api
    from repro.configs import get_config
    from repro.models.graph_block import block_program

    warmup, iters = (0, 1) if smoke else (1, 3)
    cases = [c for c in CASES if c[2] > 1] if smoke else CASES
    n_host = len(jax.devices())
    out: dict = {"batch": B, "seq": S, "smoke": smoke, "cases": {},
                 "devices_available": n_host}

    for arch, par, m in cases:
        n_dev = par["dp"] * par["tp"] * par["pp"]
        if n_host < n_dev:
            continue
        cfg = get_config(arch).reduced()
        prog = block_program(cfg, batch=B, seq=S, **par)
        rng = np.random.default_rng(0)
        ws = _init_weights(prog, rng)
        feeds = {
            "ids": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab,
                                   (B, S)).astype(np.int32)}
        label = f"{arch}/dp{par['dp']}tp{par['tp']}pp{par['pp']}/m{m}"
        case: dict = {"devices": n_dev, "num_microbatches": m}

        losses = {}
        for exn, ex in (("jax", api.JaxExecutor()),
                        ("async", api.AsyncExecutor()),
                        ("async_serialized",
                         api.AsyncExecutor(serialize=True))):
            sess = api.Session(prog, 0, executor=ex)
            sess.load(dict(ws))
            losses[exn] = sess.train_step(dict(feeds),
                                          num_microbatches=m).loss
            sess = api.Session(prog, 0, executor=ex)
            sess.load(dict(ws))
            sec = _time_calls(
                lambda s=sess: s.train_step(dict(feeds),
                                            num_microbatches=m),
                warmup, iters)
            case[exn] = {"seconds_per_step": sec,
                         "steps_per_second": 1.0 / sec,
                         "loss_step0": losses[exn]}
        assert losses["async"] == losses["jax"] == \
            losses["async_serialized"], losses

        t_async = case["async"]["seconds_per_step"]
        t_serial = case["async_serialized"]["seconds_per_step"]
        case["overlap_fraction"] = 1.0 - t_async / t_serial
        case["async_vs_jax"] = (case["jax"]["seconds_per_step"]
                                / t_async)
        # honest bottleneck label: per-stage python dispatch vs the
        # single fused scan
        case["dispatch_bound"] = case["async_vs_jax"] < 1.0

        ax = api.AsyncExecutor()
        lw = ax.lowered(prog.compile_train(0, loss="loss"))
        case["programs"] = len(lw.programs)
        case["channels"] = len(lw.channels)
        case["channel_kinds"] = sorted(ch.kind for ch in lw.channels)
        if smoke:
            # structural gates: per-(virtual stage, phase) programs and
            # hoisted comm channels really exist on the pipelined case
            assert case["programs"] == 2 * par["pp"], case
            assert "p2p" in case["channel_kinds"], case
        out["cases"][label] = case

    out["micro"] = micro(min(n_host, 4), warmup, max(iters, 1) * 4)
    return out


def rows(report: dict | None = None):
    report = report or bench()
    out = []
    for label, case in sorted(report["cases"].items()):
        for exn in ("jax", "async", "async_serialized"):
            sec = case[exn]["seconds_per_step"]
            out.append((f"overlap/{label}/{exn}", sec,
                        f"steps_per_s={1.0 / sec:.2f} "
                        f"loss0={case[exn]['loss_step0']:.6g}"))
        out.append((f"overlap/{label}/summary", 0.0,
                    f"overlap_fraction={case['overlap_fraction']:.3f} "
                    f"async_vs_jax={case['async_vs_jax']:.2f}x "
                    f"programs={case['programs']} "
                    f"channels={case['channels']}"))
    for name, entry in sorted(report.get("micro", {}).items()):
        out.append((
            f"overlap/micro/{name}/fused",
            entry["fused"]["seconds_per_call"],
            f"launches={entry['fused']['ppermute_calls']}"))
        out.append((
            f"overlap/micro/{name}/gspmd_per_pair",
            entry["gspmd_per_pair"]["seconds_per_call"],
            f"launches={entry['gspmd_per_pair']['ppermute_calls']} "
            f"launch_ratio={entry['launch_ratio']:.1f}x "
            f"speedup={entry['speedup']:.2f}x"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one config, single-shot timings (CI liveness)")
    args = ap.parse_args()
    report = bench(smoke=args.smoke)
    for name, seconds, derived in rows(report):
        print(f"{name},{seconds * 1e6:.0f},{derived}")
    if args.smoke:
        print("smoke ok (BENCH_overlap.json left untouched)")
        return
    with open("BENCH_overlap.json", "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print("wrote BENCH_overlap.json")


if __name__ == "__main__":
    main()
