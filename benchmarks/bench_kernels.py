"""Kernel micro-benchmarks: Pallas (interpret, correctness proxy) vs the
XLA reference on CPU.  Wall times on CPU do NOT reflect TPU performance —
the derived column carries the arithmetic intensities the TPU roofline
uses instead."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, rglru_ref, ssd_scan_ref
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def rows():
    out = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    b, h, s, dh = 1, 4, 512, 64
    q = jax.random.normal(ks[0], (b, h, s, dh))
    k = jax.random.normal(ks[1], (b, h, s, dh))
    v = jax.random.normal(ks[2], (b, h, s, dh))
    flops = 4 * b * h * s * s * dh
    t_ref = _time(lambda *a: flash_attention_ref(*a, causal=True), q, k, v)
    out.append(("kern/flash_attn/xla_ref", t_ref,
                f"ai={flops / (3 * q.size * 4):.0f}flops/B"))
    t_pl = _time(lambda *a: flash_attention(*a, causal=True,
                                            interpret=True), q, k, v)
    out.append(("kern/flash_attn/pallas_interp", t_pl,
                "interpret-mode (correctness path)"))

    bs, ss, hh, p, n = 1, 512, 4, 64, 128
    x = jax.random.normal(ks[3], (bs, ss, hh, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[4], (bs, ss, hh)))
    A = -jnp.exp(jax.random.normal(ks[5], (hh,)) * 0.3)
    B = jax.random.normal(ks[6], (bs, ss, n)) * 0.3
    C = jax.random.normal(ks[7], (bs, ss, n)) * 0.3
    t_ref = _time(lambda *a: ssd_scan_ref(*a, 128)[0], x, dt, A, B, C)
    out.append(("kern/ssd_scan/xla_ref", t_ref, ""))
    t_pl = _time(lambda *a: ssd_scan(*a, chunk=128, interpret=True)[0],
                 x, dt, A, B, C)
    out.append(("kern/ssd_scan/pallas_interp", t_pl, ""))

    w = 256
    xr = jax.random.normal(ks[0], (1, 512, w)) * 0.5
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (1, 512, w)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (1, 512, w)))
    lam = jax.random.normal(ks[3], (w,)) * 0.5
    t_ref = _time(rglru_ref, xr, r, i, lam)
    out.append(("kern/rglru/xla_ref", t_ref, "assoc-scan"))
    t_pl = _time(lambda *a: rglru_pallas(*a, chunk=128, interpret=True),
                 xr, r, i, lam)
    out.append(("kern/rglru/pallas_interp", t_pl, ""))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
