"""Fig 15/16 reproduction: mixed-length training policies.

baseline (fixed long-context packing) vs HotSPa/Hetu-A (intra-step
homogeneous switching) vs Hetu-B (cross-step heterogeneous strategies),
over CommonCrawl-like and GitHub-like synthetic corpora at 32K and 16K
context lengths."""

from __future__ import annotations

import numpy as np

from repro.scenarios.mixed_length import run_mixed_length


def rows(n_steps=20):
    out = []
    for corpus in ("commoncrawl", "github"):
        for context in (32768, 16384):
            for policy in ("baseline", "hotspa", "hetu_b"):
                reps = run_mixed_length(policy, context=context,
                                        corpus_name=corpus,
                                        n_steps=n_steps, seed=7)
                ts = np.array([r.seconds for r in reps])
                tag = f"fig15/{corpus}_{context // 1024}k/{policy}"
                out.append((tag, float(ts.mean()),
                            f"p50={np.percentile(ts, 50):.2f}s "
                            f"p95={np.percentile(ts, 95):.2f}s "
                            f"switches={sum(r.switched for r in reps)}"))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
