"""§8 case-study reproduction (Figs 17-18 left): communication resolution
mix under the C2 heterogeneous strategy + graph-specialization timing
breakdown.

Measures our REAL code: annotation deduction, hierarchical resolution,
per-device operator instantiation — wall-clock on this machine (the
paper reports <10 s for operator instantiation; ours is the same order
at 48-rank scale)."""

from __future__ import annotations

import time

from repro.core.annotations import DUP, PARTIAL, HSPMD
from repro.core.comm_resolve import resolve
from repro.core.costmodel import LLAMA_32B
from repro.scenarios.elastic import TRACE_HOMOG, two_pipeline_strategy
from repro.scenarios.hetero import (grad_sync_annotations,
                                    strategy_annotations)


def rows():
    model = LLAMA_32B
    strat = two_pipeline_strategy(TRACE_HOMOG[1][1], model)  # C2: 31 ranks
    shape = (int(model.params_per_layer // model.d_model), model.d_model)

    t0 = time.perf_counter()
    annots = strategy_annotations(strat, model)
    t_deduce = time.perf_counter() - t0

    # grad-sync resolution per layer: count operator kinds (Fig 17)
    t0 = time.perf_counter()
    kinds: dict[str, int] = {}
    nbytes = 0
    for layer, (src, dst) in grad_sync_annotations(strat, model).items():
        plan = resolve(src, dst, shape)
        nbytes += plan.nbytes_moved()
        for s in plan.steps:
            kinds[s.kind] = kinds.get(s.kind, 0) + 1
    t_resolve = time.perf_counter() - t0

    out = [
        ("fig17/c2/deduction", t_deduce, f"layers={len(annots)}"),
        ("fig17/c2/resolution", t_resolve,
         "ops=" + "+".join(f"{k}x{v}" for k, v in sorted(kinds.items()))
         + f" vol={nbytes / 1e6:.0f}MB"),
    ]

    # specialization wall time on the Fig 9 graph at 48 ranks
    from repro.core.graph import Graph
    from repro.core.annotations import DS, spmd
    from repro.core.specialize import construct_pipelines, specialize
    g = Graph()
    n = 48
    x = g.placeholder("X", (96, 64, 256), [spmd(range(n), DS({0: n}))])
    w = g.parameter("W", (256, 256), [spmd(range(n), DS({DUP: n}))])
    y = g.dot(g.gelu(x), w)
    g.comm(y, spmd(range(n), DS({0: n})))
    g.deduce()
    t0 = time.perf_counter()
    for dev in range(n):
        specialize(g, dev)
    t_spec = time.perf_counter() - t0
    t0 = time.perf_counter()
    pipes = construct_pipelines(g)
    t_pipe = time.perf_counter() - t0
    out.append(("fig18/specialize_48rank", t_spec, f"devices={n}"))
    out.append(("fig18/pipeline_construct", t_pipe,
                f"pipelines={len(pipes)}"))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
