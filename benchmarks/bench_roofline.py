"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``dryrun_results.jsonl`` (raw full-step compiles) and
``roofline_results.jsonl`` (compositional trip-count-corrected terms) if
present; rows report seconds per term + the dominant bottleneck."""

from __future__ import annotations

import json
import os

FILES = ("roofline_results.jsonl", "roofline_final.jsonl",
         "dryrun_results.jsonl")


def rows():
    out = []
    for fname in FILES:
        if not os.path.exists(fname):
            continue
        kind = ("roofline_opt" if "final" in fname else
        "roofline" if "roofline" in fname else "dryrun_raw")
        for line in open(fname):
            r = json.loads(line)
            if "skipped" in r or "error" in r:
                continue
            t = r["roofline_seconds"]
            dom = r["bottleneck"]
            extra = ""
            if "useful_flops_ratio" in r:
                extra = f" useful={r['useful_flops_ratio']:.2f}"
            out.append((
                f"{kind}/{r['arch']}/{r['shape']}",
                t["compute"] + 0.0,
                f"mem={t['memory'] * 1e3:.1f}ms "
                f"coll={t['collective'] * 1e3:.1f}ms "
                f"bottleneck={dom}{extra}"))
    if not out:
        out.append(("roofline/missing", 0.0,
                    "run launch/dryrun.py --all --json dryrun_results.jsonl"))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
