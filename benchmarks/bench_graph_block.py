"""Graph-IR transformer block vs the plain-jax layers stack.

Seeds the perf trajectory for the graph-IR block (the PR 6 tentpole):
fwd+bwd training steps/s of ``models.graph_block.block_program`` on the
numpy simulator and — when enough host devices are forced — the jax
shard_map backend, against the unsharded plain-jax ``models.layers``
reference (jit'd ``jax.value_and_grad``), per reduced config.  The
ref-vs-pallas attention dispatch tallies of the lowered plan ride along
(``LoweringStats``; see docs/kernels.md), so the JSON records what the
compute seam actually dispatched — as do the specialization-class
emission counts (``switch_branches_emitted`` etc.; docs/lowering.md)
and the graph-jax/plain-jax steps/s ratio, so the structural claim
(homogeneous strategies lower switch-free) stays measured.  ``--smoke``
asserts the homogeneous dp2tp2 case really is at the straight-line
minimum: zero switch branches, every segment straight-line.  Emits
``BENCH_graph_block.json``::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_graph_block [--smoke]

``--smoke`` (what CI runs) keeps one config and single-shot timings —
a liveness check for the whole graph-IR train path, not a measurement.
"""

from __future__ import annotations

import argparse
import json
import time

CASES = [
    # (config, parallelism): GQA + qkv bias + tied head, then an
    # untied 2-stage pipeline
    ("qwen2_1_5b", dict(dp=2, tp=2, pp=1)),
    ("llama_32b", dict(dp=1, tp=2, pp=2)),
]
B, S = 2, 8


def _init_weights(prog, rng):
    import numpy as np

    ws = {}
    for t in prog.graph.parameters():
        shp = tuple(t.shape)
        ws[t.name] = np.ones(shp, np.float32) \
            if "norm" in t.name.split("/")[-1] \
            else (rng.standard_normal(shp) * 0.05).astype(np.float32)
    return ws


def _reference_step(cfg, ids, labels):
    """jit'd fwd+bwd of the plain-jax twin of ``build_block``."""
    import jax
    import jax.numpy as jnp

    from repro.models import layers

    eps = cfg.norm_eps

    def loss(params):
        x = params["embed"][ids]
        for i in range(cfg.n_layers):
            p = {k.split("/", 1)[1]: v for k, v in params.items()
                 if k.startswith(f"l{i}/")}
            ap = {k: p[k] for k in ("wq", "wk", "wv", "wo")}
            for bn in ("bq", "bk", "bv"):
                if bn in p:
                    ap[bn] = p[bn]
            h = layers.rms_norm({"w": p["attn_norm"]}, x, eps)
            y, _ = layers.apply_attention(ap, h, cfg, positions=None,
                                          causal=True, use_rope=False)
            x = x + y
            h = layers.rms_norm({"w": p["mlp_norm"]}, x, eps)
            x = x + layers.apply_mlp(
                {"gate": p["w_gate"], "up": p["w_up"],
                 "down": p["w_down"]}, h, cfg.mlp)
        x = layers.rms_norm({"w": params["final_norm"]}, x, eps)
        lm = params["embed"].T if cfg.tie_embeddings \
            else params["lm_head"]
        probs = jax.nn.softmax(x @ lm, -1)
        return jnp.take_along_axis(
            probs, labels[..., None], -1)[..., 0].mean()

    return jax.jit(jax.value_and_grad(loss))


def _time_calls(fn, warmup, iters):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return iters / (time.perf_counter() - t0)


def _dispatch_stats(prog, tplan):
    """Static ref/pallas dispatch tallies of the lowered train plan
    under each forced policy (no execution needed — the seam decides
    eagerly at lowering time)."""
    from repro import api
    from repro.kernels import policy

    out = {}
    for pol in ("ref", "pallas"):
        policy.set_policy(pol)
        try:
            lw = api.JaxExecutor().lowered(tplan, None)
            out[pol] = {"ref": lw.stats.ref_dispatches,
                        "pallas": lw.stats.pallas_dispatches}
        finally:
            policy.set_policy("auto")
    return out


def bench(smoke: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.configs import get_config
    from repro.models.graph_block import block_program

    warmup, iters = (0, 1) if smoke else (1, 3)
    cases = CASES[:1] if smoke else CASES
    out: dict = {"batch": B, "seq": S, "smoke": smoke, "cases": {}}
    for arch, par in cases:
        cfg = get_config(arch).reduced()
        n_dev = par["dp"] * par["tp"] * par["pp"]
        prog = block_program(cfg, batch=B, seq=S, **par)
        rng = np.random.default_rng(0)
        ws = _init_weights(prog, rng)
        ids = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        labels = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
        feeds = {"ids": ids, "labels": labels}
        label = f"{arch}/dp{par['dp']}tp{par['tp']}pp{par['pp']}"
        case: dict = {"devices": n_dev}

        executors = {"sim": api.SimulatorExecutor()}
        if len(jax.devices()) >= n_dev:
            executors["jax"] = api.JaxExecutor()
        for exn, ex in executors.items():
            sess = api.Session(prog, 0, executor=ex)
            sess.load(dict(ws))
            loss0 = sess.train_step(dict(feeds), num_microbatches=1).loss
            sess = api.Session(prog, 0, executor=ex)
            sess.load(dict(ws))
            sps = _time_calls(
                lambda s=sess: s.train_step(dict(feeds),
                                            num_microbatches=1),
                warmup, iters)
            case[f"graph_{exn}"] = {"steps_per_second": sps,
                                    "loss_step0": loss0}

        step = _reference_step(cfg, ids, labels)
        jp = {n: jnp.asarray(v) for n, v in ws.items()}
        want, _ = step(jp)
        case["plain_jax"] = {
            "steps_per_second": _time_calls(
                lambda: jax.block_until_ready(step(jp)),
                max(warmup, 1), iters),
            "loss_step0": float(want),
        }
        if "graph_jax" in case:
            case["graph_jax"]["vs_plain_jax"] = (
                case["graph_jax"]["steps_per_second"]
                / case["plain_jax"]["steps_per_second"])
        if "jax" in executors:
            tplan = prog.compile_train(0, loss="loss")
            case["dispatches"] = _dispatch_stats(prog, tplan)
            lw = api.JaxExecutor().lowered(tplan, None)
            case["lowering"] = {
                "compute_segments": lw.stats.compute_segments,
                "straightline_segments": lw.stats.straightline_segments,
                "switch_branches_emitted":
                    lw.stats.switch_branches_emitted,
            }
            homogeneous = par["pp"] == 1
            if smoke and homogeneous:
                # the CI liveness gate for the specialization-class
                # lowering: a homogeneous (single-class) strategy must
                # emit NO switches at all — every segment straight-line
                assert case["lowering"]["switch_branches_emitted"] == 0, \
                    case["lowering"]
                assert case["lowering"]["straightline_segments"] == \
                    case["lowering"]["compute_segments"] > 0, \
                    case["lowering"]
        out["cases"][label] = case
    return out


def rows(report: dict | None = None):
    report = report or bench()
    out = []
    for label, case in sorted(report["cases"].items()):
        for kind in ("graph_sim", "graph_jax", "plain_jax"):
            if kind not in case:
                continue
            sps = case[kind]["steps_per_second"]
            out.append((f"graph_block/{label}/{kind}", 1.0 / sps,
                        f"steps_per_s={sps:.2f} "
                        f"loss0={case[kind]['loss_step0']:.6g}"))
        disp = case.get("dispatches")
        if disp:
            out.append((f"graph_block/{label}/dispatch", 0.0,
                        f"ref_policy={disp['ref']['ref']}ref+"
                        f"{disp['ref']['pallas']}pallas "
                        f"pallas_policy={disp['pallas']['ref']}ref+"
                        f"{disp['pallas']['pallas']}pallas"))
        low = case.get("lowering")
        if low:
            out.append((f"graph_block/{label}/lowering", 0.0,
                        f"segments={low['compute_segments']} "
                        f"straightline={low['straightline_segments']} "
                        f"switch_branches="
                        f"{low['switch_branches_emitted']}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one config, single-shot timings (CI liveness)")
    args = ap.parse_args()
    report = bench(smoke=args.smoke)
    for name, seconds, derived in rows(report):
        print(f"{name},{seconds * 1e6:.0f},{derived}")
    if args.smoke:
        print("smoke ok (BENCH_graph_block.json left untouched)")
        return
    with open("BENCH_graph_block.json", "w", encoding="utf-8") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
    print("wrote BENCH_graph_block.json")


if __name__ == "__main__":
    main()
