"""Fig 18 (right) + Table 2 reproduction: BSR planning approaches for the
C1 -> C2 strategy transition.

Compares: unfused-no-heuristics (min rank id), per-tensor heuristic
planning, and the fused global plan — transition time estimate, message
count, and the Table 2-style per-sender fast/slow link volume split."""

from __future__ import annotations

from repro.core.costmodel import ClusterSpec, H20, LLAMA_32B
from repro.core.topology import NvlinkIbTopology
from repro.scenarios.elastic import TRACE_HOMOG, two_pipeline_strategy
from repro.scenarios.hetero import strategy_annotations
from repro.core.bsr import (BsrPlan, plan_bsr_naive, plan_fused_bsr,
                            plan_unfused_bsr)


def _tensors():
    model = LLAMA_32B
    src = two_pipeline_strategy(TRACE_HOMOG[0][1], model)   # C1: 32 H20
    dst = two_pipeline_strategy(TRACE_HOMOG[1][1], model)   # C2: 31 H20
    sa, da = strategy_annotations(src, model), strategy_annotations(dst, model)
    shape = (int(model.params_per_layer // model.d_model), model.d_model)
    return [(f"l{i}", sa[i], da[i], shape, 2) for i in range(model.n_layers)]


def rows():
    topo = NvlinkIbTopology(gpus_per_node=8, nvlink_gbps=900.0)
    tensors = _tensors()
    naive_assignments = []
    for name, s, d, shape, isz in tensors:
        naive_assignments.extend(
            plan_bsr_naive(s, d, shape, name, isz).assignments)
    plans = {
        "naive_unfused": BsrPlan(naive_assignments, fused=False),
        "heuristic_unfused": plan_unfused_bsr(tensors, topo),
        "fused": plan_fused_bsr(tensors, topo),
    }
    out = []
    for name, plan in plans.items():
        t = plan.est_time(topo)
        out.append((f"fig18/c1c2/{name}", t,
                    f"msgs={plan.message_count()} "
                    f"bytes={plan.total_bytes() / 1e6:.0f}MB"))
    # Table 2: per-sender volume split over fast (NVLink) vs slow (IB)
    fused = plans["fused"]
    per = fused.per_sender_bytes(topo, fast_threshold=100.0)
    for rank in sorted(per)[:8]:
        fast, slow = per[rank]
        out.append((f"table2/fused/R{rank}", 0.0,
                    f"nvlink={fast / 1e6:.0f}MB ib={slow / 1e6:.0f}MB"))
    return out


def main():
    for name, seconds, derived in rows():
        print(f"{name},{seconds * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
