"""Regenerate the EXPERIMENTS.md appendix tables from sweep artifacts.

  PYTHONPATH=src python -m benchmarks.make_tables >> EXPERIMENTS.md
"""

from __future__ import annotations

import json
import os


def _load(fname):
    rows = {}
    for line in open(fname):
        r = json.loads(line)
        rows[(r["arch"].replace("-", "_").replace(".", "_"),
              r["shape"])] = r
    return rows


def roofline_compare(base_f, opt_f, title):
    base, opt = _load(base_f), _load(opt_f)
    print(f"### {title}\n")
    print("| arch | shape | compute (ms) base→opt | memory (ms) base→opt | "
          "collective (ms) base→opt | bottleneck (opt) | useful base→opt |")
    print("|---|---|---|---|---|---|---|")
    for k in sorted(opt):
        o = opt[k]
        b = base.get(k, o)
        if "skipped" in o:
            print(f"| {k[0]} | {k[1]} | — | — | — | *skipped (sub-quadratic "
                  f"required)* | — |")
            continue
        if "error" in o or "error" in b:
            continue
        tb, to = b["roofline_seconds"], o["roofline_seconds"]

        def f(x):
            return f"{x * 1e3:.1f}"

        print(f"| {k[0]} | {k[1]} | {f(tb['compute'])} → {f(to['compute'])} "
              f"| {f(tb['memory'])} → {f(to['memory'])} "
              f"| {f(tb['collective'])} → {f(to['collective'])} "
              f"| **{o['bottleneck']}** "
              f"| {b.get('useful_flops_ratio', 0):.2f} → "
              f"{o.get('useful_flops_ratio', 0):.2f} |")
    print()


def dryrun_table(fname, title):
    rows = _load(fname)
    print(f"### {title}\n")
    print("| arch | shape | compile (s) | args/device (GiB) | "
          "temps/device (GiB) |")
    print("|---|---|---|---|---|")
    for k, r in sorted(rows.items()):
        if "skipped" in r:
            print(f"| {k[0]} | {k[1]} | — | — | *skipped* |")
            continue
        b = r["bytes_per_device"]
        print(f"| {k[0]} | {k[1]} | {r['compile_s']:.1f} "
              f"| {b['arguments'] / 2**30:.2f} | {b['temps'] / 2**30:.2f} |")
    print()


def main():
    if os.path.exists("roofline_final.jsonl"):
        roofline_compare(
            "roofline_results.jsonl", "roofline_final.jsonl",
            "Roofline: paper-faithful baseline → optimized "
            "(single-pod, per step)")
    if os.path.exists("dryrun_opt.jsonl"):
        dryrun_table("dryrun_opt.jsonl",
                     "Optimized single-pod full-step compiles")
    if os.path.exists("dryrun_multipod_opt.jsonl"):
        dryrun_table("dryrun_multipod_opt.jsonl",
                     "Optimized multi-pod (2×16×16) full-step compiles")


if __name__ == "__main__":
    main()
