#!/usr/bin/env python
"""Execute the ``python`` code blocks of markdown docs.

CI runs this over ``docs/*.md`` so the documentation cannot rot: every
fenced block marked exactly ```` ```python ```` must run (blocks within
one file share a namespace and run in order, so later blocks may use
names defined earlier).  Use a different info string (e.g.
```` ```text ```` or bare fences) for illustrative snippets that are
not meant to execute.

Each file runs in its own subprocess with ``PYTHONPATH=src`` and 8
forced host CPU devices (before any jax import), matching the runtime
selftest harness, so doc examples may use multi-device strategies and
the JaxExecutor.

Usage::

    python tools/run_doc_blocks.py docs/*.md [README.md]
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

FENCE = re.compile(r"^```(\S*)\s*$")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def extract_blocks(path: str) -> list[tuple[int, str]]:
    """(start line, source) for every ```python fenced block."""
    blocks: list[tuple[int, str]] = []
    lang = None
    buf: list[str] = []
    start = 0
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            m = FENCE.match(line)
            if m and lang is None:
                lang = m.group(1)
                buf, start = [], lineno + 1
            elif m:
                if lang == "python" and buf:
                    blocks.append((start, "".join(buf)))
                lang = None
            elif lang is not None:
                buf.append(line)
    if lang is not None:
        raise SystemExit(f"{path}: unterminated code fence")
    return blocks


def run_file(path: str) -> bool:
    blocks = extract_blocks(path)
    if not blocks:
        print(f"  {path}: no python blocks")
        return True
    source = "".join(
        f"\n# --- {path}:{start} (block {i + 1}/{len(blocks)})\n{code}"
        for i, (start, code) in enumerate(blocks))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("XLA_FLAGS",
                   "--xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, "-c", source], cwd=REPO,
                          env=env, capture_output=True, text=True)
    ok = proc.returncode == 0
    status = "ok" if ok else "FAIL"
    print(f"  {path}: {len(blocks)} block(s) {status}")
    if not ok:
        sys.stderr.write(proc.stdout)
        sys.stderr.write(proc.stderr)
    return ok


def main(argv: list[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    failures = [p for p in argv if not run_file(p)]
    if failures:
        print(f"doc blocks FAILED in: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
