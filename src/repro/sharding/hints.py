"""In-model sharding hints (``with_sharding_constraint`` helpers).

GSPMD propagates shardings well through matmuls but poorly through the
scatter/gather MoE dispatch and the fused loss; these helpers pin the
few intermediates that otherwise balloon per-device memory.  They no-op
when no mesh context is active (smoke tests, single device) or when the
requested axes don't exist / don't divide, so model code can call them
unconditionally.
"""

from __future__ import annotations

import numpy as np
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001 — any jax-internal change: just no-op
        return None


def batch_axes() -> tuple[str, ...] | None:
    m = _active_mesh()
    if m is None:
        return None
    return tuple(a for a in ("pod", "data") if a in m.axis_names) or None


def hint(x, *spec):
    """with_sharding_constraint(x, P(*spec)) if the ambient mesh has the
    named axes and every sharded dim divides; otherwise identity."""
    m = _active_mesh()
    if m is None:
        return x
    fixed = []
    for dim, axis in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if axis is None:
            fixed.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if not all(a in m.axis_names for a in axes):
            fixed.append(None)
            continue
        n = int(np.prod([m.shape[a] for a in axes]))
        fixed.append(axis if dim % n == 0 else None)
    if all(a is None for a in fixed):
        return x
    import jax
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def hint_tokens(x):
    """Shard a (tokens, ...) tensor's leading dim over the batch axes."""
    bd = batch_axes()
    return hint(x, bd) if bd else x


# --- sequence-parallel residual stream (Megatron-SP analogue) -------------
_SEQ_SHARD = False


def set_seq_shard(on: bool) -> None:
    """Shard the residual stream's sequence dim over the ``model`` axis
    between blocks (norms/elementwise run on S/TP shards; GSPMD turns the
    TP output all-reduces into reduce-scatter + all-gather pairs)."""
    global _SEQ_SHARD
    _SEQ_SHARD = on


def seq_shard_residual(x):
    if not _SEQ_SHARD or x.ndim != 3:
        return x
    bd = batch_axes()
    if bd is None:
        return x
    return hint(x, bd, "model", None)
