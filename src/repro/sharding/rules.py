"""Annotation -> jax sharding compilation + per-arch parameter rules.

Two layers:

1. ``annot_to_spec`` — the bridge from a (homogeneous, HSize=1) HSPMD
   annotation to a ``PartitionSpec``.  Heterogeneous annotations (HSize>1)
   compile to one spec per sharding subgroup on that subgroup's sub-mesh —
   used by the specialization layer; the production pjit path below covers
   the symmetric case exactly as classical SPMD is the HSize=1 degenerate
   form of HSPMD.

2. ``param_specs`` / ``batch_specs`` / ``decode_state_specs`` — rule-based
   PartitionSpec trees for the production mesh:
     - weights: FSDP over ``data`` x TP over ``model`` (replicated over
       ``pod``; gradients AR over pod = cross-pipeline DP sync),
     - MoE experts: EP over ``model`` when n_experts divides, else TP
       inside each expert,
     - activations/caches: batch over (pod, data), heads/latent over
       ``model`` (GQA head counts below the TP degree shard with GSPMD
       padding — documented trade-off, visible in the roofline),
     - non-divisible dims fall back to replication.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.annotations import DUP, PARTIAL, HSPMD
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# HSPMD annotation -> PartitionSpec (HSize == 1)
# ---------------------------------------------------------------------------

def annot_to_spec(annot: HSPMD, axis_order: tuple[str, ...]) -> P:
    """Compile a single-subgroup annotation to a PartitionSpec.

    ``axis_order`` names the mesh axes corresponding to the DS entries in
    order (the device-major decomposition must match the mesh's).
    Duplicate entries map to unsharded mesh axes; Partial is rejected
    (inputs/outputs of a jit program cannot be partial-valued).
    """
    if annot.hsize != 1:
        raise ValueError("annot_to_spec expects HSize == 1; specialize "
                         "heterogeneous annotations per subgroup")
    ds = annot.dss[0]
    if ds.has_partial:
        raise ValueError("Partial tensors cannot cross a jit boundary")
    if len(axis_order) != len(ds.entries):
        raise ValueError(f"axis_order {axis_order} does not match DS "
                         f"entries {ds.entries}")
    ndim = 1 + max((d for d, _ in ds.entries if d >= 0), default=-1)
    spec: list = [None] * ndim
    for (d, n), axis in zip(ds.entries, axis_order):
        if d >= 0:
            spec[d] = axis
    return P(*spec)


def spec_to_annot(spec: P, mesh: Mesh, shape: tuple[int, ...]) -> HSPMD:
    """Inverse bridge (for recording deployed strategies as annotations)."""
    from repro.core.annotations import DG, DS, spmd
    entries = []
    used = set()
    for d, axis in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        entries.append((d, n))
        used.update(axes)
    dup = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                       if a not in used]))
    if dup > 1:
        entries.append((DUP, dup))
    return spmd(sorted(d.id for d in np.ravel(mesh.devices)), dict(entries))


# ---------------------------------------------------------------------------
# production parameter rules
# ---------------------------------------------------------------------------

_2D_COL = re.compile(
    r"(wq|wk|wv|up|gate|in_proj|in_x|in_gate|gate_r|gate_i|wq_a|wq_b|"
    r"wkv_a|wkv_b|embed)$")
_2D_ROW = re.compile(r"(wo|out_proj|out|down|lm_head)$")


def _div(size: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    return size % n == 0


def _maybe(spec_dims, shape, mesh) -> P:
    """Drop non-divisible axis assignments (replicate those dims)."""
    fixed = []
    for dim, axis in zip(shape, spec_dims):
        fixed.append(axis if _div(dim, mesh, axis) else None)
    return P(*fixed)


def param_specs(params, cfg: ModelConfig, mesh: Mesh, mode: str = "train"):
    """PartitionSpec pytree for the parameter pytree (works for stacked
    layer groups: a leading layer axis is always unsharded).

    ``mode="serve"`` switches to the weight-stationary decode layout:
    weights are NOT sharded over the ``data`` axis (there is no optimizer
    state and no gradient to justify FSDP; per-step weight all-gathers
    were the dominant decode collective — §Perf iteration 2).  Use only
    when bf16 params / TP degree fits HBM alongside the KV cache
    (``serve_mode_fits`` decides)."""
    fsdp = None if mode == "serve" else "data"
    tp = "model"

    def leaf_spec(path: str, leaf) -> P:
        shape = leaf.shape
        name = path.rsplit("/", 1)[-1]
        stacked = path.startswith("groups/")
        base = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()

        def out(*dims):
            return _maybe(lead + dims, shape, mesh)

        if "experts" in path or "shared" in path:
            # (L, E, d, f) or (L, E, f, d)
            e = base[0]
            ep_ok = _div(e, mesh, tp)
            if name in ("up", "gate"):
                return out(tp, fsdp, None) if ep_ok else out(None, fsdp, tp)
            if name == "down":
                return out(tp, None, fsdp) if ep_ok else out(None, tp, fsdp)
        if len(base) == 2 and _2D_COL.search(name):
            return out(fsdp, tp)
        if len(base) == 2 and _2D_ROW.search(name):
            return out(tp, fsdp)
        if name == "router":
            return out(fsdp, None)
        if name == "conv_w":
            return out(None, tp)
        # norms, biases, scalars: replicated
        return P(*([None] * len(shape)))

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}{k}/") for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, f"{path}{i}/") for i, v in enumerate(tree)]
        if isinstance(tree, tuple):
            return tuple(walk(v, f"{path}{i}/") for i, v in enumerate(tree))
        return leaf_spec(path[:-1], tree)

    return walk(params)


def serve_mode_fits(params_struct, state_struct, mesh: Mesh,
                    budget_bytes: int = 14 * 2**30) -> bool:
    """True when bf16 weights / TP + the decode cache shard fit HBM,
    enabling the weight-stationary serve layout."""
    import numpy as np
    tp = mesh.shape.get("model", 1)
    nchips = int(np.prod(list(mesh.shape.values())))
    pbytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree.leaves(params_struct))
    sbytes = sum(int(np.prod(l.shape)) * getattr(l.dtype, "itemsize", 4)
                 for l in jax.tree.leaves(state_struct))
    return pbytes / tp + sbytes / nchips < budget_bytes


def batch_specs(batch, mesh: Mesh):
    """Batch dim over (pod, data) when divisible; everything else local."""
    bdims = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def leaf(x):
        shape = x.shape
        if len(shape) == 0:
            return P()
        if len(shape) == 3 and shape[0] == 3:   # positions3 (3, B, S)
            return _maybe((None, bdims, None), shape, mesh)
        spec = [None] * len(shape)
        spec[0] = bdims
        return _maybe(tuple(spec), shape, mesh)

    return jax.tree.map(leaf, batch)


def decode_state_specs(state, cfg: ModelConfig, mesh: Mesh):
    """KV caches: batch over (pod, data); head/latent dims over model.

    GQA caches with n_kv_heads < TP degree use GSPMD padded sharding on
    the heads dim (documented; roofline shows the cost).  SSM / RG-LRU
    states shard their width dims over model.
    """
    bdims = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tp = "model"

    def leaf(path, x):
        shape = x.shape
        name = path.rsplit("/", 1)[-1]
        if len(shape) == 0:
            return P()
        stacked = path.startswith("caches/")
        # layer-stacked caches: (L, B, ...)
        lead = (None,) if stacked else ()
        base = shape[1:] if stacked else shape
        if name in ("k", "v") and len(base) == 4:
            # (B, S, K, hd): batch over (pod,data), cache SEQUENCE over
            # model (GQA head counts are usually below the TP degree and
            # pjit requires divisibility; sequence-sharding the cache is
            # also the better decode layout: the big score tensor stays
            # sharded and only softmax stats + the (B,H,1,hd) output
            # reduce across the axis)
            return _maybe(lead + (bdims, tp, None, None), shape, mesh)
        if name == "c_kv":
            return _maybe(lead + (bdims, tp, None), shape, mesh)
        if name == "k_rope":
            return _maybe(lead + (bdims, tp, None), shape, mesh)
        if name == "state" and len(base) == 4:
            # SSM state (B, h, p, n): heads over model
            return _maybe(lead + (bdims, tp, None, None), shape, mesh)
        if name in ("conv", "h"):
            spec = lead + (bdims,) + (None,) * (len(base) - 2) + (tp,)
            return _maybe(spec, shape, mesh)
        if name == "enc_out":
            return _maybe((bdims, None, tp), shape, mesh)
        spec = lead + (bdims,) + (None,) * (len(base) - 1)
        return _maybe(spec, shape, mesh)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}{k}/") for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, f"{path}{i}/") for i, v in enumerate(tree)]
        if isinstance(tree, tuple):
            return tuple(walk(v, f"{path}{i}/") for i, v in enumerate(tree))
        return leaf(path[:-1], tree)

    return walk(state)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
