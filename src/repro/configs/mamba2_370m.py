"""Mamba2-370M [arXiv:2405.21060].

48L, d_model 1024, attention-free SSD (state 128, head_dim 64, expand 2),
vocab 50280.  Sub-quadratic: runs the long_500k decode shape.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32,
    d_ff=0, vocab=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    subquadratic=True,
    source="arXiv:2405.21060",
)
