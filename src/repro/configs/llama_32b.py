"""Llama-architecture 32B — the paper's own evaluation model (§7)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-32b", family="dense",
    n_layers=60, d_model=6656, n_heads=52, n_kv_heads=52,
    d_ff=17920, vocab=32000, mlp="swiglu", head_dim=128,
    rope_theta=10_000.0,
    source="arXiv:2307.09288 (paper §7 scale)",
)
