"""Whisper large-v3 [arXiv:2212.04356].

Encoder-decoder; 32 decoder layers (+32 encoder), d_model 1280, 20 heads
(no GQA), d_ff 5120, vocab 51866.  The mel-spectrogram + conv frontend is
a stub: input_specs() supplies 1500 precomputed frame embeddings.
LayerNorm + GELU (family "audio" switches the norm/activation).
"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, mlp="gelu",
    encdec=EncDecConfig(n_enc_layers=32, n_frames=1500),
    input_kind="audio",
    source="arXiv:2212.04356",
)
