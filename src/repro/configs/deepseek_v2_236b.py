"""DeepSeek-V2 236B [arXiv:2405.04434].

60L, d_model 5120, 128 heads, MLA (kv_lora 512, q_lora 1536, rope dim 64),
MoE: 2 shared + 160 routed experts (d_expert 1536), top-6; layer 0 dense
with d_ff 12288.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, mlp="swiglu", head_dim=128,
    mla=MLAConfig(kv_lora=512, q_lora=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  n_dense_layers=1, dense_d_ff=12288),
    source="arXiv:2405.04434",
)
