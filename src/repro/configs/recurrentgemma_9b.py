"""RecurrentGemma-9B [arXiv:2402.19427].

38L, d_model 4096, 16 heads (MQA kv=1), d_ff 12288 (GeGLU), vocab 256000.
Griffin pattern: (rec, rec, local-attn) repeating, window 2048.
Sub-quadratic: runs the long_500k decode shape.
"""
from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, mlp="geglu", head_dim=256,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), window=2048,
                        lru_width=4096, conv_width=4),
    subquadratic=True,
    source="arXiv:2402.19427",
)
