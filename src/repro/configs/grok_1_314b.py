"""Grok-1 314B MoE [hf:xai-org/grok-1].

64L, d_model 6144, 48 heads (GQA kv=8), d_ff 32768, vocab 131072,
8 experts top-2.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, mlp="gelu",
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768),
    source="hf:xai-org/grok-1",
)
