"""Llama-2-70B — the paper's larger evaluation model (§7)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-70b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=32000, mlp="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2307.09288",
)
