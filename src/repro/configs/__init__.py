"""Assigned architecture configs (public-literature pool) + the paper's own.

Each module defines ``CONFIG``; ``get_config(arch_id)`` resolves by id.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "qwen2_vl_72b",
    "whisper_large_v3",
    "phi3_medium_14b",
    "grok_1_314b",
    "qwen1_5_110b",
    "deepseek_67b",
    "qwen2_1_5b",
    "deepseek_v2_236b",
    "mamba2_370m",
    "recurrentgemma_9b",
    # the paper's own evaluation models (Llama architecture, §7)
    "llama_32b",
    "llama_70b",
]


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str):
    name = canon(arch)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
