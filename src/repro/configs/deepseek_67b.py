"""DeepSeek-67B [arXiv:2401.02954].

95L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 102400.
Llama architecture.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, mlp="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2401.02954",
)
