"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
M-RoPE (3-section t/h/w positions); the ViT vision encoder is a stub —
input_specs() supplies interleaved patch/text embeddings directly.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0, mrope=True, mlp="swiglu",
    input_kind="embeds",
    source="arXiv:2409.12191",
)
