"""The elastic *probe* fixture: a tiny training program whose weight
trajectory is **bitwise invariant** across parallel strategies,
microbatch counts, and executors.

Why it works: the loss is ``L = sum(X @ W1 + X @ W2)`` (two pipeline-able
stages joined by an add), so every weight gradient is
``dW = X^T @ ones`` — *weight-independent* small integers.  Cross-device
gradient reductions therefore sum exact integers (order-free in float32
below 2**24), AdamW's per-element update is deterministic IEEE
arithmetic on bitwise-identical inputs, and the grad-norm clip scale is
computed from an exact integer sum of squares.  The weights, optimizer
m/v, and gradients of ANY strategy / microbatch count / executor are
bit-identical at every step — the elastic driver's differential oracle.
Only the LOSS value (a sum of float activations) is
reduction-order-dependent and compares to float tolerance.

Shared by ``tests/test_elastic.py``, the ``elastic:trace/*`` runtime
selftest cases, ``docs/elastic.md`` and ``benchmarks/bench_elastic.py``
(same one-definition rule as :mod:`repro.api.testing`).  Import is
side-effect free.
"""

from __future__ import annotations

import numpy as np

from repro import api

BATCH, DIM = 16, 8
LAYOUTS = ("dp", "pp", "hetero", "single")


def probe_graph() -> "api.Graph":
    """``L = sum(X @ W1 + X @ W2)`` with comm ops slicing it into two
    annotatable halves (W1's stage feeds ``H2``/``X2`` to W2's)."""
    g = api.Graph()
    g.placeholder("X", (BATCH, DIM))
    g.parameter("W1", (DIM, DIM))
    h = g.dot(g.tensors["X"], g.tensors["W1"], name="H")
    g.comm(h, name="H2")
    g.comm(g.tensors["X"], name="X2")
    g.parameter("W2", (DIM, DIM))
    y = g.dot(g.tensors["X2"], g.tensors["W2"], name="Y")
    s = g.add(g.tensors["H2"], y, name="S")
    g.sum(g.sum(s, 1, name="L1"), 0, name="L")
    return g


def _row(k: int) -> "api.DS":
    return api.DS({0: k}) if k > 1 else api.DS({})


def _dup(k: int) -> "api.DS":
    return api.DS({api.DUP: k}) if k > 1 else api.DS({})


def layout_name(kind: str, ranks) -> str:
    return f"{kind}[{','.join(str(r) for r in ranks)}]"


def probe_layout(ranks, kind: str = "dp") -> "api.Strategy":
    """One of the probe's strategy classes on an explicit device set:

    * ``"dp"`` — pure data parallel: activations row-split over all
      ranks, weights replicated (grad-reduce = all-reduce).
    * ``"pp"`` — 2-stage pipeline: W1's stage on the first half of the
      ranks, W2's on the rest, activations row-split within a stage.
    * ``"hetero"`` — hsize=2 HSPMD: two subgroups each own a batch slab
      (hdim=0); the first row-splits its slab, the second duplicates it
      (grads resolve through the two-tier SplitAR path).
    * ``"single"`` — everything on ``ranks[0]``.
    """
    ranks = list(ranks)
    n = len(ranks)
    name = layout_name(kind, ranks)
    if kind == "single" or n == 1:
        r = [ranks[0]]
        one = api.DS({})
        annots = {t: api.spmd(r, one)
                  for t in ("X", "W1", "H2", "X2", "W2")}
        return api.Strategy(layout_name("single", r), annots)
    if kind == "dp":
        annots = {
            "X": api.spmd(ranks, _row(n)),
            "W1": api.spmd(ranks, _dup(n)),
            "H2": api.spmd(ranks, _row(n)),
            "X2": api.spmd(ranks, _row(n)),
            "W2": api.spmd(ranks, _dup(n)),
        }
        return api.Strategy(name, annots)
    if kind == "pp":
        half = (n + 1) // 2
        s0, s1 = ranks[:half], ranks[half:]
        annots = {
            "X": api.spmd(s0, _row(len(s0))),
            "W1": api.spmd(s0, _dup(len(s0))),
            "H2": api.spmd(s1, _row(len(s1))),
            "X2": api.spmd(s1, _row(len(s1))),
            "W2": api.spmd(s1, _dup(len(s1))),
        }
        return api.Strategy(name, annots)
    if kind == "hetero":
        if n % 2:
            raise ValueError(f"hetero layout needs an even rank count "
                             f"(got {n})")
        h = n // 2
        groups = [ranks[:h], ranks[h:]]
        annots = {
            "X": api.HSPMD(groups, [_row(h), _dup(h)], hdim=0),
            "W1": api.HSPMD(groups, [_dup(h), _dup(h)]),
            "H2": api.HSPMD(groups, [_dup(h), _row(h)], hdim=0),
            "X2": api.HSPMD(groups, [_dup(h), _row(h)], hdim=0),
            "W2": api.HSPMD(groups, [_dup(h), _dup(h)]),
        }
        return api.Strategy(name, annots)
    raise ValueError(f"unknown probe layout {kind!r}; have {LAYOUTS}")


def probe_values(seed: int = 3) -> dict[str, np.ndarray]:
    """Integer initial weights."""
    rng = np.random.default_rng(seed)
    return {"W1": rng.integers(-4, 5, (DIM, DIM)).astype(np.float32),
            "W2": rng.integers(-4, 5, (DIM, DIM)).astype(np.float32)}


def probe_feeds(step: int) -> dict[str, np.ndarray]:
    """Deterministic per-step integer batch — the same logical batch
    schedule regardless of which devices are alive, so an elastic run
    and an uninterrupted reference see identical data."""
    rng = np.random.default_rng(10_000 + step)
    return {"X": rng.integers(-4, 5, (BATCH, DIM)).astype(np.float32)}


def probe_provider(default: str = "dp", max_width: int = 8):
    """``(ranks, layout=None) -> api.Strategy`` for the driver: honors a
    per-event layout hint, degrading to a feasible class when the rank
    count cannot host it.  Shard widths must divide the (micro)batch, so
    the provider uses the largest power-of-two prefix of the ranks (at
    most ``max_width``; pass ``BATCH // (2 * m)`` when running ``m``
    microbatches) — surplus devices idle, like a real system dropping
    stragglers that don't fill a shard group."""
    def provider(ranks, layout: str | None = None) -> "api.Strategy":
        kind = layout or default
        n = min(len(ranks), max_width)
        n = 1 << (n.bit_length() - 1)        # largest power of two <= n
        use = list(ranks)[:n]
        if n == 1:
            kind = "single"
        elif kind == "hetero" and n % 2:
            kind = "dp"
        return probe_layout(use, kind)
    return provider


class SearchProvider:
    """A driver provider that re-SELECTS through :class:`repro.search.
    Searcher` on every transition (ROADMAP item 2's "wire into a live
    trace driver"): the searcher picks the best cost-model strategy for
    the surviving ranks, and its *shape* (pipelined or not) is realized
    as the matching probe layout.  Selections are recorded on
    ``self.selections`` for inspection."""

    def __init__(self, searcher=None, cluster=None, max_rank: int = 8):
        from repro.search import Searcher, cpu_cluster, tiny_spec
        self.searcher = searcher or Searcher(
            tiny_spec(), global_batch=8, seq_len=128,
            tp_options=(1, 2), pp_options=(1, 2),
            pipeline_options=(1,), virtual_options=(1,),
            include_hetero=False)
        self.cluster = cluster or cpu_cluster(max_rank)
        self.selections: list = []

    def __call__(self, ranks, layout: str | None = None) -> "api.Strategy":
        if layout is not None:          # explicit hint wins
            return probe_provider()(ranks, layout)
        sel = self.searcher.select_candidate(self.cluster, list(ranks))
        self.selections.append(sel)
        cand = sel.candidate
        pipelined = cand is not None and any(
            len(p.stages) > 1 for p in cand.strategy.pipelines)
        kind = "pp" if pipelined and len(ranks) > 1 else "dp"
        return probe_provider()(ranks, kind)


def reference_run(strategy: "api.Strategy", n_steps: int, *,
                  executor=None, num_microbatches: int = 1,
                  schedule: str = "1f1b", seed: int = 3,
                  feeds=probe_feeds):
    """The differential oracle's dense side: ``n_steps`` uninterrupted
    ``train_step``s under ONE strategy.  Returns ``(session, losses)``;
    the probe's invariance means ``session.weights`` / ``opt_state``
    must be bitwise equal to any elastic trajectory of the same length.
    """
    program = api.Program(probe_graph(), [strategy])
    session = api.Session(program, strategy.name, executor=executor)
    session.load(probe_values(seed))
    losses = []
    for s in range(n_steps):
        r = session.train_step(feeds(s),
                               num_microbatches=num_microbatches,
                               schedule=schedule)
        losses.append(r.loss)
    return session, losses


__all__ = ["BATCH", "DIM", "LAYOUTS", "SearchProvider", "layout_name",
           "probe_feeds", "probe_graph", "probe_layout", "probe_provider",
           "probe_values", "reference_run"]
