"""Fault injection for the elastic trace driver.

A :class:`FaultPlan` is a declarative list of :class:`Fault`\\ s — device
kills / joins / process crashes pinned to a step AND a phase of the
driver loop:

* ``"pre-step"`` — the fault lands before step ``step`` begins (the
  driver sees it when it computes the step's device set).
* ``"mid-transition"`` — the fault lands while step ``step``'s strategy
  transition is in flight: the driver has already re-selected and
  migrated once, and must re-select AND migrate again from the
  just-switched state.
* ``"post-checkpoint"`` — (``kind="crash"`` only) the process dies right
  after step ``step``'s checkpoint hits disk and before the step runs —
  the classic lost-progress window the resume path must cover.

:func:`inject` is the *pure* half the differential tests lean on: it
folds a trace and a FaultPlan into the effective ``step -> device set``
map, without running anything.
"""

from __future__ import annotations

from dataclasses import dataclass, field

KINDS = ("kill", "join", "crash")
PHASES = ("pre-step", "mid-transition", "post-checkpoint")


class FaultError(ValueError):
    """A malformed fault specification."""


@dataclass(frozen=True)
class Fault:
    step: int
    kind: str                       # "kill" | "join" | "crash"
    ranks: tuple[int, ...] = ()
    phase: str = "pre-step"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise FaultError(f"unknown fault kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.phase not in PHASES:
            raise FaultError(f"unknown fault phase {self.phase!r}; "
                             f"have {PHASES}")
        if self.kind == "crash":
            if self.phase != "post-checkpoint":
                raise FaultError(
                    "crash faults model the checkpoint-to-step window; "
                    "use phase='post-checkpoint'")
        elif not self.ranks:
            raise FaultError(f"{self.kind} fault needs ranks")
        object.__setattr__(self, "ranks", tuple(self.ranks))


@dataclass(frozen=True)
class FaultPlan:
    faults: tuple[Fault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    def at(self, step: int, phase: str) -> list[Fault]:
        return [f for f in self.faults
                if f.step == step and f.phase == phase]

    def apply(self, step: int, phase: str, active) -> tuple[int, ...]:
        """The device set after this (step, phase)'s kills/joins land.
        Deterministic: kills drop, joins append (deduplicated), order of
        surviving ranks is preserved."""
        out = list(active)
        for f in self.at(step, phase):
            if f.kind == "kill":
                out = [r for r in out if r not in f.ranks]
            elif f.kind == "join":
                out += [r for r in f.ranks if r not in out]
        return tuple(out)

    def crashes_at(self, step: int) -> bool:
        return any(f.kind == "crash" for f in
                   self.at(step, "post-checkpoint"))


def inject(trace, plan: FaultPlan | None,
           n_steps: int) -> dict[int, tuple[int, ...]]:
    """Fold ``trace`` (TraceEvents or ``(step, ranks)`` pairs) and a
    :class:`FaultPlan` into the effective ``step -> active device set``
    map for steps ``0..n_steps-1`` — the oracle side of the driver's
    fault handling.  Trace events are ABSOLUTE (they reset prior kills);
    faults are deltas on top."""
    plan = plan or FaultPlan()
    events: dict[int, tuple[int, ...]] = {}
    for e in trace:
        if hasattr(e, "step"):
            events[int(e.step)] = tuple(e.ranks)
        else:
            step, ranks = e[0], e[1]
            events[int(step)] = tuple(ranks)
    if 0 not in events:
        raise FaultError("trace must set the device set at step 0")
    out: dict[int, tuple[int, ...]] = {}
    active: tuple[int, ...] = ()
    for step in range(n_steps):
        active = plan.apply(step, "pre-step", active)
        if step in events:
            active = events[step]
        active = plan.apply(step, "mid-transition", active)
        if not active:
            raise FaultError(f"no devices alive at step {step}")
        out[step] = active
    return out


__all__ = ["Fault", "FaultError", "FaultPlan", "KINDS", "PHASES",
           "inject"]
