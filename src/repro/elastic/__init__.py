"""Elastic, fault-tolerant training (paper §7.2 + ROADMAP item 3).

Two halves:

* :mod:`repro.elastic.driver` — the LIVE trace driver: real
  ``train_step``s through device loss/join, strategy re-selection via
  ``repro.search``, fused-BSR weight+optimizer migration through
  ``Session.switch``, durable checkpoints, and crash/resume under a
  different topology.  :mod:`repro.elastic.faults` injects kills /
  joins / crashes at trace-specified (step, phase) points.
* :mod:`repro.elastic.pricing` — the ANALYTIC C1..C7 trace pricing
  (Fig 14), re-exported by the legacy ``repro.scenarios.elastic`` shim.

:mod:`repro.elastic.fixtures` holds the shared probe program whose
weight/optimizer trajectory is bitwise strategy-invariant — the
differential oracle used by tests, the runtime selftest, docs and the
benchmark.
"""

from .driver import (ElasticDriver, ElasticError, ElasticRun, StepRecord,
                     TraceEvent, TransitionRecord, classify_transition,
                     latest_checkpoint)
from .faults import Fault, FaultError, FaultPlan, inject
from .pricing import (TRACE_HETERO, TRACE_HOMOG, TransitionReport,
                      checkpoint_restart_baseline, run_trace,
                      two_pipeline_strategy)

__all__ = [
    "ElasticDriver", "ElasticError", "ElasticRun", "Fault", "FaultError",
    "FaultPlan", "StepRecord", "TRACE_HETERO", "TRACE_HOMOG",
    "TraceEvent", "TransitionRecord", "TransitionReport",
    "checkpoint_restart_baseline", "classify_transition", "inject",
    "latest_checkpoint", "run_trace", "two_pipeline_strategy",
]
