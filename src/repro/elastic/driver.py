"""The trace-driven elastic training driver (ROADMAP item 3).

Runs a REAL training loop over a ``(step -> device set)`` trace.  On
every transition it asks a *provider* for the new device set's strategy
(plug in :class:`repro.elastic.fixtures.SearchProvider` to re-select
through ``repro.search.Searcher`` mid-run), migrates weights AND AdamW
m/v restart-free through ``Session.switch`` (fused-BSR plan), and keeps
issuing ``train_step``\\ s on the surviving logical batch schedule —
bit-identically to an uninterrupted single-strategy run (see
:mod:`repro.elastic.fixtures` for why that oracle is exact).

A :class:`~repro.elastic.faults.FaultPlan` injects device loss/join at
trace-specified steps — including *mid-transition* (the driver
re-selects and migrates a second time from the just-switched state) and
*between a checkpoint and the next step* (``crash`` faults: the run
returns ``interrupted_at`` and :meth:`ElasticDriver.resume` restores
from the latest durable checkpoint, under whatever device set is then
alive — a DIFFERENT topology than the one that saved).
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping

import numpy as np

from repro import api
from repro.checkpoint import store
from repro.checkpoint.store import CheckpointError
from repro.core.simulator import gather
from repro.core.switching import SwitchReport

from .faults import FaultError, FaultPlan


class ElasticError(RuntimeError):
    """The driver cannot make progress (empty trace, no devices, no
    checkpoint to resume from, ...)."""


@dataclass(frozen=True)
class TraceEvent:
    """At ``step`` (before it runs), the cluster becomes ``ranks``.
    ``layout`` optionally pins the provider's strategy class — same
    ranks + a new layout is a *class-change* transition."""

    step: int
    ranks: tuple[int, ...]
    layout: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "ranks", tuple(self.ranks))


@dataclass
class StepRecord:
    step: int
    loss: float
    strategy: str
    ranks: tuple[int, ...]
    wall_seconds: float


@dataclass
class TransitionRecord:
    """One strategy transition: what triggered it, how it was
    classified, and the consumed :class:`SwitchReport` (wall seconds,
    fused-BSR bytes/messages) plus the provider's selection time."""

    step: int
    kind: str                        # shrink | grow | class-change | no-op | resize
    trigger: str                     # trace | fault | mid-transition | resume
    report: SwitchReport
    select_seconds: float
    src_ranks: tuple[int, ...]
    dst_ranks: tuple[int, ...]

    def describe(self) -> str:
        return (f"step {self.step}: {self.kind} ({self.trigger}) "
                f"{list(self.src_ranks)} -> {list(self.dst_ranks)} "
                f"[{self.report.summary()}, "
                f"wall {self.report.wall_seconds * 1e3:.1f} ms, "
                f"select {self.select_seconds * 1e3:.1f} ms]")


@dataclass
class ElasticRun:
    """One driver run (or resumed continuation)."""

    steps: list[StepRecord] = field(default_factory=list)
    transitions: list[TransitionRecord] = field(default_factory=list)
    checkpoints: list[tuple[int, str]] = field(default_factory=list)
    interrupted_at: int | None = None   # crash fault fired before this step
    resumed_from: tuple[int, str] | None = None

    @property
    def losses(self) -> list[float]:
        return [s.loss for s in self.steps]

    def transition_kinds(self) -> list[str]:
        return [t.kind for t in self.transitions]

    def summary(self) -> str:
        lines = [f"{len(self.steps)} step(s), "
                 f"{len(self.transitions)} transition(s), "
                 f"{len(self.checkpoints)} checkpoint(s)"
                 + (f", interrupted at step {self.interrupted_at}"
                    if self.interrupted_at is not None else "")
                 + (f", resumed from step {self.resumed_from[0]}"
                    if self.resumed_from else "")]
        lines += ["  " + t.describe() for t in self.transitions]
        return "\n".join(lines)


def classify_transition(src_ranks, dst_ranks, src_name: str,
                        dst_name: str) -> str:
    """shrink / grow / resize by device-set containment; same set is a
    class-change (new strategy) or a no-op (same strategy)."""
    old, new = set(src_ranks), set(dst_ranks)
    if old == new:
        return "no-op" if src_name == dst_name else "class-change"
    if new < old:
        return "shrink"
    if old < new:
        return "grow"
    return "resize"


def latest_checkpoint(ckpt_dir: str):
    """``(path, manifest)`` of the newest COMPLETE checkpoint under
    ``ckpt_dir`` (``step-NNNNNN`` directories; half-written temp dirs
    and corrupted saves are skipped), or ``None``."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in sorted(os.listdir(ckpt_dir)):
        if not name.startswith("step-"):
            continue
        path = os.path.join(ckpt_dir, name)
        try:
            manifest = store.peek(path)
        except CheckpointError:
            continue
        if best is None or manifest["step"] > best[1]["step"]:
            best = (path, manifest)
    return best


class ElasticDriver:
    """Trace-driven elastic training over one graph.

    ``provider(ranks, layout=None) -> api.Strategy`` maps a live device
    set to a strategy (see :func:`repro.elastic.fixtures.probe_provider`
    / :class:`repro.elastic.fixtures.SearchProvider`).  ``feeds(step)``
    yields the step's placeholder feeds — the LOGICAL batch schedule,
    independent of which devices are alive.
    """

    def __init__(self, graph: "api.Graph",
                 values: Mapping[str, np.ndarray],
                 provider: Callable[..., "api.Strategy"],
                 feeds: Callable[[int], Mapping[str, np.ndarray]], *,
                 executor=None, shape_env=None, topology=None,
                 num_microbatches: int = 1, schedule: str = "1f1b",
                 checkpoint_every: int = 0, ckpt_dir: str | None = None,
                 faults: FaultPlan | None = None, optimizer=None,
                 seed: int = 0):
        if checkpoint_every and not ckpt_dir:
            raise ElasticError("checkpoint_every needs ckpt_dir")
        self.graph = graph
        self.values = {k: np.asarray(v) for k, v in values.items()}
        self.provider = provider
        self.feeds = feeds
        self.executor = executor
        self.shape_env = dict(shape_env or {})
        self.topology = topology
        self.num_microbatches = num_microbatches
        self.schedule = schedule
        self.checkpoint_every = checkpoint_every
        self.ckpt_dir = ckpt_dir
        self.faults = faults or FaultPlan()
        self.optimizer = optimizer
        self.seed = seed
        self.session: "api.Session | None" = None
        self.ranks: tuple[int, ...] = ()

    # -- state -------------------------------------------------------------
    @property
    def strategy_name(self) -> str:
        return self.session.strategy.name if self.session else ""

    def weight_value(self, name: str) -> np.ndarray:
        return self.session.weight_value(name)

    def state_tree(self) -> dict:
        """The gathered (sharding-agnostic) full state: weights plus —
        once training has stepped — AdamW m/v and the step count."""
        sess = self.session
        tree: dict = {"weights": {n: gather(st)
                                  for n, st in sess.weights.items()}}
        if sess.opt_state is not None:
            tree["opt"] = {
                "m": {n: gather(st)
                      for n, st in sess.opt_state["m"].items()},
                "v": {n: gather(st)
                      for n, st in sess.opt_state["v"].items()},
                "count": np.asarray(sess.opt_state["count"],
                                    dtype=np.int64),
            }
        return tree

    # -- trace execution ---------------------------------------------------
    def run(self, trace: Iterable, n_steps: int) -> ElasticRun:
        """Execute steps ``0..n_steps-1`` under ``trace`` (TraceEvents or
        ``(step, ranks[, layout])`` tuples) + the fault plan.  Returns
        early (``interrupted_at`` set) when a crash fault fires."""
        events = self._normalize(trace)
        if 0 not in events:
            raise ElasticError("trace must set the device set at step 0")
        self.session = None
        self.ranks = ()
        return self._loop(events, 0, n_steps)

    def resume(self, trace: Iterable, n_steps: int, *,
               ranks=None, layout: str | None = None) -> ElasticRun:
        """Restore the latest durable checkpoint and continue to
        ``n_steps``.  The restore topology is ``ranks`` when given (the
        devices alive NOW — typically different from the saver's),
        otherwise the trace+faults' effective set at the checkpoint
        step.  Steps between the checkpoint and the interruption are
        deterministically replayed."""
        found = latest_checkpoint(self.ckpt_dir or "")
        if found is None:
            raise ElasticError(
                f"no complete checkpoint under {self.ckpt_dir!r}")
        path, manifest = found
        step0 = int(manifest["step"])
        events = self._normalize(trace)
        if ranks is None:
            from .faults import inject
            ranks = inject(events.values(), self.faults,
                           step0 + 1)[step0]
        skeleton: dict = {"weights": {n: np.zeros_like(v)
                                      for n, v in self.values.items()}}
        if manifest["meta"].get("has_opt"):
            skeleton["opt"] = {
                "m": {n: np.zeros_like(v)
                      for n, v in self.values.items()},
                "v": {n: np.zeros_like(v)
                      for n, v in self.values.items()},
                "count": np.zeros((), np.int64),
            }
        tree, _ = store.restore(path, skeleton)
        self.session = None
        self._start(tuple(ranks), layout)
        self.session.load(tree["weights"])
        if "opt" in tree:
            sess = self.session
            self.session.opt_state = {
                "m": {n: sess._shard(n, v)
                      for n, v in tree["opt"]["m"].items()},
                "v": {n: sess._shard(n, v)
                      for n, v in tree["opt"]["v"].items()},
                "count": int(tree["opt"]["count"]),
            }
        run = self._loop(events, step0, n_steps)
        run.resumed_from = (step0, path)
        return run

    # -- internals ----------------------------------------------------------
    @staticmethod
    def _normalize(trace) -> dict[int, TraceEvent]:
        events: dict[int, TraceEvent] = {}
        for e in trace:
            if not isinstance(e, TraceEvent):
                e = TraceEvent(*e)
            events[e.step] = e
        return events

    def _start(self, ranks: tuple[int, ...], layout: str | None) -> None:
        strategy = self.provider(ranks, layout)
        program = api.Program(self.graph, [strategy])
        self.session = api.Session(
            program, strategy.name, executor=self.executor,
            shape_env=self.shape_env, topology=self.topology,
            seed=self.seed, optimizer=self.optimizer)
        self.session.load(self.values)
        self.ranks = tuple(ranks)

    def _transition(self, step: int, target: tuple[int, ...],
                    layout: str | None, trigger: str,
                    run: ElasticRun) -> None:
        t0 = time.perf_counter()
        strategy = self.provider(target, layout)
        select_s = time.perf_counter() - t0
        kind = classify_transition(self.ranks, target,
                                   self.strategy_name, strategy.name)
        report = self.session.switch(strategy)
        run.transitions.append(TransitionRecord(
            step, kind, trigger, report, select_s,
            src_ranks=self.ranks, dst_ranks=tuple(target)))
        self.ranks = tuple(target)

    def _checkpoint(self, step: int) -> str:
        path = os.path.join(self.ckpt_dir, f"step-{step:06d}")
        tree = self.state_tree()
        store.save(path, tree, step=step,
                   meta={"ranks": list(self.ranks),
                         "strategy": self.strategy_name,
                         "has_opt": "opt" in tree})
        return path

    def _loop(self, events: dict[int, TraceEvent], start: int,
              n_steps: int) -> ElasticRun:
        run = ElasticRun()
        for step in range(start, n_steps):
            # 1. pre-step faults, then the trace event (absolute)
            target = self.faults.apply(step, "pre-step", self.ranks)
            faulted = target != self.ranks
            layout = None
            ev = events.get(step)
            if ev is not None:
                target, layout = ev.ranks, ev.layout
            if not target:
                raise FaultError(f"no devices alive at step {step}")
            if self.session is None:
                self._start(target, layout)
            elif target != self.ranks or layout is not None:
                self._transition(step, target, layout,
                                 "fault" if faulted and ev is None
                                 else "trace", run)
            # 2. faults landing while the transition was in flight:
            #    re-select and migrate AGAIN from the just-switched state
            mid = self.faults.apply(step, "mid-transition", self.ranks)
            if mid != self.ranks:
                if not mid:
                    raise FaultError(
                        f"no devices alive mid-transition at step {step}")
                self._transition(step, mid, None, "mid-transition", run)
            # 3. durable checkpoint of the state BEFORE this step
            if (self.checkpoint_every and step > start
                    and step % self.checkpoint_every == 0):
                path = self._checkpoint(step)
                run.checkpoints.append((step, path))
                if self.faults.crashes_at(step):
                    run.interrupted_at = step
                    return run
            # 4. one real training step on the logical batch schedule
            t0 = time.perf_counter()
            result = self.session.train_step(
                dict(self.feeds(step)),
                num_microbatches=self.num_microbatches,
                schedule=self.schedule)
            run.steps.append(StepRecord(
                step, result.loss, self.strategy_name, self.ranks,
                time.perf_counter() - t0))
        return run


__all__ = ["ElasticDriver", "ElasticError", "ElasticRun", "StepRecord",
           "TraceEvent", "TransitionRecord", "classify_transition",
           "latest_checkpoint"]
