"""ANALYTIC pricing of the elastic trace (paper §7.2, Fig 14).

This is the cost-model half of the elastic story — per-config step times
and fused-BSR transition costs on the paper's 32-GPU trace.  The LIVE
half (real ``train_step``s through device loss/join) is
:mod:`repro.elastic.driver`; ``repro.scenarios.elastic`` remains a shim
over this module.

A trace of cluster configurations (C1..C7 with GPU/node failures); on
every transition Hetu:
  1. re-selects a parallel strategy for the surviving devices (cost model
     — the paper's "pre-profiled results combined with a cost model"),
  2. runs *graph specialization* for the new strategy (measured: our real
     resolve/specialize code), and
  3. migrates weights with *fused BSR* (planned on the real planner;
     transfer time estimated on the paper's NVLink/IB topology).

The checkpoint-and-restart baseline (DeepSpeed/Megatron) instead pays a
fixed restart cost and loses in-flight progress; Oobleck-style template
switching is modeled as naive (unfused, min-rank) BSR + broadcast.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.costmodel import (LLAMA_32B, ClusterSpec, ModelSpec,
                                  PipelineSpec, Stage, Strategy,
                                  best_uniform, paper_cluster, step_time)
from repro.core.switching import plan_tensor_switch
from repro.core.topology import NvlinkIbTopology
from repro.scenarios.hetero import strategy_annotations

# the paper's trace (homogeneous: 32 H20)
TRACE_HOMOG = [
    ("C1", list(range(32))),                       # 32 H20
    ("C2", list(range(31))),                       # GPU failure
    ("C3", list(range(24))),                       # node failure
]
# heterogeneous: 16 H800 (ranks 0-15) + 32 H20 (16-47)
TRACE_HETERO = [
    ("C4", list(range(48))),
    ("C5", list(range(40))),                       # node of H20 lost
    ("C6", [r for r in range(40) if r != 15]),     # one H800 lost
    ("C7", list(range(8)) + list(range(16, 40))),  # 8 H800 lost
]


@dataclass
class TransitionReport:
    name: str
    step_time_s: float
    specialize_s: float = 0.0
    switch_plan_s: float = 0.0
    switch_transfer_s: float = 0.0
    total_bytes: int = 0
    messages: int = 0

    @property
    def reconfigure_s(self) -> float:
        return self.specialize_s + self.switch_plan_s + self.switch_transfer_s


def two_pipeline_strategy(ranks: list[int], model: ModelSpec,
                          global_batch: int = 64) -> Strategy:
    """Hetu's fault-isolated two-pipeline layout (Tables 7/8): split the
    rank list into two pipelines with TP4 stages; a remainder that does
    not fill a TP4 stage becomes smaller trailing stages (paper C2's
    2-GPU and 1-GPU stages)."""
    half = (len(ranks) + 1) // 2
    halves = [ranks[:half], ranks[half:]]
    pipelines = []
    for part in halves:
        if not part:
            continue
        stages = []
        groups = []
        i = 0
        while i < len(part):
            take = 4 if len(part) - i >= 4 else len(part) - i
            # avoid 3-GPU stages (odd TP): fold into 2+1
            if take == 3:
                take = 2
            groups.append(tuple(part[i:i + take]))
            i += take
        n_layers = model.n_layers
        # layers proportional to group size (bigger TP -> more layers)
        weights = [len(g) for g in groups]
        tot = sum(weights)
        lo = 0
        for g, w in zip(groups, weights):
            hi = min(n_layers, lo + max(1, round(n_layers * w / tot)))
            if g is groups[-1]:
                hi = n_layers
            stages.append(Stage(g, (lo, hi)))
            lo = hi
        n_micro = max(global_batch // 2, 1)
        pipelines.append(PipelineSpec(tuple(stages), n_micro, 1))
    return Strategy(tuple(pipelines), zero1=False)  # fault isolation


def run_trace(trace, cluster: ClusterSpec, model: ModelSpec = LLAMA_32B,
              global_batch: int = 64, seq_len: int = 4096,
              mode: str = "fused", pricing: str = "analytic",
              searcher=None) -> list[TransitionReport]:
    """Simulate the trace; returns per-config step time + transition cost.

    ``pricing="analytic"`` (the fast default) keeps the 1:2 fwd:bwd
    split; ``pricing="measured"`` prices step times with the fwd share
    of a differentiated ``compile_train`` proxy plan (memoized in
    :mod:`repro.search.rank`).  With a :class:`repro.search.Searcher`
    the per-config strategy is re-SELECTED against the surviving ranks
    (``searcher.select``, restart-free — ROADMAP item 3) with the
    hand-written two-pipeline layout competing as an ``extras`` entry;
    otherwise the fixture layout is used directly as before."""
    from repro.core.specialize import resolve_comm_ops  # noqa: F401
    from repro.search.rank import resolve_fwd_fraction
    frac = resolve_fwd_fraction(
        "measured" if pricing == "measured" else None)
    topo = NvlinkIbTopology(
        gpus_per_node=8,
        node_nvlink_gbps={n: (400.0 if cluster.ranks[n * 8].name == "H800"
                              else 900.0)
                          for n in range(len(cluster.ranks) // 8)})
    reports = []
    prev_strat = None
    for name, ranks in trace:
        fixture = two_pipeline_strategy(ranks, model, global_batch)
        if searcher is not None:
            strat = searcher.select(cluster, list(ranks),
                                    extras=(fixture,))
        else:
            strat = fixture
        t_step = step_time(cluster, model, strat, seq_len,
                           fwd_fraction=frac)
        rep = TransitionReport(name, t_step)
        if prev_strat is not None:
            # specialization cost: measured wall time of planning every
            # layer's (src, dst) communication
            t0 = time.perf_counter()
            src_annots = strategy_annotations(prev_strat, model)
            dst_annots = strategy_annotations(strat, model)
            rep.specialize_s = time.perf_counter() - t0
            tensors = []
            for layer in range(model.n_layers):
                shape = (int(model.params_per_layer // model.d_model),
                         model.d_model)
                tensors.append((f"layer{layer}", src_annots[layer],
                                dst_annots[layer], shape, 2))
            sw = plan_tensor_switch(tensors, topo, mode=mode)
            rep.switch_plan_s = sw.planning_seconds
            rep.switch_transfer_s = sw.est_transfer_seconds
            rep.total_bytes = sw.total_bytes
            rep.messages = sw.message_count
        reports.append(rep)
        prev_strat = strat
    return reports


def checkpoint_restart_baseline(trace, cluster: ClusterSpec,
                                model: ModelSpec = LLAMA_32B,
                                global_batch: int = 64,
                                seq_len: int = 4096,
                                restart_s: float = 120.0):
    """DeepSpeed/Megatron: re-tune uniform strategy + full restart.
    A failed GPU discards its whole node (uniform sharding constraint)."""
    reports = []
    for name, ranks in trace:
        # uniform systems must drop incomplete nodes
        by_node: dict[int, list[int]] = {}
        for r in ranks:
            by_node.setdefault(r // 8, []).append(r)
        usable = [r for node, rs in by_node.items() if len(rs) == 8
                  for r in rs]
        strat, t = best_uniform(cluster, model, usable, global_batch,
                                seq_len)
        reports.append(TransitionReport(name, t, specialize_s=restart_s))
    return reports
