"""AdamW with optional ZeRO-style sharded optimizer states.

The optimizer state pytree mirrors the parameter pytree, so under pjit the
states inherit the parameters' HSPMD-derived shardings (FSDP over the
``data`` axis x TP over ``model``) — the storage equivalent of ZeRO-3,
with the ZeRO-1 variant (states sharded, params replicated) selectable by
the sharding rules.  The paper's elastic scenarios (§7.2) disable
optimizer-state sharding for restart-free fault tolerance; that maps here
to passing fully-replicated state specs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     opt_state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c
    lr = _schedule(cfg, count)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# sharded AdamW over ShardedTensors (repro.api Session.train_step)
# ---------------------------------------------------------------------------
#
# The graph-IR training step produces gradients as per-device
# ShardedTensors whose annotations MATCH the parameters' (backward's
# grad-reduce comm guarantees it: Partial grads are all-reduced /
# reduce-scattered onto the parameter placement).  The update is
# therefore elementwise per shard — replicas stay bitwise in sync
# because every device applies identical numpy arithmetic to identical
# inputs, which is also what makes the sim and jax executors'
# train_steps bit-comparable.  The math mirrors ``apply_updates`` above
# (same clip, warmup, bias correction and decoupled weight decay), so a
# single-device session matches jax.grad + apply_updates to float
# tolerance.

def init_sharded_state(params):
    """Optimizer state mirroring a ``{name: ShardedTensor}`` weight dict
    (fp32 m/v shards under the SAME annotations — ZeRO-3 storage when
    the params are sharded, ZeRO-1 when only the states are)."""
    import numpy as np

    from repro.core.simulator import ShardedTensor

    def zeros_like(st):
        return ShardedTensor(
            st.shape, st.annot,
            {d: np.zeros(a.shape, np.float32)
             for d, a in st.parts.items()})

    return {"m": {n: zeros_like(st) for n, st in params.items()},
            "v": {n: zeros_like(st) for n, st in params.items()},
            "count": 0}


def sharded_grad_norm(grads) -> float:
    """Global gradient norm over ``{name: ShardedTensor}`` — computed on
    the reconstructed global values (replicas counted once), fp32
    accumulation like :func:`apply_updates`."""
    import numpy as np

    from repro.core.simulator import gather

    acc = np.float32(0.0)
    for st in grads.values():
        g = np.asarray(gather(st), np.float32)
        acc = acc + np.sum(np.square(g), dtype=np.float32)
    return float(np.sqrt(acc))


def sharded_apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """AdamW over sharded weights: returns ``(new_params, new_state,
    metrics)`` with the same structure; deterministic numpy, identical
    for both executors given identical gradient shards."""
    import numpy as np

    from repro.core.simulator import ShardedTensor

    if set(params) != set(grads):
        raise ValueError(
            f"gradient names {sorted(grads)} do not match parameters "
            f"{sorted(params)}")
    count = opt_state["count"] + 1
    gnorm = np.float32(sharded_grad_norm(grads))
    scale = np.minimum(np.float32(1.0),
                       np.float32(cfg.grad_clip) / (gnorm + np.float32(1e-9)))
    c = np.float32(count)
    bc1 = np.float32(1) - np.float32(cfg.b1) ** c
    bc2 = np.float32(1) - np.float32(cfg.b2) ** c
    warm = min(float(count) / max(cfg.warmup_steps, 1), 1.0)
    lr = np.float32(cfg.lr * warm)

    new_params: dict[str, object] = {}
    new_m: dict[str, object] = {}
    new_v: dict[str, object] = {}
    for name, p in params.items():
        g_st, m_st, v_st = grads[name], opt_state["m"][name], \
            opt_state["v"][name]
        pp, mm, vv = {}, {}, {}
        for dev, arr in p.parts.items():
            g = np.asarray(g_st.parts[dev], np.float32) * scale
            m_ = np.float32(cfg.b1) * m_st.parts[dev] \
                + np.float32(1 - cfg.b1) * g
            v_ = np.float32(cfg.b2) * v_st.parts[dev] \
                + np.float32(1 - cfg.b2) * g * g
            step = (m_ / bc1) / (np.sqrt(v_ / bc2) + np.float32(cfg.eps))
            step = step + np.float32(cfg.weight_decay) * \
                arr.astype(np.float32)
            pp[dev] = (arr.astype(np.float32) - lr * step).astype(
                arr.dtype)
            mm[dev] = m_
            vv[dev] = v_
        new_params[name] = ShardedTensor(p.shape, p.annot, pp)
        new_m[name] = ShardedTensor(p.shape, p.annot, mm)
        new_v[name] = ShardedTensor(p.shape, p.annot, vv)
    metrics = {"grad_norm": float(gnorm), "lr": float(lr)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
