"""AdamW with optional ZeRO-style sharded optimizer states.

The optimizer state pytree mirrors the parameter pytree, so under pjit the
states inherit the parameters' HSPMD-derived shardings (FSDP over the
``data`` axis x TP over ``model``) — the storage equivalent of ZeRO-3,
with the ZeRO-1 variant (states sharded, params replicated) selectable by
the sharding rules.  The paper's elastic scenarios (§7.2) disable
optimizer-state sharding for restart-free fault tolerance; that maps here
to passing fully-replicated state specs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     opt_state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c
    lr = _schedule(cfg, count)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# sharded AdamW over ShardedTensors (repro.api Session.train_step)
# ---------------------------------------------------------------------------
#
# The graph-IR training step produces gradients as per-device
# ShardedTensors whose annotations MATCH the parameters' (backward's
# grad-reduce comm guarantees it: Partial grads are all-reduced /
# reduce-scattered onto the parameter placement).  The update is
# therefore elementwise per shard — replicas stay bitwise in sync
# because every device applies identical numpy arithmetic to identical
# inputs, which is also what makes the sim and jax executors'
# train_steps bit-comparable.  The math mirrors ``apply_updates`` above
# (same clip, warmup, bias correction and decoupled weight decay), so a
# single-device session matches jax.grad + apply_updates to float
# tolerance.

def init_sharded_state(params):
    """Optimizer state mirroring a ``{name: ShardedTensor}`` weight dict
    (fp32 m/v shards under the SAME annotations — ZeRO-3 storage when
    the params are sharded, ZeRO-1 when only the states are)."""
    import numpy as np

    from repro.core.simulator import ShardedTensor

    def zeros_like(st):
        return ShardedTensor(
            st.shape, st.annot,
            {d: np.zeros(a.shape, np.float32)
             for d, a in st.parts.items()})

    return {"m": {n: zeros_like(st) for n, st in params.items()},
            "v": {n: zeros_like(st) for n, st in params.items()},
            "count": 0}


_TILE_GROUP_CACHE: dict = {}


def _tile_groups(st):
    """Group a ShardedTensor's devices by the global tile their shard
    covers: one entry per distinct tile, listing the tile's replicas.
    Returns ``None`` when the shards are not plain tiles (any Partial
    layout — shards are then summands, not copies), so callers fall
    back to per-device handling.  Pure geometry — memoized on the
    (annotation, shape) pair, which the optimizer revisits every step."""
    from repro.core.annotations import DUP, PARTIAL

    annot = st.annot
    ck = (annot, st.shape)
    hit = _TILE_GROUP_CACHE.get(ck, False)
    if hit is not False:
        return hit
    out = None
    if annot.hdim != PARTIAL:
        groups: dict[tuple, list[int]] = {}
        for g, (dg, ds) in enumerate(zip(annot.dgs, annot.dss)):
            if ds.has_partial:
                groups = None
                break
            slab = annot.subgroup_shape(g, st.shape)
            key_g = 0 if annot.hdim == DUP else g
            for pos, dev in enumerate(dg):
                box = ds.local_box(pos, slab)
                groups.setdefault((key_g, box), []).append(dev)
        if groups is not None:
            out = list(groups.values())
    _TILE_GROUP_CACHE[ck] = out
    return out


def sharded_grad_norm(grads) -> float:
    """Global gradient norm over ``{name: ShardedTensor}`` — replicas
    counted once, fp32 accumulation like :func:`apply_updates`.

    Computed tile-by-tile from the shards in hand (split dims tile the
    global value, so the squared norm decomposes exactly); only Partial
    layouts — where shards are summands — reconstruct via ``gather``."""
    import numpy as np

    from repro.core.simulator import gather

    acc = np.float32(0.0)
    for st in grads.values():
        tiles = _tile_groups(st)
        if tiles is None:
            g = np.asarray(gather(st, check_dups=False), np.float32)
            acc = acc + np.sum(np.square(g), dtype=np.float32)
        else:
            for devs in tiles:
                g = np.asarray(st.parts[devs[0]], np.float32)
                acc = acc + np.sum(np.square(g), dtype=np.float32)
    return float(np.sqrt(acc))


def sharded_apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """AdamW over sharded weights: returns ``(new_params, new_state,
    metrics)`` with the same structure; deterministic numpy, identical
    for both executors given identical gradient shards."""
    import numpy as np

    from repro.core.simulator import ShardedTensor

    if set(params) != set(grads):
        raise ValueError(
            f"gradient names {sorted(grads)} do not match parameters "
            f"{sorted(params)}")
    count = opt_state["count"] + 1
    c = np.float32(count)
    bc1 = np.float32(1) - np.float32(cfg.b1) ** c
    bc2 = np.float32(1) - np.float32(cfg.b2) ** c
    warm = min(float(count) / max(cfg.warmup_steps, 1), 1.0)
    lr = np.float32(cfg.lr * warm)

    b1, omb1 = np.float32(cfg.b1), np.float32(1 - cfg.b1)
    b2, omb2 = np.float32(cfg.b2), np.float32(1 - cfg.b2)
    eps, wd = np.float32(cfg.eps), np.float32(cfg.weight_decay)

    def upd(arr, g_arr, m_prev, v_prev):
        g = np.asarray(g_arr, np.float32) * scale
        m_ = b1 * m_prev + omb1 * g
        v_ = b2 * v_prev + omb2 * g * g
        step = (m_ / bc1) / (np.sqrt(v_ / bc2) + eps)
        step = step + wd * arr.astype(np.float32)
        return (arr.astype(np.float32) - lr * step).astype(arr.dtype), \
            m_, v_

    # replicas of a tile receive bit-identical updates (identical numpy
    # arithmetic on identical inputs), so each tile is computed once and
    # its result arrays shared across the replica devices — the same
    # class-dedup the lowered executors apply to compute.  fp32 tiles
    # additionally batch into ONE flat buffer so the ~16-op elementwise
    # chain dispatches once per STEP instead of once per tile (the tiles
    # are small enough that numpy per-call overhead, not bandwidth,
    # dominates).  Per-element operation order is identical to ``upd``,
    # so the batched path is bit-for-bit the per-tile path.
    new_params: dict[str, object] = {}
    new_m: dict[str, object] = {}
    new_v: dict[str, object] = {}
    pp_all = {name: {} for name in params}
    mm_all = {name: {} for name in params}
    vv_all = {name: {} for name in params}
    jobs: list[tuple] = []      # (name, devs, p, g, m, v) fp32 tiles
    fb_tiles: list[tuple] = []  # deduped tiles on the per-tile path
    fb_names: list[str] = []    # tensors updated per device (Partial)
    for name, p in params.items():
        g_st, m_st = grads[name], opt_state["m"][name]
        v_st = opt_state["v"][name]
        tiles = _tile_groups(p)
        if tiles is not None and all(
                devs[0] in g_st.parts and devs[0] in m_st.parts
                and p.parts[devs[0]].dtype == np.float32
                and g_st.parts[devs[0]].dtype == np.float32
                for devs in tiles):
            for devs in tiles:
                d0 = devs[0]
                jobs.append((name, devs, p.parts[d0], g_st.parts[d0],
                             m_st.parts[d0], v_st.parts[d0]))
        elif tiles is not None and all(
                devs[0] in g_st.parts and devs[0] in m_st.parts
                for devs in tiles):
            for devs in tiles:
                d0 = devs[0]
                fb_tiles.append((name, devs, p.parts[d0],
                                 g_st.parts[d0], m_st.parts[d0],
                                 v_st.parts[d0]))
        else:                   # Partial shards: per-device update
            fb_names.append(name)
    # steady-state reuse: the views handed out below are contiguous
    # slices of the flat buffers IN JOB ORDER, so when the caller feeds
    # the previous step's params/state straight back (the training
    # loop), the flat P/M/V buffers already hold this step's inputs and
    # the update runs fully in place — no 3x whole-model concatenate.
    # Validated by base identity + byte offset per tile; any reshard,
    # switch() migration or fresh state fails the check and falls back
    # to the concat path.  In-place means the PREVIOUS step's param/
    # state views alias the updated values afterwards — the optimizer
    # consumes its inputs, like any in-place optimizer.
    prev = opt_state.get("_flat")
    flat_cache = None
    if jobs:
        layout = tuple((j[0], tuple(j[1]), j[2].size) for j in jobs)
        reuse = prev is not None and prev["layout"] == layout
        if reuse:
            Pb, Mb, Vb = prev["P"], prev["M"], prev["V"]
            pa = Pb.__array_interface__["data"][0]
            ma = Mb.__array_interface__["data"][0]
            va = Vb.__array_interface__["data"][0]
            off = 0
            for _, _, p0, _, m0, v0 in jobs:
                want = off * 4
                if not (p0.base is Pb and m0.base is Mb
                        and v0.base is Vb
                        and p0.__array_interface__["data"][0] - pa == want
                        and m0.__array_interface__["data"][0] - ma == want
                        and v0.__array_interface__["data"][0] - va == want):
                    reuse = False
                    break
                off += p0.size
        if reuse:
            P, M, V = prev["P"], prev["M"], prev["V"]
            G, t, S = prev["G"], prev["t"], prev["S"]
        else:
            P, M, V = (np.concatenate([j[i].ravel() for j in jobs])
                       for i in (2, 4, 5))
            G = np.empty_like(P)
            t = np.empty_like(P)
            S = np.empty_like(P)
        off = 0                 # grads land in G in ONE pass per tile
        for _, _, _, g0, _, _ in jobs:
            n = g0.size
            np.copyto(G[off:off + n].reshape(g0.shape), g0)
            off += n
        flat_cache = {"layout": layout, "P": P, "M": M, "V": V,
                      "G": G, "t": t, "S": S}

    # global grad norm: one BLAS dot over the flat buffer; tensors off
    # the flat path contribute through the tile/gather logic of
    # :func:`sharded_grad_norm`.  fp32 accumulation either way.
    sq = np.float32(np.dot(G, G)) if jobs else np.float32(0.0)
    fb_norm = {j[0] for j in fb_tiles} | set(fb_names)
    if fb_norm:
        sq = sq + np.float32(
            sharded_grad_norm({n: grads[n] for n in fb_norm})) ** 2
    gnorm = np.sqrt(sq)
    scale = np.minimum(np.float32(1.0),
                       np.float32(cfg.grad_clip) / (gnorm + np.float32(1e-9)))

    for name, devs, p0, g0, m0, v0 in fb_tiles:
        p_, m_, v_ = upd(p0, g0, m0, v0)
        for dev in devs:
            pp_all[name][dev] = p_
            mm_all[name][dev] = m_
            vv_all[name][dev] = v_
    for name in fb_names:
        p, g_st = params[name], grads[name]
        m_st, v_st = opt_state["m"][name], opt_state["v"][name]
        for dev, arr in p.parts.items():
            pp_all[name][dev], mm_all[name][dev], vv_all[name][dev] = \
                upd(arr, g_st.parts[dev], m_st.parts[dev],
                    v_st.parts[dev])

    if jobs:
        G *= scale                              # g = g * scale
        M *= b1                                 # m = b1*m + omb1*g
        np.multiply(G, omb1, out=t)
        M += t
        V *= b2                                 # v = b2*v + (omb2*g)*g
        np.multiply(G, omb2, out=t)
        t *= G
        V += t
        np.divide(M, bc1, out=S)                # (m/bc1)/(sqrt(v/bc2)+eps)
        np.divide(V, bc2, out=t)
        np.sqrt(t, out=t)
        t += eps
        S /= t
        np.multiply(P, wd, out=t)               # step += wd*p
        S += t
        S *= lr                                 # p -= lr*step
        P -= S
        off = 0
        for name, devs, p0, _, _, _ in jobs:
            n = p0.size
            p_ = P[off:off + n].reshape(p0.shape)
            m_ = M[off:off + n].reshape(p0.shape)
            v_ = V[off:off + n].reshape(p0.shape)
            off += n
            for dev in devs:
                pp_all[name][dev] = p_
                mm_all[name][dev] = m_
                vv_all[name][dev] = v_
    for name, p in params.items():
        new_params[name] = ShardedTensor(p.shape, p.annot, pp_all[name])
        new_m[name] = ShardedTensor(p.shape, p.annot, mm_all[name])
        new_v[name] = ShardedTensor(p.shape, p.annot, vv_all[name])
    metrics = {"grad_norm": float(gnorm), "lr": float(lr)}
    new_state = {"m": new_m, "v": new_v, "count": count}
    if flat_cache is not None:
        new_state["_flat"] = flat_cache
    return new_params, new_state, metrics
