"""AdamW with optional ZeRO-style sharded optimizer states.

The optimizer state pytree mirrors the parameter pytree, so under pjit the
states inherit the parameters' HSPMD-derived shardings (FSDP over the
``data`` axis x TP over ``model``) — the storage equivalent of ZeRO-3,
with the ZeRO-1 variant (states sharded, params replicated) selectable by
the sharding rules.  The paper's elastic scenarios (§7.2) disable
optimizer-state sharding for restart-free fault tolerance; that maps here
to passing fully-replicated state specs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply_updates(params, grads, opt_state, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g,
                     opt_state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c
    lr = _schedule(cfg, count)

    def upd(p, m_, v_):
        step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
