"""Train / prefill / decode step builders (the functions the launcher jits).

``build_train_step``: gradient-accumulation scan over microbatches (the
global batch is reshaped to (n_micro, micro, ...) inside the step so the
dry-run's input specs stay (global_batch, seq)), remat inside the layer
scan, fp32 grad accumulation, AdamW update.

``build_prefill_step`` / ``build_decode_step``: the serving pair — prefill
lowers a full forward over the context; decode consumes ONE token with the
KV/SSM/window cache as carried state.

``build_graph_train_step``: the graph-IR trainer — wraps
``repro.api.Session.train_step`` (joint fwd+bwd plan with real backward
ExecItems, grad-reduce comm, sharded AdamW) so launchers drive the HSPMD
pipeline and the jitted model trainer through one interface.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, forward, loss_fn
from repro.optim.adamw import AdamWConfig, apply_updates


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     num_microbatches: int = 1, remat: bool = True):
    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            def resh(x):
                if x.ndim >= 2 and x.shape[0] == 3:   # positions3 (3, B, S)
                    y = x.reshape((3, num_microbatches, -1) + x.shape[2:])
                    return jnp.moveaxis(y, 0, 1)
                if x.ndim >= 1 and x.shape[0] % num_microbatches == 0:
                    return x.reshape((num_microbatches, -1) + x.shape[1:])
                return x
            mbs = jax.tree.map(resh, batch)

            def mb_step(acc, mb):
                from repro.sharding.hints import batch_axes, hint
                bd = batch_axes()
                if bd:
                    # re-pin batch sharding lost by the (G,) -> (n,mb)
                    # reshape across the scan boundary
                    mb = jax.tree.map(
                        lambda a: hint(a, None, bd)
                        if a.ndim >= 2 and a.shape[0] == 3 else hint(a, bd),
                        mb)
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb, cfg, remat=remat)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(mb_step, zeros, mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = jnp.mean(losses)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, remat=remat)
        new_params, new_opt, om = apply_updates(params, grads, opt_state,
                                                opt_cfg)
        return new_params, new_opt, {"loss": loss, **om}

    return train_step


def build_graph_train_step(session, *, num_microbatches: int = 1,
                           schedule: str = "1f1b",
                           virtual_stages_per_device: int | None = None,
                           loss: str | None = None):
    """Graph-IR training step over a ``repro.api.Session`` — the HSPMD
    counterpart of :func:`build_train_step`.

    Returns ``step(feeds) -> TrainResult`` running the session's joint
    fwd+bwd plan (real backward ExecItems on the pipeline timetable's
    bwd ticks, grad-reduce comm, sharded AdamW) on whichever executor
    the session holds — the launcher-facing wrapper around
    ``Session.train_step`` so launch scripts treat both trainers
    uniformly."""
    def step(feeds):
        return session.train_step(
            feeds, num_microbatches=num_microbatches, schedule=schedule,
            virtual_stages_per_device=virtual_stages_per_device,
            loss=loss)

    return step


def build_switch_step(graph, src_strategy: int, dst_strategy: int, *,
                      shape_env: dict[str, int] | None = None,
                      topology=None, backend: str = "sim", mesh=None,
                      reduction: str = "exact"):
    """Dynamic-strategy weight migration as a reusable step (paper §6).

    Returns ``switch_step(weights) -> weights`` re-sharding every
    parameter from ``src_strategy``'s annotations to ``dst_strategy``'s
    through the fused-BSR plan — on the virtual-device simulator
    (``backend="sim"``) or on real devices via the shard_map execution
    backend (``backend="jax"``).
    """
    from repro.core.switching import execute_switch

    def switch_step(weights):
        return execute_switch(weights, graph, src_strategy, dst_strategy,
                              shape_env, topology, backend=backend,
                              mesh=mesh, reduction=reduction)

    return switch_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        # head computed on the last position only (what a server samples
        # from) — the full (B, 32K, vocab) logits would be ~20 GiB/device
        logits, _ = forward(params, batch, cfg, last_only=True)
        return logits[:, -1, :]

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, state, batch):
        logits, new_state = decode_step(params, state, batch, cfg)
        return logits[:, -1, :], new_state

    return serve_step
