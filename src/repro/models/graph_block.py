"""Transformer block expressed in the differentiable graph IR.

``build_block`` grows a :class:`~repro.core.graph.Graph` into the
standard pre-norm decoder block stack of a :class:`ModelConfig` —
embedding lookup, per-layer (rmsnorm -> QKV projections -> multi-head
``attention`` -> output projection -> residual) and (rmsnorm -> SwiGLU /
GeLU MLP -> residual), final norm and a softmax+gather loss head — using
only graph-IR op kinds, so HSPMD deduction, reverse-mode autodiff and
both executors apply to a real architecture end to end.

The math mirrors ``models.layers`` with ``positions=None`` (no RoPE;
rotary embeddings need interleaved trig kernels the IR does not carry
yet) and the loss head is ``mean(softmax(logits)[labels])`` — ``gather``
of the label column, a scalar training loss that exercises softmax and
gather VJPs without a ``log`` op kind.

``block_strategy`` then annotates the SAME graph for a TP x DP x PP
layout: activations batch-split over DP and duplicated over TP, column
weights (wq/wk/wv, gate/up, lm head) split over TP on their output dim,
row weights (wo, down) on their contraction dim (producing Partial
partial-sums that the per-layer CommOps all-reduce), norm weights
replicated, and consecutive layer spans placed on consecutive pipeline
stages with boundary CommOps carrying the residual stream — the
annotation-entry orders are chosen so deduction composes without any
further resharding.  ``block_program`` bundles both into an
``api.Program`` ready for ``compile_train``.
"""

from __future__ import annotations

from ..core.annotations import DS, DUP, spmd

# roles an annotation point can play under the TP x DP x PP layout;
# ``block_strategy`` maps each to a DS whose entry ORDER (outermost
# first) keeps the device -> shard decomposition consistent across ops
ACT = "act"            # (B, ...) activation: [(0, dp), (DUP, tp)]
ACT_LAST = "act_last"  # activation split on its LAST dim over tp
COL = "col"            # (k, n) weight: [(DUP, dp), (1, tp)]
ROW = "row"            # (k, ...) weight/bias: [(DUP, dp), (0, tp)]
REP = "rep"            # fully replicated: [(DUP, dp*tp)]


def _mark(g, t, role: str, stage: int):
    g.block_roles[t.name] = role
    g.block_stages[t.name] = stage
    return t


def _bias_add(g, y, bias, stage: int, name: str):
    """Lift a 1-D column-split bias onto the activation layout: two
    ``bcast`` ops insert (S, B), then a CommOp slices the broadcast onto
    the batch-split placement (an intra-group Slice — no wire traffic)."""
    B, S, _ = y.shape
    bb = g.bcast(g.bcast(bias, 0, S), 0, B)
    bb = _mark(g, g.comm(bb, name=f"{name}_b"), ACT_LAST, stage)
    return g.add(y, bb, name=name)


def build_block(g, cfg, *, batch: int = 4, seq: int = 8,
                n_layers: "int | None" = None, pp: int = 1,
                embed: bool = True, loss_head: bool = True):
    """Grow ``g`` into the block stack of ``cfg``; returns the scalar
    loss tensor (or the residual-stream output when ``loss_head`` is
    off).  ``pp`` fixes where the stage-boundary CommOps go — the graph
    must agree with the strategies later installed on it."""
    B, S, d = batch, seq, cfg.d_model
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers if n_layers is None else n_layers
    if pp < 1 or pp > L:
        raise ValueError(f"pp={pp} must be in 1..{L} (one layer span "
                         f"per stage at minimum)")
    g.block_roles = {}
    g.block_stages = {}

    def stage_of(i):
        return i * pp // L

    if embed:
        ids = _mark(g, g.placeholder("ids", (B, S)), ACT, 0)
        table = _mark(g, g.parameter("embed", (cfg.vocab, d)), REP, 0)
        x = g.embedding(table, ids, name="x0")
    else:
        x = _mark(g, g.placeholder("X", (B, S, d)), ACT, 0)

    for i in range(L):
        st = stage_of(i)
        if i > 0 and st != stage_of(i - 1):
            x = _mark(g, g.comm(x, name=f"pp{st}/x"), ACT, st)
        p = f"l{i}/"

        # -- attention half-layer -------------------------------------
        a_in = g.rmsnorm(
            x, _mark(g, g.parameter(p + "attn_norm", (d,)), REP, st),
            eps=cfg.norm_eps, name=p + "attn_in")
        q = g.dot(a_in, _mark(g, g.parameter(p + "wq", (d, H * hd)),
                              COL, st), name=p + "q0")
        k = g.dot(a_in, _mark(g, g.parameter(p + "wk", (d, K * hd)),
                              COL, st), name=p + "k0")
        v = g.dot(a_in, _mark(g, g.parameter(p + "wv", (d, K * hd)),
                              COL, st), name=p + "v0")
        if cfg.qkv_bias:
            q = _bias_add(g, q, _mark(g, g.parameter(p + "bq", (H * hd,)),
                                      ROW, st), st, p + "q")
            k = _bias_add(g, k, _mark(g, g.parameter(p + "bk", (K * hd,)),
                                      ROW, st), st, p + "k")
            v = _bias_add(g, v, _mark(g, g.parameter(p + "bv", (K * hd,)),
                                      ROW, st), st, p + "v")
        qh = g.transpose(g.reshape(q, (B, S, H, hd)), (0, 2, 1, 3),
                         name=p + "qh")
        kh = g.transpose(g.reshape(k, (B, S, K, hd)), (0, 2, 1, 3),
                         name=p + "kh")
        vh = g.transpose(g.reshape(v, (B, S, K, hd)), (0, 2, 1, 3),
                         name=p + "vh")
        att = g.attention(qh, kh, vh, causal=True, name=p + "att")
        ao = g.reshape(g.transpose(att, (0, 2, 1, 3)), (B, S, H * hd),
                       name=p + "ao")
        proj = g.dot(ao, _mark(g, g.parameter(p + "wo", (H * hd, d)),
                               ROW, st), name=p + "proj")
        proj = _mark(g, g.comm(proj, name=p + "attn_out"), ACT, st)
        x = g.add(x, proj, name=p + "x_attn")

        # -- MLP half-layer -------------------------------------------
        m_in = g.rmsnorm(
            x, _mark(g, g.parameter(p + "mlp_norm", (d,)), REP, st),
            eps=cfg.norm_eps, name=p + "mlp_in")
        up = g.dot(m_in, _mark(g, g.parameter(p + "w_up", (d, cfg.d_ff)),
                               COL, st), name=p + "up")
        if cfg.mlp in ("swiglu", "geglu"):
            gate = g.dot(m_in, _mark(g, g.parameter(p + "w_gate",
                                                    (d, cfg.d_ff)),
                                     COL, st), name=p + "gate")
            act = g.silu(gate) if cfg.mlp == "swiglu" else g.gelu(gate)
            h = g.mul(act, up, name=p + "h")
        else:
            h = g.gelu(up, name=p + "h")
        down = g.dot(h, _mark(g, g.parameter(p + "w_down", (cfg.d_ff, d)),
                              ROW, st), name=p + "down")
        down = _mark(g, g.comm(down, name=p + "mlp_out"), ACT, st)
        x = g.add(x, down, name=p + "x")

    if not loss_head:
        return x

    last = stage_of(L - 1)
    xf = g.rmsnorm(
        x, _mark(g, g.parameter("final_norm", (d,)), REP, last),
        eps=cfg.norm_eps, name="xf")
    if embed and cfg.tie_embeddings:
        # tied head: reuse the embedding table, resharded onto the last
        # stage in column-parallel layout (grads from both uses of the
        # table accumulate through the CommOp's VJP)
        lm = _mark(g, g.comm(g.transpose(g.tensors["embed"], (1, 0)),
                             name="lm_head"), COL, last)
    else:
        lm = _mark(g, g.parameter("lm_head", (d, cfg.vocab)), COL, last)
    logits = g.dot(xf, lm, name="logits0")
    # softmax spans the full vocab: gather the TP-split logits first
    logits = _mark(g, g.comm(logits, name="logits"), ACT, last)
    probs = g.softmax(logits, name="probs")
    labels = _mark(g, g.placeholder("labels", (B, S)), ACT, last)
    pl = g.gather(probs, labels, name="pl")
    return g.scale(g.sum(g.sum(pl, 1), 0), 1.0 / (B * S), name="loss")


def block_strategy(g, *, dp: int = 1, tp: int = 1, pp: int = 1,
                   devices=None, name: "str | None" = None):
    """Annotate a ``build_block`` graph for a dp x tp x pp layout:
    ``pp`` consecutive stage groups of ``dp * tp`` devices each, DP
    outermost within a group."""
    from repro import api

    per = dp * tp
    n_stages = max(g.block_stages.values(), default=0) + 1
    if pp != n_stages:
        raise ValueError(
            f"strategy pp={pp} but the graph was built with "
            f"{n_stages} stage span(s); rebuild with pp={pp}")
    devices = list(devices) if devices is not None \
        else list(range(per * pp))
    if len(devices) != per * pp:
        raise ValueError(f"{len(devices)} devices for dp*tp*pp = "
                         f"{per * pp}")
    stage_devs = [devices[s * per:(s + 1) * per] for s in range(pp)]
    annots = {}
    for t in g.annotation_points():
        role = g.block_roles[t.name]
        sd = stage_devs[g.block_stages[t.name]]
        if role == ACT:
            ds = DS([(0, dp), (DUP, tp)])
        elif role == ACT_LAST:
            ds = DS([(0, dp), (len(t.shape) - 1, tp)])
        elif role == COL:
            ds = DS([(DUP, dp), (1, tp)])
        elif role == ROW:
            ds = DS([(DUP, dp), (0, tp)])
        elif role == REP:
            ds = DS({DUP: per})
        else:
            raise ValueError(f"unknown block role {role!r} for {t.name}")
        annots[t.name] = spmd(sd, ds)
    return api.Strategy(name or f"dp{dp}tp{tp}pp{pp}", annots)


def block_program(cfg, *, batch: int = 4, seq: int = 8,
                  n_layers: "int | None" = None, dp: int = 1, tp: int = 1,
                  pp: int = 1, embed: bool = True, loss_head: bool = True,
                  name: "str | None" = None):
    """One-call bundle: a ``build_block`` graph of ``cfg`` under a
    single dp x tp x pp strategy, as an ``api.Program``."""
    from repro import api

    g = api.Graph()
    build_block(g, cfg, batch=batch, seq=seq, n_layers=n_layers, pp=pp,
                embed=embed, loss_head=loss_head)
    strat = block_strategy(g, dp=dp, tp=tp, pp=pp, name=name)
    return api.Program(g, [strat])
