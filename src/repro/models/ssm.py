"""Mamba2 — state-space duality (SSD) block [arXiv:2405.21060].

Reference implementation of the chunked SSD algorithm in pure jnp
(the Pallas TPU kernel in kernels/ssd_scan.py computes the same math with
VMEM tiling; kernels/ref.py re-exports :func:`ssd_chunked` as its oracle).

The block follows the Mamba2 architecture: in_proj -> (z gate | x, B, C,
dt heads) -> short conv on (x,B,C) -> SSD scan -> gated RMSNorm -> out_proj.
Decode carries (conv_state, ssm_state) — O(1) per token, which is what
makes ``long_500k`` decode feasible for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _init, init_rmsnorm, rms_norm


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:  (b, s, h, p)   head inputs
    dt: (b, s, h)      softplus-activated step sizes
    A:  (h,)           negative decay rates
    B:  (b, s, n)      input projection (shared across heads, 1 group)
    C:  (b, s, n)      output projection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad to a chunk multiple: dt=0 padding is a no-op on the state
        # (decay exp(0)=1, input contribution dt*x = 0)
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, st = ssd_chunked(x, dt, A, B, C, chunk, initial_state)
        return y[:, :s], st
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    # per-step log decay: a_t = exp(dt_t * A)  (A < 0)
    la = dtc * A[None, None, None, :]              # (b,nc,q,h) log decay
    cum = jnp.cumsum(la, axis=2)                   # within-chunk cumsum
    total = cum[:, :, -1]                          # (b,nc,h)

    xbar = xc * dtc[..., None]                     # dt-weighted inputs

    # intra-chunk (quadratic within chunk): L[i,j] = exp(cum_i - cum_j), i>=j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,q,q,h)
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    # scores[i,j] = C_i . B_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)         # (b,nc,q,q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         scores, L, xbar)

    # chunk-level states: S_c = sum_j exp(total - cum_j) B_j x_j^T
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)     # (b,nc,q,h)
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xbar)

    # inter-chunk recurrence over c: S = S_prev * exp(total_c) + S_chunk_c
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    def step(S_prev, inp):
        S_c, tot_c = inp                                    # (b,h,p,n),(b,h)
        S_new = S_prev * jnp.exp(tot_c)[:, :, None, None] + S_c
        return S_new, S_prev

    tot_t = jnp.moveaxis(total, 1, 0)                       # (nc,b,h)
    S_t = jnp.moveaxis(S_chunk, 1, 0)                       # (nc,b,h,p,n)
    final_state, S_prevs = jax.lax.scan(step, initial_state, (S_t, tot_t))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                   # (b,nc,h,p,n)

    # contribution of carried state within each chunk
    decay_in = jnp.exp(cum)                                 # (b,nc,q,h)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_in, S_prevs)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, A, B, C, state):
    """Single-token SSD update: state' = state * exp(dt A) + B (dt x)^T;
    y = C . state'.   x:(b,1,h,p) dt:(b,1,h) B,C:(b,1,n)."""
    a = jnp.exp(dt[..., None, None] * A[None, None, :, None, None])[:, 0]
    xbar = (x * dt[..., None])[:, 0]                        # (b,h,p)
    upd = jnp.einsum("bn,bhp->bhpn", B[:, 0], xbar)
    state = state * a + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0], state)
    return y[:, None].astype(x.dtype), state


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    ks = jax.random.split(key, 6)
    conv_ch = din + 2 * s.d_state
    return {
        "in_proj": _init(ks[0], (d, 2 * din + 2 * s.d_state + nh), dtype),
        "conv_w": _init(ks[1], (s.d_conv, conv_ch), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(A_log)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), dtype),
        "norm": init_rmsnorm(din, dtype),
        "out_proj": _init(ks[2], (din, d), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d. x: (b,s,c); w: (k,c); state: (b,k-1,c)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out + b, new_state


def apply_mamba2(p, x, cfg: ModelConfig, cache=None):
    """x: (b,s,d). cache: {conv, state} for decode."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    din = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    b, s, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + s_cfg.d_state,
                 2 * din + 2 * s_cfg.d_state], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [din, din + s_cfg.d_state], axis=-1)

    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xin.reshape(b, s, nh, s_cfg.head_dim)

    if cache is not None:
        y, new_state = ssd_decode_step(xh, dt, A, Bc, Cc, cache["state"])
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        from repro.kernels.policy import use_pallas
        if use_pallas() and s % s_cfg.chunk == 0:
            from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas
            y, final_state = _ssd_pallas(
                xh, dt, A, Bc, Cc, chunk=s_cfg.chunk,
                interpret=jax.default_backend() != "tpu")
        else:
            y, final_state = ssd_chunked(xh, dt, A, Bc, Cc, s_cfg.chunk)
        new_cache = None
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, din)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_cache
