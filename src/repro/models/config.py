"""Model configuration dataclasses for every assigned architecture family."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    router_aux_coef: float = 0.01
    # first N layers stay dense (DeepSeek-V2 keeps layer 0 dense)
    n_dense_layers: int = 0
    dense_d_ff: int | None = None
    capacity_factor: float = 1.25
    # exact dispatch (capacity = n_tokens, no drops) — used by reduced
    # smoke configs so decode == full forward bit-for-bit
    exact: bool = False


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int
    q_lora: int | None
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style: blocks of (recurrent x R, local-attn x A)."""

    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    window: int = 2048
    lru_width: int | None = None
    conv_width: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    n_frames: int            # stub frontend output length (e.g. 1500)
    frame_dim: int | None = None  # defaults to d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope: bool = False      # qwen2-vl M-RoPE (3-section positions)
    mlp: str = "swiglu"      # swiglu | gelu | geglu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""         # citation
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    # which input modality input_specs() provides
    input_kind: str = "tokens"   # tokens | embeds (vlm) | audio (enc-dec)
    # sub-quadratic decode? (controls long_500k eligibility)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers (x pattern), d_model<=256,
        <=4 experts — same family and code paths."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        kw: dict = dict(
            n_layers=2, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) or 0, vocab=min(self.vocab, 512),
            head_dim=d_model // n_heads if self.head_dim else None,
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                d_expert=min(128, self.moe.d_expert),
                n_shared=min(1, self.moe.n_shared),
                n_dense_layers=min(1, self.moe.n_dense_layers),
                dense_d_ff=min(256, self.moe.dense_d_ff or 256)
                if self.moe.dense_d_ff else None,
                exact=True)
        if self.mla:
            kw["mla"] = replace(
                self.mla, kv_lora=min(64, self.mla.kv_lora),
                q_lora=min(96, self.mla.q_lora) if self.mla.q_lora else None,
                qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=min(32, self.ssm.d_state),
                                head_dim=32, chunk=32)
        if self.hybrid:
            kw["hybrid"] = replace(self.hybrid, window=64,
                                   lru_width=d_model)
            kw["n_layers"] = len(self.hybrid.pattern)  # one full pattern
        if self.encdec:
            kw["encdec"] = replace(self.encdec, n_enc_layers=2, n_frames=8)
        return replace(self, **kw)

    # -- parameter counting (roofline MODEL_FLOPS = 6 N D) -----------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; ``active_only`` counts only
        routed-active experts (MoE 6*N_active*D convention)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if self.family == "ssm":
            s = self.ssm
            din = s.d_inner(d)
            nh = s.n_heads(d)
            per_layer += d * (2 * din + 2 * s.d_state * 0) \
                + d * (2 * din) + din * d  # in_proj(x,z) + out_proj
            per_layer += din * (2 * s.d_state) + nh * 2  # B,C proj + A,dt
            per_layer += s.d_conv * (din + 2 * s.d_state * nh // nh)
        elif self.mla:
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            if m.q_lora:
                per_layer += d * m.q_lora + m.q_lora * qdim
            else:
                per_layer += d * qdim
            per_layer += d * (m.kv_lora + m.qk_rope_dim)
            per_layer += m.kv_lora * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        else:
            hd = self.hd
            per_layer += d * self.n_heads * hd          # q
            per_layer += 2 * d * self.n_kv_heads * hd   # k, v
            per_layer += self.n_heads * hd * d          # o
        # mlp
        def mlp_params(ff):
            return d * ff * (3 if self.mlp in ("swiglu", "geglu") else 2)
        if self.moe:
            n_e = self.moe.top_k if active_only else self.moe.n_experts
            moe_l = (mlp_params(self.moe.d_expert) * (n_e + self.moe.n_shared)
                     + d * self.moe.n_experts)
            dense_l = mlp_params(self.moe.dense_d_ff or self.d_ff)
            nd = self.moe.n_dense_layers
            total_mlp = nd * dense_l + (L - nd) * moe_l
        elif self.family == "ssm":
            total_mlp = 0
        else:
            total_mlp = L * mlp_params(self.d_ff)
        total = emb + L * per_layer + total_mlp
        if self.encdec:
            # encoder layers + cross-attention in decoder
            total += self.encdec.n_enc_layers * (per_layer + mlp_params(self.d_ff))
            total += L * 2 * d * self.n_heads * self.hd  # cross kv+o approx
        return int(total)
