"""RG-LRU recurrent block (RecurrentGemma / Griffin [arXiv:2402.19427]).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the linear recurrence
(log-depth, shardable over batch/width); decode carries ``h``.  The Pallas
kernel in kernels/rglru_scan.py implements the same scan with VMEM tiling.

The recurrent block wraps the RG-LRU Griffin-style: two input branches
(gate via GeLU, signal via causal conv + RG-LRU), merged multiplicatively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _init

_C = 8.0


def rglru_scan(x, r, i, lam):
    """Associative-scan RG-LRU.  x, r, i: (b, s, w); lam: (w,)."""
    log_a = -_C * jax.nn.softplus(lam) * r.astype(jnp.float32)   # (b,s,w)
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    return h.astype(x.dtype)


def rglru_decode_step(x, r, i, lam, h_prev):
    """One-step recurrence: x,r,i: (b,1,w); h_prev: (b,w)."""
    log_a = -_C * jax.nn.softplus(lam) * r[:, 0].astype(jnp.float32)
    a = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i[:, 0] * x[:, 0]).astype(jnp.float32)
    h = a * h_prev + b_t
    return h[:, None].astype(x.dtype), h


def init_recurrent_block(key, cfg: ModelConfig, dtype):
    h = cfg.hybrid
    d = cfg.d_model
    w = h.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "in_x": _init(ks[0], (d, w), dtype),
        "in_gate": _init(ks[1], (d, w), dtype),
        "conv_w": _init(ks[2], (h.conv_width, w), dtype, scale=0.1),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_r": _init(ks[3], (w, w), dtype),
        "gate_i": _init(ks[4], (w, w), dtype),
        "lam": jnp.full((w,), 1.0, jnp.float32),
        "out": _init(ks[5], (w, d), dtype),
    }


def apply_recurrent_block(p, x, cfg: ModelConfig, cache=None):
    """Griffin recurrent branch. cache: {conv, h}."""
    from .ssm import _causal_conv
    gate = jax.nn.gelu(x @ p["in_gate"])
    sig = x @ p["in_x"]
    conv_state = cache["conv"] if cache else None
    sig, new_conv = _causal_conv(sig, p["conv_w"], p["conv_b"], conv_state)
    r = jax.nn.sigmoid(sig @ p["gate_r"])
    i = jax.nn.sigmoid(sig @ p["gate_i"])
    if cache is not None:
        y, new_h = rglru_decode_step(sig, r, i, p["lam"], cache["h"])
        new_cache = {"conv": new_conv, "h": new_h}
    else:
        from repro.kernels.policy import use_pallas
        if use_pallas() and sig.shape[1] % 128 == 0 \
                and sig.shape[2] % 128 == 0:
            from repro.kernels.rglru_scan import rglru_pallas
            y = rglru_pallas(sig, r, i, p["lam"],
                             interpret=jax.default_backend() != "tpu")
        else:
            y = rglru_scan(sig, r, i, p["lam"])
        new_cache = None
    return (y * gate) @ p["out"], new_cache
