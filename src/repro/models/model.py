"""Unified model: one composable stack covering all assigned families.

Layers are grouped into homogeneous *groups* (dense blocks, MoE blocks,
Mamba2 blocks, Griffin superblocks, encoder/decoder stacks).  Each group's
parameters are stacked along a leading layer axis (init via ``jax.vmap``)
and executed with ``jax.lax.scan`` + optional ``jax.checkpoint`` — keeping
the lowered HLO compact enough that 512-way GSPMD partitioning of a
95-layer model compiles in seconds.

Public entry points:
  init_params(key, cfg, dtype)
  forward(params, batch, cfg)                 -> (logits, aux)
  loss_fn(params, batch, cfg)                 -> (loss, metrics)
  init_decode_state(cfg, batch, max_len, dtype)
  decode_step(params, state, batch, cfg)      -> (logits, state)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (_init, apply_attention, apply_mla, apply_mlp,
                     init_attention, init_layernorm, init_mla, init_mlp,
                     init_rmsnorm, layer_norm, rms_norm)
from .moe import apply_moe, init_moe
from .rglru import apply_recurrent_block, init_recurrent_block
from .ssm import apply_mamba2, init_mamba2

Params = Any


# ---------------------------------------------------------------------------
# layer groups
# ---------------------------------------------------------------------------

def layer_groups(cfg: ModelConfig) -> list[tuple[str, int]]:
    """(block_kind, count) sequence describing the decoder stack."""
    if cfg.family == "ssm":
        return [("mamba", cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        n_super, tail = divmod(cfg.n_layers, len(pat))
        groups: list[tuple[str, int]] = [("griffin", n_super)]
        if tail:
            groups.append(("griffin_tail", 1))  # tail = pattern[:tail]
        return groups
    if cfg.moe:
        nd = cfg.moe.n_dense_layers
        out = []
        if nd:
            out.append(("dense", nd))
        out.append(("moe", cfg.n_layers - nd))
        return out
    if cfg.encdec:
        return [("dec", cfg.n_layers)]
    return [("dense", cfg.n_layers)]


def _norm_init(cfg):
    return init_layernorm if cfg.family == "audio" else init_rmsnorm


def _norm_apply(cfg):
    return layer_norm if cfg.family == "audio" else rms_norm


def init_block(key, cfg: ModelConfig, kind: str, dtype):
    d = cfg.d_model
    ninit = _norm_init(cfg)
    ks = jax.random.split(key, 8)
    if kind == "mamba":
        return {"n1": ninit(d, dtype), "mixer": init_mamba2(ks[0], cfg, dtype)}
    if kind in ("griffin", "griffin_tail"):
        pat = cfg.hybrid.pattern
        if kind == "griffin_tail":
            tail = cfg.n_layers % len(pat)
            pat = pat[:tail]
        subs = []
        for j, p in enumerate(pat):
            kk = jax.random.split(ks[j], 4)
            if p == "rec":
                mixer = init_recurrent_block(kk[0], cfg, dtype)
            else:
                mixer = init_attention(kk[0], cfg, dtype)
            subs.append({"n1": ninit(d, dtype), "mixer": mixer,
                         "n2": ninit(d, dtype),
                         "mlp": init_mlp(kk[1], d, cfg.d_ff, cfg.mlp, dtype)})
        return {"subs": subs}
    if kind == "moe":
        attn = (init_mla(ks[0], cfg, dtype) if cfg.mla
                else init_attention(ks[0], cfg, dtype))
        return {"n1": ninit(d, dtype), "attn": attn,
                "n2": ninit(d, dtype), "moe": init_moe(ks[1], cfg, dtype)}
    if kind == "dense":
        attn = (init_mla(ks[0], cfg, dtype) if cfg.mla
                else init_attention(ks[0], cfg, dtype))
        ff = (cfg.moe.dense_d_ff if (cfg.moe and cfg.moe.dense_d_ff)
              else cfg.d_ff)
        return {"n1": ninit(d, dtype), "attn": attn,
                "n2": ninit(d, dtype),
                "mlp": init_mlp(ks[1], d, ff, cfg.mlp, dtype)}
    if kind == "enc":
        return {"n1": ninit(d, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "n2": ninit(d, dtype),
                "mlp": init_mlp(ks[1], d, cfg.d_ff, "gelu", dtype)}
    if kind == "dec":
        return {"n1": ninit(d, dtype),
                "attn": init_attention(ks[0], cfg, dtype),
                "nx": ninit(d, dtype),
                "xattn": init_attention(ks[1], cfg, dtype, cross=True),
                "n2": ninit(d, dtype),
                "mlp": init_mlp(ks[2], d, cfg.d_ff, "gelu", dtype)}
    raise ValueError(kind)


def apply_block(p, x, cfg: ModelConfig, kind: str, ctx: dict,
                cache=None):
    """Returns (y, new_cache, aux)."""
    napp = _norm_apply(cfg)
    aux = jnp.float32(0.0)
    eps = cfg.norm_eps

    def attn_call(ap, h, *, window=None, cross=False, c=None):
        if cfg.mla and not cross:
            return apply_mla(ap, h, cfg, positions=ctx.get("positions"),
                             cache=c)
        return apply_attention(
            ap, h, cfg, positions=ctx.get("positions"),
            positions3=ctx.get("positions3"),
            causal=False if cross else ctx.get("causal", True),
            window=window,
            cache=c, kv_src=ctx.get("enc_out") if cross else None,
            use_rope=not cross and cfg.family != "audio")

    if kind == "mamba":
        y, nc = apply_mamba2(p["mixer"], napp(p["n1"], x, eps), cfg, cache)
        return x + y, nc, aux

    if kind in ("griffin", "griffin_tail"):
        pat = cfg.hybrid.pattern
        if kind == "griffin_tail":
            pat = pat[: cfg.n_layers % len(pat)]
        new_caches = []
        for j, kindj in enumerate(pat):
            sp = p["subs"][j]
            cj = cache[j] if cache is not None else None
            h = napp(sp["n1"], x, eps)
            if kindj == "rec":
                y, nc = apply_recurrent_block(sp["mixer"], h, cfg, cj)
            else:
                y, nc = attn_call(sp["mixer"], h,
                                  window=cfg.hybrid.window, c=cj)
            x = x + y
            x = x + apply_mlp(sp["mlp"], napp(sp["n2"], x, eps), cfg.mlp)
            new_caches.append(nc)
        return x, (new_caches if cache is not None else None), aux

    if kind in ("dense", "moe"):
        from repro.sharding.hints import seq_shard_residual
        y, nc = attn_call(p["attn"], napp(p["n1"], x, eps), c=cache)
        x = seq_shard_residual(x + y)
        h = napp(p["n2"], x, eps)
        if kind == "moe":
            y2, aux = apply_moe(p["moe"], h, cfg)
        else:
            y2 = apply_mlp(p["mlp"], h, cfg.mlp)
        return seq_shard_residual(x + y2), nc, aux

    if kind == "enc":
        ctx_enc = dict(ctx, causal=False)
        y, _ = apply_attention(p["attn"], napp(p["n1"], x, eps), cfg,
                               causal=False, use_rope=False)
        x = x + y
        return x + apply_mlp(p["mlp"], napp(p["n2"], x, eps), "gelu"), None, aux

    if kind == "dec":
        c_self = cache["self"] if cache is not None else None
        y, nc = attn_call(p["attn"], napp(p["n1"], x, eps), c=c_self)
        x = x + y
        yx, _ = attn_call(p["xattn"], napp(p["nx"], x, eps), cross=True)
        x = x + yx
        x = x + apply_mlp(p["mlp"], napp(p["n2"], x, eps), "gelu")
        return x, ({"self": nc} if nc is not None else None), aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    params: dict = {}
    if cfg.input_kind == "tokens" or cfg.encdec:
        params["embed"] = _init(ks[0], (cfg.vocab, cfg.d_model), dtype)
    params["groups"] = {}
    for gi, (kind, count) in enumerate(layer_groups(cfg)):
        gkeys = jax.random.split(ks[1 + (gi % 4)], count)
        params["groups"][f"g{gi}_{kind}"] = jax.vmap(
            lambda k: init_block(k, cfg, kind, dtype))(gkeys)
    if cfg.encdec:
        ekeys = jax.random.split(ks[5], cfg.encdec.n_enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block(k, cfg, "enc", dtype))(ekeys)
        params["enc_norm"] = _norm_init(cfg)(cfg.d_model, dtype)
    params["final_norm"] = _norm_init(cfg)(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(ks[6], (cfg.d_model, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _sinusoid(positions, d, dtype):
    """Sinusoidal position embedding (stand-in for Whisper's learned table;
    the conv frontend is already a stub per DESIGN.md)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _embed_inputs(params, batch, cfg: ModelConfig, pos=None):
    if cfg.input_kind == "embeds":
        return batch["embeds"]
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "audio":
        b, s = tokens.shape
        if pos is None:
            p = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        else:
            p = jnp.broadcast_to(pos[None, None], (b, s))
        x = x + _sinusoid(p, cfg.d_model, x.dtype)
    return x


def _run_encoder(params, batch, cfg: ModelConfig, remat: bool = False):
    h = batch["audio_embeds"]
    b, f = h.shape[:2]
    h = h + _sinusoid(jnp.broadcast_to(jnp.arange(f)[None], (b, f)),
                      cfg.d_model, h.dtype)

    def enc_step(x, lp):
        y, _, _ = apply_block(lp, x, cfg, "enc", {})
        return y, None

    if remat:  # §Perf it. 9: un-remat'd encoder dominated whisper train temps
        enc_step = jax.checkpoint(enc_step, prevent_cse=False)
    h, _ = jax.lax.scan(enc_step, h, params["encoder"])
    return _norm_apply(cfg)(params["enc_norm"], h, cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig, remat: bool = False,
            last_only: bool = False):
    """Full-sequence forward -> (logits, aux_loss).  ``last_only``
    computes the LM head on the final position only (prefill serving:
    the (B, S, vocab) logits tensor at 32K x 152K vocab is ~20 GiB per
    device otherwise — §Perf iteration 8)."""
    from repro.sharding.hints import batch_axes, hint
    x = _embed_inputs(params, batch, cfg)
    x = hint(x, batch_axes())
    b, s = x.shape[:2]
    ctx = {
        "positions": batch.get("positions",
                               jnp.broadcast_to(jnp.arange(s)[None], (b, s))),
        "positions3": batch.get("positions3"),
        "causal": True,
    }
    if cfg.encdec:
        ctx["enc_out"] = _run_encoder(params, batch, cfg, remat=remat)

    aux_total = jnp.float32(0.0)
    for gname, gparams in params["groups"].items():
        kind = gname.split("_", 1)[1]

        def blk(x, lp, kind=kind):
            y, _, aux = apply_block(lp, x, cfg, kind, ctx)
            return y, aux

        if remat:
            blk = jax.checkpoint(blk, prevent_cse=False)

        x, auxs = jax.lax.scan(blk, x, gparams)
        aux_total = aux_total + jnp.sum(auxs)

    if last_only:
        x = x[:, -1:, :]
    x = _norm_apply(cfg)(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    from repro.sharding.hints import batch_axes, hint
    logits = hint(logits, batch_axes(), None, "model")
    return logits, aux_total


def loss_fn(params, batch, cfg: ModelConfig, remat: bool = False):
    logits, aux = forward(params, batch, cfg, remat=remat)
    labels = batch["labels"]
    # cross-entropy without materializing a full fp32 log-softmax:
    # logsumexp (fp32 accumulate) + picked-logit gather
    from repro.sharding.hints import batch_axes as _ba, hint as _hint
    logits32 = _hint(logits.astype(jnp.float32), _ba(), None, "model")
    lse = jax.scipy.special.logsumexp(logits32, axis=-1)
    picked = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _empty_cache_block(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                       dtype):
    d = cfg.d_model
    if kind == "mamba":
        s = cfg.ssm
        din = s.d_inner(d)
        return {
            "conv": jnp.zeros((batch, s.d_conv - 1, din + 2 * s.d_state), dtype),
            "state": jnp.zeros((batch, s.n_heads(d), s.head_dim, s.d_state),
                               jnp.float32),
        }
    if kind in ("griffin", "griffin_tail"):
        pat = cfg.hybrid.pattern
        if kind == "griffin_tail":
            pat = pat[: cfg.n_layers % len(pat)]
        w = cfg.hybrid.lru_width or d
        out = []
        for p in pat:
            if p == "rec":
                out.append({"conv": jnp.zeros((batch, cfg.hybrid.conv_width - 1, w), dtype),
                            "h": jnp.zeros((batch, w), jnp.float32)})
            else:
                wlen = min(cfg.hybrid.window, max_len)
                out.append({"k": jnp.zeros((batch, wlen, cfg.n_kv_heads, cfg.hd), dtype),
                            "v": jnp.zeros((batch, wlen, cfg.n_kv_heads, cfg.hd), dtype),
                            "idx": jnp.int32(0)})
        return out
    if cfg.mla:
        m = cfg.mla
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
                "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
                "idx": jnp.int32(0)}
    kv = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
          "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
          "idx": jnp.int32(0)}
    if kind == "dec":
        return {"self": kv}
    return kv


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.float32, enc_out=None) -> dict:
    """Stacked per-group caches (leading layer axis) + step counter."""
    caches = {}
    for gname_kind, count in zip(
            [f"g{i}_{k}" for i, (k, _) in enumerate(layer_groups(cfg))],
            [c for _, c in layer_groups(cfg)]):
        kind = gname_kind.split("_", 1)[1]
        one = _empty_cache_block(cfg, kind, batch, max_len, dtype)
        caches[gname_kind] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape).copy()
            if isinstance(a, jnp.ndarray) else a,
            one, is_leaf=lambda a: isinstance(a, jnp.ndarray))
    state = {"caches": caches, "pos": jnp.int32(0)}
    if enc_out is not None:
        state["enc_out"] = enc_out
    return state


def decode_step(params, state, batch, cfg: ModelConfig):
    """One-token decode.  batch: {tokens: (B,1)} (or embeds).  Returns
    (logits (B,1,V), new_state)."""
    pos = state["pos"]
    x = _embed_inputs(params, batch, cfg, pos=pos)
    b = x.shape[0]
    ctx = {
        "positions": jnp.broadcast_to(pos[None, None], (b, 1)),
        "positions3": batch.get("positions3"),
        "causal": True,
    }
    if "enc_out" in state:
        ctx["enc_out"] = state["enc_out"]

    new_caches = {}
    for gname, gparams in params["groups"].items():
        kind = gname.split("_", 1)[1]
        cache = state["caches"][gname]

        def blk(x, inp, kind=kind):
            lp, c = inp
            y, nc, _ = apply_block(lp, x, cfg, kind, ctx, cache=c)
            return y, nc

        x, nc = jax.lax.scan(blk, x, (gparams, cache))
        new_caches[gname] = nc

    x = _norm_apply(cfg)(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return logits, {**state, "caches": new_caches, "pos": pos + 1}
