"""Neural-net building blocks (pure functional JAX).

Every block is a pair ``init_*(key, cfg, ...) -> params`` /
``apply(params, x, ...) -> y`` over plain dict pytrees, so layer stacks can
be created with ``jax.vmap`` over per-layer keys and executed with
``jax.lax.scan`` (compact HLO — essential for 512-way GSPMD partitioning
of 80-95 layer models).

Attention runs through :mod:`repro.kernels.ops` which dispatches between
the pure-XLA reference and the Pallas TPU kernels.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _init(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"w": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * p["w"]


def init_layernorm(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layer_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype)) * p["w"] + p["b"]


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections=(16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: positions3 (3, B, S) = (t, h, w) ids;
    the head-dim frequency bands are partitioned into 3 sections, each
    rotated by its own position stream [arXiv:2409.12191]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # section id per frequency band
    sec = jnp.concatenate([jnp.full((s,), i) for i, s in enumerate(sections)])
    sec = sec[: hd // 2]
    # gather per-band positions: band b uses the positions3[sec[b]] stream
    p = positions3.astype(jnp.float32)                  # (3,B,S)
    ang = p[sec, :, :]                                  # (hd/2,B,S)
    ang = jnp.moveaxis(ang, 0, -1) * freqs              # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, d, ff, kind, dtype, bias=False):
    ks = jax.random.split(key, 3)
    p = {"up": _init(ks[1], (d, ff), dtype),
         "down": _init(ks[2], (ff, d), dtype)}
    if kind in ("swiglu", "geglu"):
        p["gate"] = _init(ks[0], (d, ff), dtype)
    return p


def apply_mlp(p, x, kind):
    up = x @ p["up"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * up
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, cross-attention, KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype, cross=False):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {"wq": _init(ks[0], (d, H * hd), dtype),
         "wk": _init(ks[1], (d, K * hd), dtype),
         "wv": _init(ks[2], (d, K * hd), dtype),
         "wo": _init(ks[3], (H * hd, d), dtype)}
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def cache_write(buf, new, idx):
    """Write ``new`` (B, s, ...) into ``buf`` (B, S, ...) at position ``idx``.

    Single-token decode uses a masked `where(iota == idx)` update instead
    of dynamic_update_slice: with the cache SEQUENCE-sharded over the TP
    axis, DUS at a dynamic index triggers GSPMD's "involuntary full
    rematerialization" (an all-gather of the whole cache per layer per
    token — §Perf iteration 1); the masked form is elementwise and stays
    entirely shard-local (XLA fuses it into a masked copy).
    """
    if new.shape[1] == 1:
        ids = jnp.arange(buf.shape[1])
        mask = (ids == idx).reshape((1, -1) + (1,) * (buf.ndim - 2))
        return jnp.where(mask, new.astype(buf.dtype), buf)
    start = (0, idx) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)


_CHUNK_Q = 1024
_CHUNK_THRESHOLD = 8 * 1024 * 1024  # sq*sk above which q-chunking kicks in


def _sdpa_block(q, k, v, *, causal, window, q_offset, length_mask,
                kv_seq_hint: bool = False):
    """GQA attention WITHOUT materializing repeated K/V: queries are
    grouped as (b, sq, kv_heads, rep, hd) and contracted against the
    un-repeated cache.  (`jnp.repeat` over heads lowers to a
    broadcast_in_dim that GSPMD implements by ALL-GATHERING a
    sequence-sharded KV cache — 2.1 GB/layer at decode_32k;
    §Perf iteration 1b.)

    ``kv_seq_hint`` pins the score tensor's key dim to the ``model`` axis
    (decode path: the cache is sequence-sharded, so scores stay sharded
    and only softmax stats + the (b,h,1,hd) output cross the axis)."""
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    rep = h // kh
    if kv_seq_hint:
        # decode path: grouped heads, un-repeated K/V (repeat would
        # all-gather the sequence-sharded cache)
        qg = q.reshape(b, sq, kh, rep, hd)
        logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    else:
        # train/prefill: K/V are fresh activations (repeat is local);
        # the grouped reshape would mis-align head sharding when H does
        # not divide the TP degree (phi3's 40 heads on TP16 regressed
        # memory 2x — measured, reverted for this path)
        kq = jnp.repeat(k, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    if kv_seq_hint:
        from repro.sharding.hints import batch_axes, hint
        logits = hint(logits, batch_axes(), None, None, None, "model")
    qi = jnp.arange(sq) + q_offset
    ki = jnp.arange(sk)
    if causal or window is not None:
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= ki[None, :] <= qi[:, None]
        if window is not None:
            mask &= ki[None, :] > qi[:, None] - window
        mshape = (1,) * (logits.ndim - 2) + (sq, sk)
        logits = jnp.where(mask.reshape(mshape), logits, -1e30)
    if length_mask is not None:  # (B, Sk) valid-key mask
        lshape = (b,) + (1,) * (logits.ndim - 3) + (1, sk)
        logits = jnp.where(length_mask.reshape(lshape), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    if kv_seq_hint:
        out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
        return out.reshape(b, sq, h, v.shape[-1])
    vq = jnp.repeat(v, rep, axis=2)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vq)


def sdpa(q, k, v, *, causal: bool, window: int | None = None,
         q_offset: int = 0, length_mask: jnp.ndarray | None = None,
         kv_seq_hint: bool = False):
    """Reference scaled-dot-product attention with GQA broadcast.

    q: (B,Sq,H,hd); k/v: (B,Sk,K,hd).  On TPU the Pallas flash kernel
    (kernels/flash_attention.py) replaces this math; shapes and semantics
    are identical (see kernels/ref.py).

    Long sequences take a query-chunked path (scan over Sq blocks,
    materializing only (chunk, Sk) score tiles) so the XLA fallback stays
    O(S) in memory — required to even lower prefill_32k, where the naive
    (B,H,S,S) fp32 score tensor would be tens of GiB per device.
    """
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    from repro.kernels.policy import use_pallas
    if (use_pallas() and length_mask is None and q_offset == 0
            and sq % 128 == 0 and sk % 128 == 0 and hd % 8 == 0):
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), causal=causal, window=window,
                              interpret=jax.default_backend() != "tpu")
        return out.swapaxes(1, 2)
    if sq * sk > _CHUNK_THRESHOLD and sq % _CHUNK_Q == 0 and sq > _CHUNK_Q:
        nc = sq // _CHUNK_Q
        qc = q.reshape(b, nc, _CHUNK_Q, h, hd).swapaxes(0, 1)

        def body(carry, inp):
            qi, idx = inp
            out = _sdpa_block(qi, k, v, causal=causal, window=window,
                              q_offset=q_offset + idx * _CHUNK_Q,
                              length_mask=length_mask,
                              kv_seq_hint=kv_seq_hint)
            return carry, out

        _, outs = jax.lax.scan(body, 0, (qc, jnp.arange(nc)))
        # output head dim follows v (MLA uses v_head_dim != qk head dim)
        return outs.swapaxes(0, 1).reshape(b, sq, h, v.shape[-1])
    return _sdpa_block(q, k, v, causal=causal, window=window,
                       q_offset=q_offset, length_mask=length_mask,
                       kv_seq_hint=kv_seq_hint)


def apply_attention(p, x, cfg: ModelConfig, *, positions=None,
                    positions3=None, causal=True, window=None,
                    cache=None, kv_src=None, use_rope=True):
    """Self- or cross-attention.  ``cache`` (decode): dict with
    k/v (B, S_max, K, hd) and index; returns (y, new_cache)."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"] + (p.get("bq", 0.0) if "bq" in p else 0.0)
    src = x if kv_src is None else kv_src
    k = src @ p["wk"] + (p.get("bk", 0.0) if "bk" in p else 0.0)
    v = src @ p["wv"] + (p.get("bv", 0.0) if "bv" in p else 0.0)
    q = _split_heads(q, H, hd)
    k = _split_heads(k, K, hd)
    v = _split_heads(v, K, hd)
    if use_rope and kv_src is None:
        if cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.rope_theta)
        elif positions is not None:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        idx = cache["idx"]                                 # scalar int32
        b = x.shape[0]
        cache_len = cache["k"].shape[1]
        if window is not None and cache_len <= window:
            # ring buffer: the cache IS the sliding window; every live slot
            # is in-window by construction (keys carry their write-time RoPE)
            slot = idx % cache_len
            ck = cache_write(cache["k"], k, slot)
            cv = cache_write(cache["v"], v, slot)
            valid = jnp.arange(cache_len) < (idx + x.shape[1])
            y = sdpa(q, ck, cv, causal=False, kv_seq_hint=True,
                     length_mask=jnp.broadcast_to(valid[None, :],
                                                  (b, cache_len)))
        else:
            ck = cache_write(cache["k"], k, idx)
            cv = cache_write(cache["v"], v, idx)
            valid = jnp.arange(ck.shape[1]) < (idx + x.shape[1])
            y = sdpa(q, ck, cv, causal=False, window=window, q_offset=idx,
                     kv_seq_hint=True,
                     length_mask=jnp.broadcast_to(valid[None, :],
                                                  (b, ck.shape[1])))
        new_cache = {"k": ck, "v": cv, "idx": idx + x.shape[1]}
    else:
        y = sdpa(q, k, v, causal=causal, window=window)
    b, s = x.shape[:2]
    out = y.reshape(b, s, H * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 [arXiv:2405.04434])
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    p = {}
    if m.q_lora:
        p["wq_a"] = _init(ks[0], (d, m.q_lora), dtype)
        p["wq_b"] = _init(ks[1], (m.q_lora, H * qd), dtype)
    else:
        p["wq"] = _init(ks[0], (d, H * qd), dtype)
    # joint KV low-rank compression + decoupled rope key
    p["wkv_a"] = _init(ks[2], (d, m.kv_lora + m.qk_rope_dim), dtype)
    p["wkv_b"] = _init(ks[3], (m.kv_lora, H * (m.qk_nope_dim + m.v_head_dim)),
                       dtype)
    p["wo"] = _init(ks[4], (H * m.v_head_dim, d), dtype)
    return p


def apply_mla(p, x, cfg: ModelConfig, *, positions=None, causal=True,
              cache=None):
    """MLA attention.  Decode cache stores only the compressed latent
    (kv_lora + rope dims per token) — the paper's KV-cache saving."""
    m = cfg.mla
    H = cfg.n_heads
    b, s, _ = x.shape
    qd = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora:
        q = (x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, H, qd)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = x @ p["wkv_a"]                                # (b,s,lora+rope)
    c_kv, k_rope = jnp.split(latent, [m.kv_lora], axis=-1)
    k_rope = k_rope[:, :, None, :]                         # (b,s,1,rope)
    if positions is not None:
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        c_all = cache_write(cache["c_kv"], c_kv, idx)
        r_all = cache_write(cache["k_rope"], k_rope[:, :, 0, :], idx)
        new_cache = {"c_kv": c_all, "k_rope": r_all, "idx": idx + s}
        kv_len = c_all.shape[1]
        valid = jnp.arange(kv_len) < (idx + s)
        c_kv_full, k_rope_full = c_all, r_all[:, :, None, :]
        q_offset = idx
    else:
        c_kv_full, k_rope_full = c_kv, k_rope
        valid = None
        q_offset = 0

    kv = (c_kv_full @ p["wkv_b"]).reshape(
        b, c_kv_full.shape[1], H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_full,
                                  (*k_nope.shape[:3], m.qk_rope_dim))], -1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    y = sdpa(qh, k, v, causal=causal and cache is None,
             q_offset=q_offset, kv_seq_hint=cache is not None,
             length_mask=None if valid is None
             else jnp.broadcast_to(valid[None, :], (b, valid.shape[0])))
    out = y.reshape(b, s, H * m.v_head_dim) @ p["wo"]
    return out, new_cache
