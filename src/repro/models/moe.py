"""Mixture-of-Experts layer (GShard-style capacity dispatch).

Covers grok-1 (8 experts, top-2) and DeepSeek-V2 (2 shared + 160 routed,
top-6).  The dense dispatch/combine einsum formulation is used because it
shards cleanly under GSPMD: with the expert dim Split over the ``model``
mesh axis, XLA inserts the all-to-all the paper's expert parallelism
requires — which our HSPMD layer annotates and the roofline pass measures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init, apply_mlp, init_mlp


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"router": _init(ks[0], (d, m.n_experts), dtype)}
    # routed experts: stacked (E, d, ff) weights
    def one_expert(k):
        return init_mlp(k, d, m.d_expert, cfg.mlp, dtype)
    p["experts"] = jax.vmap(one_expert)(
        jax.random.split(ks[1], m.n_experts))
    if m.n_shared:
        p["shared"] = jax.vmap(lambda k: init_mlp(k, d, m.d_expert, cfg.mlp,
                                                  dtype))(
            jax.random.split(ks[2], m.n_shared))
    return p


def _capacity(tokens: int, m) -> int:
    if m.exact:
        return tokens  # every token fits any expert: no drops
    cap = int(tokens * m.top_k * m.capacity_factor / m.n_experts)
    # round UP to a 128 multiple: MXU-aligned expert matmuls AND keeps the
    # (E, cap, d) buffer divisible for GSPMD (an unaligned cap measurably
    # DEGRADES the partitioning — §Perf iteration 4, refuted-then-refined)
    cap = max(cap, 1)
    return ((cap + 127) // 128) * 128 if cap > 128 else cap


def apply_moe_ep_shmap(p, x, cfg: ModelConfig, mesh):
    """Expert-parallel MoE via shard_map (§Perf iteration 6).

    Observation: activations are replicated across the ``model`` axis
    (only batch is data-sharded), so no token all-to-all is needed at
    all — each (data, model) device processes ITS batch shard's tokens
    through ITS model-shard's experts, and one bf16 psum over ``model``
    combines the per-expert-shard partial outputs.  The GSPMD
    scatter/gather dispatch instead reshuffled multi-GB replicated
    buffers with AR/AG pairs (measured ~8 GB/layer/microbatch).

    Requires E % tp == 0; falls back to the GSPMD path otherwise.
    Drop policy: capacity is enforced per (batch shard x expert), a
    standard local-capacity variant (exact mode keeps zero drops).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    b, s, d = x.shape
    tp = mesh.shape["model"]
    bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    e_loc = m.n_experts // tp

    def local(xt, router, experts, shared):
        # xt: (T_loc, d); experts: (E_loc, d, f) — weights arrive full
        # (their FSDP 'data' dim is all-gathered by the caller spec)
        mi = jax.lax.axis_index("model")
        T_loc = xt.shape[0]
        logits = (xt @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, m.top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        cap = _capacity(T_loc, m) if not m.exact else T_loc
        lo = mi * e_loc
        rel = top_e - lo                                   # (T,k)
        mine = (rel >= 0) & (rel < e_loc)
        A = T_loc * m.top_k
        flat_rel = jnp.where(mine, rel, e_loc).reshape(A)
        order = jnp.argsort(flat_rel, stable=True)
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[flat_rel].add(1)
        starts = jnp.cumsum(counts) - counts
        ranks = jnp.arange(A, dtype=jnp.int32) - starts[flat_rel[order]]
        pos = jnp.zeros((A,), jnp.int32).at[order].set(ranks)
        keep = mine.reshape(A) & (pos < cap)
        e_idx = jnp.where(keep, flat_rel, e_loc)
        p_idx = jnp.minimum(pos, cap - 1)

        buf = jnp.zeros((e_loc, cap, d), xt.dtype)
        buf = buf.at[e_idx, p_idx].add(
            jnp.repeat(xt, m.top_k, axis=0), mode="drop")
        out = jax.vmap(lambda w, h: apply_mlp(w, h, cfg.mlp))(experts, buf)
        flat_out = out.reshape(e_loc * cap, d)
        slot = jnp.minimum(e_idx, e_loc - 1) * cap + p_idx
        gathered = flat_out[slot].reshape(T_loc, m.top_k, d)
        w = (top_p * keep.reshape(T_loc, m.top_k)).astype(xt.dtype)
        y = jnp.einsum("tkd,tk->td", gathered, w)
        if m.n_shared:
            # shared experts: compute on model-rank 0's slice only? No —
            # replicate across ranks and divide by tp inside the psum
            sh = jax.vmap(lambda w_: apply_mlp(w_, xt, cfg.mlp))(shared)
            y = y + jnp.sum(sh, axis=0) / tp
        y = jax.lax.psum(y, "model")
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, m.n_experts), 1), 0)
        aux = m.router_aux_coef * m.n_experts * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, "model")
        for ax in bd:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    xt = x.reshape(b * s, d)
    import jax.tree_util as jtu
    experts_specs = jtu.tree_map(lambda _: P("model", None, None),
                                 p["experts"])
    shared_arg = p.get("shared") if m.n_shared else jnp.zeros(())
    shared_specs = (jtu.tree_map(lambda _: P(None, None, None), p["shared"])
                    if m.n_shared else P())
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(bd, None), P(None, None), experts_specs,
                             shared_specs),
                   out_specs=(P(bd, None), P()), check_rep=False)
    y, aux = fn(xt, p["router"], p["experts"], shared_arg)
    return y.reshape(b, s, d).astype(x.dtype), aux


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss).

    Dispatches to the shard_map expert-parallel formulation when a
    production mesh is active and the expert count divides the TP degree
    (§Perf iteration 6); otherwise the GSPMD scatter/gather path below.
    """
    from repro.sharding.hints import _active_mesh
    mesh = _active_mesh()
    tokens = x.shape[0] * x.shape[1]
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.moe.n_experts % mesh.shape["model"] == 0
            and tokens >= 4096  # tiny decode batches: expert-weight AG
                                # would dominate (measured regression)
            and tokens % max(
                int(np.prod([mesh.shape[a] for a in mesh.axis_names
                             if a in ("pod", "data")])), 1) == 0):
        return apply_moe_ep_shmap(p, x, cfg, mesh)
    return _apply_moe_gspmd(p, x, cfg)


def _apply_moe_gspmd(p, x, cfg: ModelConfig):
    """GSPMD scatter/gather dispatch (fallback path)."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = b * s
    xt = x.reshape(tokens, d)
    logits = (xt @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    top_p, top_e = jax.lax.top_k(probs, m.top_k)           # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    cap = _capacity(tokens, m)
    # position of each (token, k) assignment within its expert's capacity
    # buffer via ARGSORT over expert ids (O(A log A), A = T*k) — the
    # one-hot cumsum alternative materializes an (A, E) tensor that at
    # DeepSeek-V2 scale is a replicated ~1 GiB s32 monster plus a 1 GB
    # all-gather per layer (§Perf iteration 3, measured)
    A = tokens * m.top_k
    flat_e = top_e.reshape(A)
    order = jnp.argsort(flat_e, stable=True)                       # (A,)
    counts = jnp.zeros((m.n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                           # (E,)
    ranks_sorted = jnp.arange(A, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((A,), jnp.int32).at[order].set(ranks_sorted)
    pos = pos.reshape(tokens, m.top_k)
    keep = pos < cap                                               # (T,k)

    # scatter each kept assignment into the (E, cap, d) expert buffer with
    # 2D indices; the buffer itself is pinned to the EP axis so GSPMD
    # emits dispatch communication instead of a replicated-buffer AR
    from repro.sharding.hints import hint, hint_tokens
    e_idx = jnp.where(keep, top_e, m.n_experts).reshape(A)   # OOB = drop
    p_idx = jnp.minimum(pos, cap - 1).reshape(A)
    expert_in = hint(jnp.zeros((m.n_experts, cap, d), x.dtype),
                     "model", None, None)
    expert_in = expert_in.at[e_idx, p_idx].add(
        jnp.repeat(xt, m.top_k, axis=0), mode="drop")
    expert_in = hint(expert_in, "model", None, None)
    expert_out = jax.vmap(lambda w, h: apply_mlp(w, h, cfg.mlp))(
        p["experts"], expert_in)
    expert_out = hint(expert_out, "model", None, None)

    slot = top_e * cap + p_idx.reshape(tokens, m.top_k)
    gathered = expert_out.reshape(m.n_experts * cap, d)[
        jnp.minimum(slot, m.n_experts * cap - 1).reshape(-1)]      # (A,d)
    gathered = hint_tokens(gathered.reshape(tokens, m.top_k, d))
    w = (top_p * keep).astype(x.dtype)                             # (T,k)
    y = jnp.einsum("tkd,tk->td", gathered, w)

    if m.n_shared:
        sh = jax.vmap(lambda w: apply_mlp(w, xt, cfg.mlp))(p["shared"])
        y = y + jnp.sum(sh, axis=0)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, m.n_experts), axis=1), axis=0)
    aux = m.router_aux_coef * m.n_experts * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux
