"""Progressive graph specialization (paper §5.3-5.4, Fig 9).

From a deduced (annotated) graph, instantiate a *device-specific executable
graph* per device:

1. **Non-local operator removal** — ops whose input and output tensors never
   place data on the device are pruned.
2. **CommOp substitution** — every CommOp is resolved (§4) into concrete
   communication steps; a device keeps only the steps it participates in.
   Top-tier communication replaces the CommOp uniformly across the DG
   union; bottom-tier communication is substituted per sharding subgroup
   (Fig 9's CommOp id=2 becoming RS on GPU0 but BSR on GPU6).
3. **Pipeline construction** — devices start as singleton pipelines;
   scanning the scheduled CommOps, collective participants merge into one
   pipeline and P2P receivers append as successor stages.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .annotations import HSPMD
from .comm_resolve import resolve
from .graph import Graph, Op
from .plan import CommPlan, CommStep
from .topology import Topology, UniformTopology


@dataclass
class ResolvedComm:
    op: Op
    plan: CommPlan


@dataclass
class ExecItem:
    """One node of a device's executable graph."""

    kind: str                   # op kind, or a comm step kind (AR/RS/.../BSR)
    name: str
    role: str = "compute"       # compute | comm
    detail: str = ""
    phase: str = "fwd"          # fwd | bwd (autodiff backward extension)


@dataclass
class ExecutableGraph:
    device: int
    items: list[ExecItem] = field(default_factory=list)

    def kinds(self) -> list[str]:
        return [i.kind for i in self.items]

    def phase_items(self, phase: str) -> list[ExecItem]:
        """This device's items for one schedule phase — what a fwd/bwd
        tick of the pipeline timetable executes."""
        return [i for i in self.items if i.phase == phase]


def resolve_comm_ops(graph: Graph, strategy: int = 0,
                     topology: Topology | None = None,
                     shape_env: dict[str, int] | None = None
                     ) -> list[ResolvedComm]:
    """Apply hierarchical communication resolution to every CommOp."""
    from .symbolic import bind_shape
    topology = topology or UniformTopology()
    out = []
    for op in graph.comm_ops:
        src = op.inputs[0].annots[strategy]
        dst = op.outputs[0].annots[strategy]
        shape = op.inputs[0].shape
        if not all(isinstance(s, int) for s in shape):
            if shape_env is None:
                raise ValueError(
                    f"CommOp on {op.inputs[0].name} has symbolic shape; "
                    f"bind symbols before specialization")
            shape = bind_shape(shape, shape_env)
        plan = resolve(src, dst, tuple(int(s) for s in shape), topology)
        out.append(ResolvedComm(op, plan))
    return out


def _device_in_annots(device: int, *annots: HSPMD) -> bool:
    return any(device in a.devices for a in annots)


def specialize(graph: Graph, device: int, strategy: int = 0,
               topology: Topology | None = None,
               shape_env: dict[str, int] | None = None,
               resolved_comms: list[ResolvedComm] | None = None
               ) -> ExecutableGraph:
    """Instantiate the executable graph for one device (paper Fig 9).

    ``resolved_comms`` shares one communication resolution across the
    per-device calls (``specialize_all`` passes it).
    """
    if resolved_comms is None:
        resolved_comms = resolve_comm_ops(graph, strategy, topology,
                                          shape_env)
    resolved = {id(rc.op): rc for rc in resolved_comms}
    eg = ExecutableGraph(device)
    for op in graph.ops:
        annots = [t.annots[strategy] for t in op.inputs + op.outputs]
        if not any(device in a.devices for a in annots):
            continue  # non-local operator removal
        phase = "bwd" if op.attrs.get("phase") == "bwd" else "fwd"
        if op.kind == "comm":
            rc = resolved[id(op)]
            for stage in rc.plan.stages:
                for step in stage.steps:
                    mine = [g for g in step.groups
                            if device in g.srcs or device in g.dsts]
                    if mine or (step.kind in ("ID", "Slice")
                                and device in stage.annot_after.devices):
                        eg.items.append(ExecItem(
                            step.kind, f"comm{op.attrs['id']}", "comm",
                            f"{len(mine)} group(s)", phase))
        else:
            # compute ops run only where their OUTPUT lives
            out_annots = [t.annots[strategy] for t in op.outputs]
            if op.outputs and not _device_in_annots(device, *out_annots):
                continue
            eg.items.append(ExecItem(op.kind, op.outputs[0].name
                                     if op.outputs else op.kind,
                                     phase=phase))
    return eg


@dataclass
class SpecializationResult:
    """Stable result of progressive specialization across ALL devices —
    what ``repro.api.Program.compile`` composes into a CompiledPlan."""

    strategy: int
    devices: tuple[int, ...]
    exec_graphs: dict[int, ExecutableGraph]
    resolved: list[ResolvedComm]
    pipelines: list["Pipeline"]

    def items(self, device: int) -> list[ExecItem]:
        return self.exec_graphs[device].items


def specialize_all(graph: Graph, strategy: int = 0,
                   topology: Topology | None = None,
                   shape_env: dict[str, int] | None = None
                   ) -> SpecializationResult:
    """Specialize every participating device, sharing one communication
    resolution, and construct the pipelines (paper §5.3-5.4)."""
    resolved = resolve_comm_ops(graph, strategy, topology, shape_env)
    devices: set[int] = set()
    for t in graph.tensors.values():
        if t.annots:
            devices |= set(t.annots[strategy].devices)
    exec_graphs = {
        dev: specialize(graph, dev, strategy, topology, shape_env,
                        resolved_comms=resolved)
        for dev in sorted(devices)}
    pipelines = construct_pipelines(graph, strategy, topology=topology,
                                    shape_env=shape_env,
                                    resolved_comms=resolved)
    return SpecializationResult(strategy, tuple(sorted(devices)),
                                exec_graphs, resolved, pipelines)


# ---------------------------------------------------------------------------
# pipeline construction (paper §5.4)
# ---------------------------------------------------------------------------

@dataclass
class Pipeline:
    """An ordered list of stages; each stage is a set of devices."""

    stages: list[set[int]] = field(default_factory=list)

    def devices(self) -> set[int]:
        return set().union(*self.stages) if self.stages else set()

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def stage_of(self, dev: int) -> int | None:
        """This device's stage index (its position in the timetable the
        schedule engine builds), or None if the device is not staged."""
        for i, devs in enumerate(self.stages):
            if dev in devs:
                return i
        return None


def construct_pipelines(graph: Graph, strategy: int = 0,
                        scheduled_only: bool = True,
                        topology: Topology | None = None,
                        shape_env: dict[str, int] | None = None,
                        resolved_comms: list[ResolvedComm] | None = None
                        ) -> list[Pipeline]:
    """Step-by-step pipeline construction (Fig 9, bottom right).

    Every device starts as its own single-stage pipeline.  For each
    scheduled CommOp (one-shot CommOps — e.g. a parameter reshard that
    executes once — are excluded, mirroring the paper's exclusion of
    CommOp id=1): devices coupled by *collective* steps merge into the
    same stage; *P2P* steps append the receiver devices as a successor
    stage of the sender's pipeline.
    """
    # union-find over devices for stage merging
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: int, b: int) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[rb] = ra

    successors: list[tuple[int, int]] = []  # (src_dev, dst_dev) stage edges

    if resolved_comms is None:
        resolved_comms = resolve_comm_ops(graph, strategy, topology,
                                          shape_env)
    for rc in resolved_comms:
        op = rc.op
        # backward CommOps (activation-grad sends, parameter grad
        # reduces) mirror the forward dataflow in REVERSE — the pipeline
        # structure is defined by the forward half alone
        if op.attrs.get("phase") == "bwd":
            continue
        if scheduled_only:
            # one-shot CommOps feed parameters; scheduled ones feed
            # activations/gradients (have a compute producer upstream)
            src_t = op.inputs[0]
            if src_t.producer is not None and src_t.producer.kind == "parameter":
                continue
        for stage in rc.plan.stages:
            for step in stage.steps:
                for g in step.groups:
                    devs = set(g.srcs) | set(g.dsts)
                    if step.kind in ("AR", "RS", "AG", "SplitAR", "SplitRS",
                                     "SplitAG"):
                        devs_l = sorted(devs)
                        for d in devs_l[1:]:
                            union(devs_l[0], d)
                    else:  # SR / BSR are P2P: receiver becomes a next stage
                        for s in g.srcs:
                            for d in g.dsts:
                                if s != d:
                                    successors.append((s, d))

    all_devices = set()
    for t in graph.tensors.values():
        if t.annots:
            all_devices |= set(t.annots[strategy].devices)

    # build stages from union-find roots
    stages: dict[int, set[int]] = {}
    for d in sorted(all_devices):
        stages.setdefault(find(d), set()).add(d)

    # link stages by successor edges
    nexts: dict[int, set[int]] = {}
    has_pred: set[int] = set()
    for s, d in successors:
        rs, rd = find(s), find(d)
        if rs != rd:
            nexts.setdefault(rs, set()).add(rd)
            has_pred.add(rd)

    pipelines = []
    visited_any: set[int] = set()

    def walk(root: int) -> Pipeline:
        pipe = Pipeline()
        frontier = [root]
        seen: set[int] = set()
        while frontier:
            stage_devs = set()
            nxt = []
            for r in frontier:
                if r in seen:
                    continue
                seen.add(r)
                stage_devs |= stages[r]
                nxt.extend(sorted(nexts.get(r, ())))
            if stage_devs:
                pipe.stages.append(stage_devs)
            frontier = nxt
        visited_any.update(seen)
        return pipe

    for root in sorted(stages):
        if root in has_pred:
            continue
        pipelines.append(walk(root))
    # Interleaved dataflow (virtual stages, paper §5.4 + Megatron's
    # virtual-pipeline layout) wraps the last stage's P2P back to the
    # first, so every stage group has a predecessor and no pred-less
    # root exists.  Start such cyclic chains from the earliest P2P
    # sender in CommOp order — the stage the first microbatch enters.
    for s, _ in successors:
        rs = find(s)
        if rs in stages and rs not in visited_any:
            pipelines.append(walk(rs))
    return pipelines
