"""Hierarchical communication resolution (paper §4, Fig 4).

Given a (source, destination) :class:`HSPMD` annotation pair, derive a
:class:`CommPlan` that realizes the transformation, preferring collective
operators and falling back to batched-send-receive:

* **Bottom tier** (§4.1) — same HSize & HDim: every sharding subgroup
  resolves independently: Identity / SendRecv (DG change), AR / RS / AG
  (Partial->Dup, Partial->Split, Split->Dup), else BSR.
* **Top tier** (§4.2) — same HSize & DG Union, HDim differs:
  SplitAR / SplitRS / SplitAG over finest-grained slices; when the DS
  Union differs too, a bottom-tier alignment stage runs first (Fig 7).
* **Fallback** (§4.3) — BSR, which cannot move *Partial* tensors; such
  requests raise :class:`UnsupportedCommError` (paper's stated limit).

Groups are produced by a *fine-slice* builder that is exact for arbitrary
geometry (including bottom-tier splits along the HDim axis and non-uniform
``hsplits``); the paper's operator names are preserved in ``CommStep.kind``
for classification, statistics and cost modeling.

Gradient synchronization rides these same rules (reverse-mode autodiff,
``core.graph.backward``): parameter grads are deduced PARTIAL wherever
the forward consumed a replica, and the grad-reduce CommOp's
(Partial -> param annotation) pair resolves here to AR for replicated
params or RS over the DP dim for Split-sharded params — no
training-specific communication logic exists anywhere.
"""

from __future__ import annotations

from .annotations import DUP, PARTIAL, DS, HSPMD
from .bsr import PartialBsrError, plan_bsr
from .plan import (Box, CommPlan, CommStep, SliceGroup, box_intersect)
from .topology import Topology, UniformTopology


class UnsupportedCommError(ValueError):
    pass


def _annot_equal(a: HSPMD, b: HSPMD) -> bool:
    # exact placement equality: entry ORDER matters (it determines the
    # device -> shard coordinate decomposition)
    return (a.same_dg_union(b)
            and all(x.entries == y.entries for x, y in zip(a.dss, b.dss))
            and a.hdim == b.hdim and a.hsplits == b.hsplits)


def _summand_id(annot: HSPMD, dev: int) -> tuple[int, int]:
    """Identifies which additive summand a device's shard carries."""
    g = annot.subgroup_of(dev)
    ds = annot.dss[g]
    pos = annot.dgs[g].index(dev)
    pcoord = ds.coords(pos).get(PARTIAL, 0)
    top = g if annot.hdim == PARTIAL else -1
    return (top, pcoord)


def _bottom_pcoord(annot: HSPMD, dev: int) -> int:
    g = annot.subgroup_of(dev)
    pos = annot.dgs[g].index(dev)
    return annot.dss[g].coords(pos).get(PARTIAL, 0)


def _bottom_pdegree(annot: HSPMD, dev: int) -> int:
    g = annot.subgroup_of(dev)
    return annot.dss[g].get(PARTIAL)


def _fine_slice_groups(src: HSPMD, dst: HSPMD, shape: tuple[int, ...],
                       src_devs: tuple[int, ...], dst_devs: tuple[int, ...],
                       reduce: bool) -> tuple[SliceGroup, ...]:
    """Exact slice-group construction.

    For every receiver's destination box, refined against source shard
    boundaries: pick contributing sources (one representative per distinct
    summand when reducing, a single copy otherwise) and record the
    delivery.  Groups with identical (box, srcs) merge their dst lists.

    When the *destination* keeps a bottom-tier Partial degree (> 1), that
    partial coordinate is a **spectator**: a receiver with bottom partial
    coordinate ``p`` only accepts contributions from sources with the same
    ``p`` (a top-tier SplitAR/SplitRS/SplitAG reduces or gathers across
    subgroups, never across the surviving bottom-tier summands).
    """
    src_boxes = {d: src.device_box(d, shape) for d in src_devs}
    dst_boxes = {d: dst.device_box(d, shape) for d in dst_devs}

    cuts: list[list[int]] = []
    for dim in range(len(shape)):
        pts = set()
        for b in src_boxes.values():
            pts.update(b[dim])
        cuts.append(sorted(pts))

    acc: dict[tuple[Box, tuple[int, ...]], set[int]] = {}
    for recv, rbox in dst_boxes.items():
        dim_segs: list[list[tuple[int, int]]] = []
        for d, (lo, hi) in enumerate(rbox):
            pts = [lo] + [c for c in cuts[d] if lo < c < hi] + [hi]
            dim_segs.append(list(zip(pts[:-1], pts[1:])))

        recv_pdeg = _bottom_pdegree(dst, recv)
        recv_pc = _bottom_pcoord(dst, recv) if recv_pdeg > 1 else None

        def rec(d: int, prefix: list[tuple[int, int]]):
            if d == len(shape):
                cell = tuple(prefix)
                owners = [dev for dev, b in src_boxes.items()
                          if box_intersect(b, cell) == cell]
                if recv_pc is not None:
                    # spectator bottom-partial: only same-summand sources
                    owners = [o for o in owners
                              if _bottom_pcoord(src, o) == recv_pc]
                if not owners:
                    raise UnsupportedCommError(f"no source owner for {cell}")
                if reduce:
                    by_sid: dict[tuple[int, int], int] = {}
                    for dev in owners:
                        by_sid.setdefault(_summand_id(src, dev), dev)
                    srcs = tuple(sorted(by_sid.values()))
                else:
                    if recv in owners:
                        return  # heuristic (I): local copy, zero traffic
                    if any(_bottom_pdegree(src, o) > 1 for o in owners) \
                            and recv_pc is None:
                        raise UnsupportedCommError(
                            "copying Partial shards into a non-Partial "
                            "destination requires a reduction")
                    srcs = (min(owners),)
                acc.setdefault((cell, srcs), set()).add(recv)
                return
            for seg in dim_segs[d]:
                rec(d + 1, prefix + [seg])

        rec(0, [])
    return tuple(SliceGroup(box, srcs, tuple(sorted(dsts)), reduce)
                 for (box, srcs), dsts in sorted(acc.items()))


# ---------------------------------------------------------------------------
# bottom tier (§4.1)
# ---------------------------------------------------------------------------

def _sr_pairs(sds: DS, dds: DS, sdg, ddg) -> list[tuple[int, int]]:
    """Positional matching by shard *coordinates* (robust to DS entry-order
    permutations): returns (src_dev, dst_dev) pairs that differ."""
    src_by_coord = {tuple(sorted(sds.coords(p).items())): sdg[p]
                    for p in range(len(sdg))}
    pairs = []
    for q in range(len(ddg)):
        key = tuple(sorted(dds.coords(q).items()))
        s = src_by_coord[key]
        if s != ddg[q]:
            pairs.append((s, ddg[q]))
    return pairs


def _classify_bottom(sds: DS, dds: DS, sdg, ddg) -> str:
    """Paper Fig 4/5 bottom-tier classification for one subgroup."""
    if sds.same_sharding(dds):
        return "ID" if not _sr_pairs(sds, dds, sdg, ddg) else "SR"
    if sdg.devices != ddg.devices:
        return "BSR"
    sm, dm = dict(sds.entries), dict(dds.entries)
    sp, dp = sm.get(PARTIAL, 1), dm.get(PARTIAL, 1)
    sdup, ddup = sm.get(DUP, 1), dm.get(DUP, 1)
    s_splits = {d: n for d, n in sm.items() if d >= 0}
    d_splits = {d: n for d, n in dm.items() if d >= 0}
    if sp > 1 and dp == 1:
        if d_splits == s_splits and ddup == sdup * sp:
            return "AR"                      # Partial -> Duplicate
        grown = {d: n for d, n in d_splits.items()
                 if n != s_splits.get(d, 1)}
        if (ddup == sdup and len(grown) == 1):
            d, n = next(iter(grown.items()))
            if n == s_splits.get(d, 1) * sp and all(
                    d_splits.get(k, 1) == v for k, v in s_splits.items() if k != d):
                return "RS"                  # Partial -> Split(d)
    if sp == 1 and dp == 1:
        shrunk = {d: n for d, n in s_splits.items()
                  if d_splits.get(d, 1) < n and d_splits.get(d, 1) == 1}
        if len(shrunk) == 1:
            d, n = next(iter(shrunk.items()))
            if ddup == sdup * n and all(
                    d_splits.get(k, 1) == v for k, v in s_splits.items() if k != d):
                return "AG"                  # Split(d) -> Duplicate
    return "BSR"


def _bottom_plan(src: HSPMD, dst: HSPMD, shape, topology, itemsize) -> CommPlan:
    plan = CommPlan(src=src, dst=dst)
    kinds: dict[str, list[SliceGroup]] = {}
    labels = []
    for i in range(src.hsize):
        kind = _classify_bottom(src.dss[i], dst.dss[i], src.dgs[i], dst.dgs[i])
        labels.append(kind)
        if kind == "ID":
            continue
        if kind == "SR":
            groups = [
                SliceGroup(src.device_box(s, shape), (s,), (d,))
                for s, d in _sr_pairs(src.dss[i], dst.dss[i],
                                      src.dgs[i], dst.dgs[i])]
            kinds.setdefault("SR", []).extend(groups)
            continue
        if kind == "BSR" and (src.dss[i].has_partial or dst.dss[i].has_partial):
            raise UnsupportedCommError(
                f"subgroup {i}: Partial repartition not expressible as "
                f"collective and BSR cannot move Partial "
                f"({src.dss[i]} -> {dst.dss[i]})")
        reduce = src.dss[i].has_partial
        groups = _fine_slice_groups(
            src, dst, shape, src.dgs[i].devices, dst.dgs[i].devices, reduce)
        kinds.setdefault(kind, []).extend(groups)
    steps = [CommStep(kind, tuple(groups))
             for kind, groups in kinds.items() if groups]
    plan.add(steps or CommStep("ID", ()), dst)
    plan.kind = "bottom:" + "+".join(sorted(set(labels)))
    return plan


# ---------------------------------------------------------------------------
# top tier (§4.2)
# ---------------------------------------------------------------------------

def _classify_top(src: HSPMD, dst: HSPMD) -> str:
    if src.hdim == PARTIAL and dst.hdim == DUP:
        return "SplitAR"
    if src.hdim == PARTIAL and dst.hdim >= 0:
        return "SplitRS"
    if src.hdim >= 0 and dst.hdim == DUP:
        return "SplitAG"
    if src.hdim == DUP and dst.hdim >= 0:
        return "Slice"  # local slab extraction, zero communication
    return "BSR"


def _top_step(src: HSPMD, dst: HSPMD, shape, plan: CommPlan) -> str:
    kind = _classify_top(src, dst)
    if kind == "BSR":
        if src.has_partial or dst.has_partial:
            raise UnsupportedCommError(
                f"top-tier hdim {src.hdim}->{dst.hdim} with Partial")
        groups = _fine_slice_groups(src, dst, shape, src.devices,
                                    dst.devices, reduce=False)
        plan.add(CommStep("BSR", groups), dst)
        return kind
    if kind == "Slice":
        # zero-comm only when every device's dst box is inside its src box
        # (e.g. bottom tier doesn't split the hdim axis); otherwise shards
        # must move: fall back to BSR geometry.
        from .plan import box_contains
        contained = all(
            box_contains(src.device_box(d, shape), dst.device_box(d, shape))
            for d in dst.devices)
        if contained:
            plan.add(CommStep("Slice", ()), dst)
            return kind
        if src.has_partial or dst.has_partial:
            raise UnsupportedCommError(
                "hdim Dup->Split with Partial shards requires data movement "
                "that BSR cannot express")
        groups = _fine_slice_groups(src, dst, shape, src.devices,
                                    dst.devices, reduce=False)
        plan.add(CommStep("BSR", groups), dst)
        return "BSR"
    reduce = src.hdim == PARTIAL
    groups = _fine_slice_groups(src, dst, shape, src.devices, dst.devices,
                                reduce)
    plan.add(CommStep(kind, groups), dst)
    return kind


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def resolve(src: HSPMD, dst: HSPMD, shape: tuple[int, ...],
            topology: Topology | None = None, itemsize: int = 2) -> CommPlan:
    """Derive a communication plan transforming ``src`` into ``dst``."""
    topology = topology or UniformTopology()
    if _annot_equal(src, dst):
        plan = CommPlan(src=src, dst=dst, kind="identity")
        plan.add(CommStep("ID", ()), dst)
        return plan

    same_top = (src.hsize == dst.hsize and src.hdim == dst.hdim
                and src.hsplits == dst.hsplits)
    if same_top:
        return _bottom_plan(src, dst, shape, topology, itemsize)

    if src.hsize == dst.hsize and src.same_dg_union(dst):
        plan = CommPlan(src=src, dst=dst)
        if src.same_ds_union(dst):
            kind = _top_step(src, dst, shape, plan)
            plan.kind = f"top:{kind}"
            return plan
        # Fig 7: bottom-tier DS alignment first, then the top-tier op
        mid = HSPMD(src.dgs, dst.dss, src.hdim, src.hsplits)
        bottom = _bottom_plan(src, mid, shape, topology, itemsize)
        for stage in bottom.stages:
            real = [s for s in stage.steps if s.kind != "ID"]
            if real:
                plan.add(real, stage.annot_after)
        kind = _top_step(mid, dst, shape, plan)
        plan.kind = f"{bottom.kind}>top:{kind}"
        return plan

    # DG Unions differ or HSize differs -> BSR fallback (§4.3)
    if src.has_partial or dst.has_partial:
        raise UnsupportedCommError(
            "cross-union repartition of Partial tensors is unsupported "
            "(paper §4.3 Discussions)")
    bplan = plan_bsr(src, dst, shape, topology, itemsize=itemsize)
    plan = CommPlan(src=src, dst=dst, kind="fallback:BSR")
    plan.add(bplan.to_step(), dst)
    return plan
