"""Analytical cluster cost model (drives the Fig 13/14/15 reproductions).

The container is CPU-only, so the paper's wall-clock cluster numbers are
reproduced with a calibrated analytical model over the paper's own
hardware table (Appendix A.1):

  H800: 990 TFLOPS bf16, 80 GB, 400 GB/s NVLink
  H20:  148 TFLOPS bf16, 96 GB, 900 GB/s NVLink
  inter-node: IB (25 GB/s per GPU)

A *strategy* is a set of pipelines; each pipeline is a list of stages;
each stage owns a device group (TP applied inside), a layer range and a
micro-batch schedule.  This mirrors the paper's Appendix A.2/A.3 strategy
tables, which are encoded verbatim as fixtures in the benchmarks.

Per-step time =
  the PRICED pipeline timetable (1F1B or GPipe; the executable tick
    table from ``core.schedule`` re-timed under per-(stage, phase)
    durations — ``stage_tick_times`` — so non-uniform stage splits are
    scored by the schedule they'd run; uniform stages keep the
    ``fill_drain_count`` closed form, asserted equal)
  + cross-pipeline gradient sync (heterogeneous DP -> SplitAR over the
    HSPMD annotations, costed per link)
and per-stage microbatch time =
  max over stage devices of (stage FLOPs / (TP x device FLOPS x MFU))
  + TP collective time (2 AR of activation bytes per layer over the
    group's NVLink) + P2P stage-boundary transfer.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class DeviceType:
    name: str
    tflops: float          # bf16 peak
    mem_gb: float
    nvlink_gbps: float


H800 = DeviceType("H800", 990.0, 80.0, 400.0)
H20 = DeviceType("H20", 148.0, 96.0, 900.0)
IB_GBPS = 25.0
MFU = 0.45                  # calibrated utilization factor


@dataclass(frozen=True)
class ClusterSpec:
    """rank -> device type; node = 8 consecutive ranks."""

    ranks: tuple[DeviceType, ...]

    def node_of(self, r: int) -> int:
        return r // 8

    def link_gbps(self, a: int, b: int) -> float:
        if self.node_of(a) == self.node_of(b):
            return min(self.ranks[a].nvlink_gbps, self.ranks[b].nvlink_gbps)
        return IB_GBPS


def paper_cluster(n_h800: int = 16, n_h20: int = 32) -> ClusterSpec:
    return ClusterSpec(tuple([H800] * n_h800 + [H20] * n_h20))


@dataclass(frozen=True)
class ModelSpec:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int = 32000

    @property
    def params_per_layer(self) -> float:
        return 4 * self.d_model ** 2 + 3 * self.d_model * self.d_ff

    @property
    def total_params(self) -> float:
        return (self.n_layers * self.params_per_layer
                + 2 * self.vocab * self.d_model)

    def layer_flops(self, tokens: int, seq_len: int) -> float:
        """fwd+bwd FLOPs for one layer over `tokens` tokens."""
        dense = 6 * self.params_per_layer * tokens
        attn = 12 * self.d_model * tokens * seq_len  # score+value matmuls
        return dense + attn

    @classmethod
    def from_config(cls, cfg) -> "ModelSpec":
        """Bridge a ``models.config.ModelConfig`` (the named ``configs/``
        pool — what ``Graph.transformer_block`` builds from) into the
        analytic cost model, so compiled graph-IR strategies and the
        Appendix A strategy tables price the same architectures."""
        return cls(cfg.name, cfg.n_layers, cfg.d_model, cfg.d_ff,
                   vocab=cfg.vocab)


LLAMA_32B = ModelSpec("llama-32b", 60, 6656, 17920)
LLAMA_70B = ModelSpec("llama-70b", 80, 8192, 28672)


@dataclass(frozen=True)
class Stage:
    ranks: tuple[int, ...]       # TP group (all compute every layer)
    layers: tuple[int, int]      # [lo, hi) layer ids

    @property
    def tp(self) -> int:
        return len(self.ranks)

    @property
    def n_layers(self) -> int:
        return self.layers[1] - self.layers[0]


@dataclass(frozen=True)
class PipelineSpec:
    stages: tuple[Stage, ...]
    n_micro: int
    micro_bs: int               # sequences per microbatch


@dataclass(frozen=True)
class Strategy:
    pipelines: tuple[PipelineSpec, ...]
    schedule: str = "1f1b"      # or "gpipe"
    zero1: bool = True

    def device_count(self) -> int:
        return sum(len(s.ranks) for p in self.pipelines for s in p.stages)


def stage_micro_time(cluster: ClusterSpec, model: ModelSpec, st: Stage,
                     micro_tokens: int, seq_len: int) -> float:
    """Seconds for one microbatch fwd+bwd through one stage."""
    flops = model.layer_flops(micro_tokens, seq_len) * st.n_layers
    slowest = min(cluster.ranks[r].tflops for r in st.ranks)
    t_comp = flops / (st.tp * slowest * 1e12 * MFU)
    if st.tp > 1:
        # Megatron TP: 4 collectives (fwd+bwd) of activation size per layer
        act_bytes = 2 * micro_tokens * model.d_model
        link = min(cluster.link_gbps(st.ranks[0], r) for r in st.ranks[1:])
        t_tp = st.n_layers * 4 * act_bytes * (st.tp - 1) / st.tp \
            / (link * 1e9)
    else:
        t_tp = 0.0
    return t_comp + t_tp


def fill_drain_count(n_micro: int, n_stages: int) -> int:
    """The 1F1B/GPipe fill+steady+drain slot count ``(m + s - 1)`` —
    the same shape the schedule engine's timetables span
    (``core.schedule.build_schedule(...).fill_drain_slots``), kept as one
    definition so the analytic model and the executable schedules cannot
    drift.  Exact only for UNIFORM stage costs; non-uniform stages are
    priced by the executable timetable itself (``pipeline_time`` →
    ``core.schedule.price_schedule``)."""
    return n_micro + n_stages - 1


# fwd : bwd tick split of one stage-microbatch (bwd recomputes the two
# matmul operands -> the canonical 1:2 ratio); the priced makespan of a
# uniform pipeline is invariant to this split (the critical path holds
# fill-count fwd ticks AND fill-count bwd ticks), so the uniform closed
# form stays exact for any fraction.  This is the analytic FALLBACK:
# compiled plans over a differentiated graph measure the real ratio
# from per-phase op FLOPs (``measured_fwd_fraction`` /
# ``CompiledPlan.tick_durations``) and pass it via ``fwd_fraction=``.
FWD_TIME_FRACTION = 1.0 / 3.0


def stage_tick_times(cluster: ClusterSpec, model: ModelSpec, st: Stage,
                     micro_tokens: int, seq_len: int,
                     fwd_fraction: float | None = None
                     ) -> tuple[float, float]:
    """(fwd, bwd) seconds of one microbatch through one stage — the
    non-uniform tick durations the schedule engine prices.
    ``fwd_fraction`` overrides the analytic 1:2 split (e.g. the ratio
    measured from a differentiated graph's real FLOPs)."""
    f = FWD_TIME_FRACTION if fwd_fraction is None else fwd_fraction
    t = stage_micro_time(cluster, model, st, micro_tokens, seq_len)
    return t * f, t * (1.0 - f)


# ---------------------------------------------------------------------------
# measured tick durations from a differentiated graph (autodiff-aware)
# ---------------------------------------------------------------------------

def graph_phase_flops(graph, strategy: int, pipelines,
                      virtual_stages_per_device: int,
                      shapes) -> dict[tuple[int, str], float]:
    """``(virtual stage, phase) -> FLOPs`` of one step, counted from the
    graph's REAL ops: forward ops land in their assigned (virtual)
    stage's ``fwd`` slot, autodiff backward ops in their anchor stage's
    ``bwd`` slot.  This is what replaces the hardcoded fwd:bwd = 1:2
    split once the graph IR carries a backward pass, and it prices each
    interleave CHUNK by its own op count (chunks no longer share their
    physical stage's pricing)."""
    from . import op_semantics
    from .schedule import assign_stages

    stage_of = assign_stages(graph, strategy, pipelines,
                             virtual_stages_per_device)
    n_stages = max((p.n_stages for p in pipelines), default=1)
    out: dict[tuple[int, str], float] = {
        (s, ph): 0.0
        for s in range(n_stages * virtual_stages_per_device)
        for ph in ("fwd", "bwd")}
    for op in graph.ops:
        if op.kind in ("placeholder", "parameter", "comm"):
            continue
        phase = "bwd" if op.attrs.get("phase") == "bwd" else "fwd"
        fl = op_semantics.flops(
            op.kind, [shapes[t.name] for t in op.inputs],
            shapes[op.outputs[0].name], op.attrs)
        out[(stage_of[id(op)], phase)] += fl
    return out


def graph_tick_durations(graph, strategy: int, pipelines,
                         virtual_stages_per_device: int, shapes,
                         flops_per_second: float = 1e12
                         ) -> dict[tuple[int, str], float]:
    """Per-(virtual stage, phase) tick seconds MEASURED from the graph's
    own op FLOPs, for ``core.schedule.price_schedule``.  Every slot is
    present (zero-cost phases price as 0.0 — e.g. ``bwd`` ticks of a
    forward-only graph)."""
    return {k: v / flops_per_second
            for k, v in graph_phase_flops(
                graph, strategy, pipelines,
                virtual_stages_per_device, shapes).items()}


def measured_fwd_fraction(graph, strategy: int, pipelines,
                          virtual_stages_per_device: int, shapes
                          ) -> float:
    """The fwd share of one step's compute FLOPs, measured from a
    differentiated graph (falls back to :data:`FWD_TIME_FRACTION` for
    forward-only graphs, whose bwd FLOPs are zero)."""
    fl = graph_phase_flops(graph, strategy, pipelines,
                           virtual_stages_per_device, shapes)
    fwd = sum(v for (s, ph), v in fl.items() if ph == "fwd")
    bwd = sum(v for (s, ph), v in fl.items() if ph == "bwd")
    if bwd <= 0.0:
        return FWD_TIME_FRACTION
    return fwd / (fwd + bwd)


def _stage_p2p_times(cluster: ClusterSpec, model: ModelSpec,
                     p: PipelineSpec, seq_len: int) -> list[float]:
    """Per-boundary activation transfer seconds for one microbatch."""
    micro_tokens = p.micro_bs * seq_len
    out = []
    for a, b in zip(p.stages[:-1], p.stages[1:]):
        act_bytes = 2 * micro_tokens * model.d_model
        link = cluster.link_gbps(a.ranks[-1], b.ranks[0])
        out.append(act_bytes / (link * 1e9))
    return out


def pipeline_tick_durations(cluster: ClusterSpec, model: ModelSpec,
                            p: PipelineSpec, seq_len: int, *,
                            virtual_stages_per_device: int = 1,
                            fwd_fraction: float | None = None
                            ) -> dict[tuple[int, str], float]:
    """``(virtual stage, phase) -> seconds`` for
    ``core.schedule.price_schedule``.

    Per stage, the steady-state slot must cover both the stage's compute
    and the slowest stage-boundary transfer it has to hide (the schedule
    overlaps sends with the next microbatch's compute), so each tick is
    ``max(stage time, slowest boundary) * phase fraction``.

    With ``virtual_stages_per_device = v > 1`` (Megatron interleaving)
    each physical stage's layers split evenly across its ``v`` chunks,
    so chunk ticks cost ``1/v`` of the stage's compute — per-CHUNK
    pricing instead of chunks inheriting their stage's full cost, which
    is what gives interleaved schedules their genuine ~1/v fill/drain
    advantage when priced.  ``fwd_fraction`` overrides the analytic
    fwd:bwd = 1:2 split (pass a ratio measured from the differentiated
    graph, :func:`measured_fwd_fraction`)."""
    f = FWD_TIME_FRACTION if fwd_fraction is None else fwd_fraction
    v = virtual_stages_per_device
    micro_tokens = p.micro_bs * seq_len
    p2p_max = max(_stage_p2p_times(cluster, model, p, seq_len), default=0.0)
    out: dict[tuple[int, str], float] = {}
    n_stages = len(p.stages)
    for s, st in enumerate(p.stages):
        slot = max(stage_micro_time(cluster, model, st, micro_tokens,
                                    seq_len) / v, p2p_max)
        for c in range(v):
            out[(c * n_stages + s, "fwd")] = slot * f
            out[(c * n_stages + s, "bwd")] = slot * (1.0 - f)
    return out


def pipeline_tick_split(cluster: ClusterSpec, model: ModelSpec,
                        p: PipelineSpec, seq_len: int, *,
                        virtual_stages_per_device: int = 1,
                        fwd_fraction: float | None = None
                        ) -> tuple[dict[tuple[int, str], float],
                                   dict[tuple[int, str], float]]:
    """Split each tick of :func:`pipeline_tick_durations` into its
    ``(compute, comm)`` components for overlap-aware pricing.

    The sync tick ``max(stage/v, p2p_max) * frac`` is decomposed as
    ``compute = (stage/v) * frac`` and ``comm = (slot - stage/v) *
    frac`` — the boundary-transfer time the sync slot serializes on top
    of compute.  By construction ``compute + comm`` equals the sync
    duration exactly, so ``price_schedule(sched, compute, comm=comm)``
    reproduces today's sync makespan bit-for-bit, and since
    ``max(compute, comm) <= compute + comm`` the overlap-priced makespan
    of the same split can never be worse."""
    f = FWD_TIME_FRACTION if fwd_fraction is None else fwd_fraction
    v = virtual_stages_per_device
    micro_tokens = p.micro_bs * seq_len
    p2p_max = max(_stage_p2p_times(cluster, model, p, seq_len), default=0.0)
    comp: dict[tuple[int, str], float] = {}
    comm: dict[tuple[int, str], float] = {}
    n_stages = len(p.stages)
    for s, st in enumerate(p.stages):
        t_stage = stage_micro_time(cluster, model, st, micro_tokens,
                                   seq_len) / v
        hidden = max(t_stage, p2p_max) - t_stage
        for c in range(v):
            for phase, frac in (("fwd", f), ("bwd", 1.0 - f)):
                comp[(c * n_stages + s, phase)] = t_stage * frac
                comm[(c * n_stages + s, phase)] = hidden * frac
    return comp, comm


def pipeline_time(cluster: ClusterSpec, model: ModelSpec, p: PipelineSpec,
                  seq_len: int, kind: str = "1f1b", *,
                  virtual_stages_per_device: int = 1,
                  fwd_fraction: float | None = None,
                  overlap: bool = False) -> float:
    """Seconds for one step of one pipeline, priced from the executable
    timetable: ``core.schedule.build_schedule`` emits the 1F1B/GPipe/
    interleaved tick table the executors would run and
    ``price_schedule`` re-times it under the per-(virtual stage, phase)
    durations above, so heterogeneous stage splits are scored by the
    schedule they'd actually execute (a non-bottleneck fill ramp no
    longer pays bottleneck price).  The fill ramp additionally pays each
    boundary's latency once, when the first microbatch traverses the
    pipeline.

    ``kind="interleaved"`` with ``virtual_stages_per_device = v > 1``
    prices Megatron's virtual-stage timetable under PER-CHUNK tick
    durations (each chunk carries ``1/v`` of its stage's layers), so
    interleaving shows its real ~``1/v`` bubble advantage; at ``v=1``
    it degenerates to the 1F1B table.  ``fwd_fraction`` overrides the
    analytic 1:2 fwd:bwd split with a measured ratio.

    Uniform stage costs (v=1) keep the closed-form fast path
    ``fill_drain_count(m, S) * slot + sum(p2p)`` — asserted equal to the
    priced timetable, so the two definitions cannot drift.

    ``overlap=True`` prices the timetable as the async executor runs
    it: each tick's duration is split into compute and the boundary
    transfer it hides (:func:`pipeline_tick_split`) and the tick costs
    ``max(compute, comm)`` instead of their sum.  The fill-ramp latency
    term is unchanged — overlap hides steady-state transfers behind the
    next microbatch's compute but cannot hide the first microbatch's
    traversal.  Overlap pricing of a pipeline is never worse than sync
    pricing (same split, ``max <= sum`` per tick).
    """
    from .schedule import build_schedule, price_schedule

    if kind not in ("1f1b", "gpipe", "interleaved"):
        raise ValueError(f"unknown schedule kind {kind!r} "
                         f"(have: 1f1b, gpipe, interleaved)")
    v = virtual_stages_per_device
    if v < 1:
        raise ValueError(f"virtual_stages_per_device must be >= 1 "
                         f"(got {v})")
    if v > 1 and kind != "interleaved":
        raise ValueError(
            f"virtual_stages_per_device={v} requires kind='interleaved' "
            f"(got {kind!r})")
    f = FWD_TIME_FRACTION if fwd_fraction is None else fwd_fraction
    micro_tokens = p.micro_bs * seq_len
    times = [stage_micro_time(cluster, model, st, micro_tokens, seq_len)
             for st in p.stages]
    p2p_each = _stage_p2p_times(cluster, model, p, seq_len)
    p2p_max = max(p2p_each, default=0.0)

    def t_priced() -> float:
        if overlap:
            durations, comm = pipeline_tick_split(
                cluster, model, p, seq_len, virtual_stages_per_device=v,
                fwd_fraction=f)
        else:
            durations = pipeline_tick_durations(
                cluster, model, p, seq_len, virtual_stages_per_device=v,
                fwd_fraction=f)
            comm = None
        if kind == "interleaved" and v > 1:
            sched = build_schedule(len(p.stages), p.n_micro,
                                   "interleaved",
                                   virtual_stages_per_device=v)
            # each of the first microbatch's v ring traversals pays the
            # boundary latencies once
            return price_schedule(sched, durations, comm=comm,
                                  overlap=overlap).makespan \
                + v * sum(p2p_each)
        sched = build_schedule(len(p.stages), p.n_micro,
                               "gpipe" if kind == "gpipe" else "1f1b")
        return price_schedule(sched, durations, comm=comm,
                              overlap=overlap).makespan + sum(p2p_each)

    if overlap:
        # the closed-form fast path encodes the SYNC slot; overlap
        # pricing must go through the timetable
        return t_priced()
    if v == 1 and all(t == times[0] for t in times[1:]):  # uniform fast path
        slot = max([times[0]] + p2p_each)
        t_uniform = fill_drain_count(p.n_micro, len(p.stages)) * slot \
            + sum(p2p_each)
        # assertion-only pricing: the O(m*S) tick table is built solely
        # to pin uniform == priced (also regression-tested), and is
        # skipped entirely under python -O
        assert math.isclose(t_priced(), t_uniform, rel_tol=1e-9)
        return t_uniform
    return t_priced()


def dp_sync_time(cluster: ClusterSpec, model: ModelSpec,
                 strat: Strategy) -> float:
    """Cross-pipeline gradient synchronization (hetero DP -> SplitAR).

    Ring all-reduce cost over the per-layer owner groups: each parameter
    byte crosses the slowest link 2(n-1)/n times.
    """
    if len(strat.pipelines) <= 1:
        return 0.0
    total = 0.0
    n_layers = model.n_layers
    for layer in range(n_layers):
        owners = []
        for p in strat.pipelines:
            for st in p.stages:
                if st.layers[0] <= layer < st.layers[1]:
                    owners.append(st)
        if len(owners) <= 1:
            continue
        grad_bytes = model.params_per_layer * 2  # bf16 grads
        ranks = [r for st in owners for r in st.ranks]
        link = min(cluster.link_gbps(a, b)
                   for a in ranks for b in ranks if a != b)
        n = len(owners)
        shard = grad_bytes / max(min(st.tp for st in owners), 1)
        total += 2 * (n - 1) / n * shard / (link * 1e9)
    return total


def step_time(cluster: ClusterSpec, model: ModelSpec, strat: Strategy,
              seq_len: int, *, virtual_stages_per_device: int = 1,
              fwd_fraction: float | None = None,
              overlap: bool = False) -> float:
    """One training step: slowest pipeline + cross-pipeline grad sync.

    ``fwd_fraction`` (the candidate-facing pricing hook used by the
    search subsystem) re-splits each tick's fwd/bwd durations by a
    measured ratio instead of the analytic :data:`FWD_TIME_FRACTION`;
    ``virtual_stages_per_device > 1`` prices the interleaved timetable;
    ``overlap=True`` prices pipelines under the async executor's
    comm/compute overlap (never worse than sync pricing).
    """
    kind = ("interleaved" if virtual_stages_per_device > 1
            else strat.schedule)
    t_pipe = max(pipeline_time(
        cluster, model, p, seq_len, kind=kind,
        virtual_stages_per_device=virtual_stages_per_device,
        fwd_fraction=fwd_fraction, overlap=overlap)
        for p in strat.pipelines)
    return t_pipe + dp_sync_time(cluster, model, strat)


def memory_per_rank(model: ModelSpec, strat: Strategy) -> dict[int, float]:
    """GB of weights(+grads+opt) per rank under the strategy."""
    out: dict[int, float] = {}
    n_dp = len(strat.pipelines)
    for p in strat.pipelines:
        for st in p.stages:
            params = model.params_per_layer * st.n_layers / st.tp
            bytes_per_param = 2 + 2 + (12 / n_dp if strat.zero1 else 12)
            for r in st.ranks:
                out[r] = out.get(r, 0.0) + params * bytes_per_param / 1e9
    return out


def feasible(cluster: ClusterSpec, model: ModelSpec,
             strat: Strategy) -> bool:
    for r, gb in memory_per_rank(model, strat).items():
        if gb > cluster.ranks[r].mem_gb * 0.85:
            return False
    return True


# ---------------------------------------------------------------------------
# simple homogeneous strategy builder (the DeepSpeed/Megatron baselines)
# ---------------------------------------------------------------------------

def uniform_strategy(ranks: list[int], model: ModelSpec, *, dp: int, tp: int,
                     pp: int, global_batch: int, micro_bs: int = 1,
                     zero1: bool = True) -> Strategy:
    assert len(ranks) == dp * tp * pp, (len(ranks), dp, tp, pp)
    per_stage = model.n_layers // pp
    pipelines = []
    idx = 0
    for d in range(dp):
        stages = []
        for s in range(pp):
            grp = tuple(ranks[idx:idx + tp])
            idx += tp
            lo = s * per_stage
            hi = model.n_layers if s == pp - 1 else (s + 1) * per_stage
            stages.append(Stage(grp, (lo, hi)))
        n_micro = max(global_batch // dp // micro_bs, 1)
        pipelines.append(PipelineSpec(tuple(stages), n_micro, micro_bs))
    return Strategy(tuple(pipelines), zero1=zero1)


def best_uniform(cluster: ClusterSpec, model: ModelSpec, ranks: list[int],
                 global_batch: int, seq_len: int) -> tuple[Strategy, float]:
    """Grid-search the best homogeneous strategy (the baselines' tuner)."""
    best = None
    n = len(ranks)
    for tp in (1, 2, 4, 8):
        for pp in (1, 2, 3, 4, 5, 6, 8):
            if n % (tp * pp):
                continue
            dp = n // (tp * pp)
            if model.n_layers < pp or global_batch % dp:
                continue
            for mbs in (1, 2, 4):
                if (global_batch // dp) % mbs:
                    continue
                st = uniform_strategy(ranks, model, dp=dp, tp=tp, pp=pp,
                                      global_batch=global_batch,
                                      micro_bs=mbs)
                if not feasible(cluster, model, st):
                    continue
                t = step_time(cluster, model, st, seq_len)
                if best is None or t < best[1]:
                    best = (st, t)
    if best is None:
        raise RuntimeError("no feasible uniform strategy")
    return best
