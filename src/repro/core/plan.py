"""Communication plan IR produced by hierarchical resolution (paper §4).

A plan is an ordered list of *stages*; each stage is one :class:`CommStep`
(a set of independent device groups that would run concurrently on a real
cluster) together with the annotation that holds after the stage.

All geometry is expressed in *global* tensor coordinates so the simulator,
the JAX executor and the cost model share one language.

The unifying primitive is the :class:`SliceGroup`: a global box, the
devices contributing it (summands when ``reduce`` else identical copies)
and the devices that must hold it afterwards.  Every operator in the
paper's Fig 4 decision tree lowers onto it:

  kind        paper op                  group structure
  ---------   -----------------------   -------------------------------
  ``ID``      identity                  (no groups)
  ``SR``      send-receive              ({src} -> {dst}) per pair
  ``AR``      all-reduce                (G -> G, reduce) per box
  ``RS``      reduce-scatter            (G -> {g_i}, reduce) per sub-box
  ``AG``      all-gather                ({g_i} -> G) per owned piece
  ``SplitAR`` split-all-reduce          cross-subgroup fine-slice AR
  ``SplitRS`` split-reduce-scatter      cross-subgroup fine-slice reduce
  ``SplitAG`` split-all-gather          cross-subgroup fine-slice gather
  ``BSR``     batched-send-receive      ({chosen_src} -> {dst}) per slice

Keeping the paper's operator *names* in ``kind`` preserves the
classification (bottom-tier orange vs top-tier blue in Fig 4) for
reporting and cost modeling, while the executor stays uniform.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .annotations import HSPMD

Box = tuple[tuple[int, int], ...]

BOTTOM_KINDS = ("ID", "SR", "AR", "RS", "AG")
TOP_KINDS = ("SplitAR", "SplitRS", "SplitAG")


def box_shape(box: Box) -> tuple[int, ...]:
    return tuple(hi - lo for lo, hi in box)


def box_numel(box: Box) -> int:
    n = 1
    for lo, hi in box:
        n *= hi - lo
    return n


def box_nbytes(box: Box, itemsize: int = 2) -> int:
    return box_numel(box) * itemsize


def box_intersect(a: Box, b: Box) -> Box | None:
    out = []
    for (alo, ahi), (blo, bhi) in zip(a, b):
        lo, hi = max(alo, blo), min(ahi, bhi)
        if lo >= hi:
            return None
        out.append((lo, hi))
    return tuple(out)


def box_contains(outer: Box, inner: Box) -> bool:
    return all(olo <= ilo and ihi <= ohi
               for (olo, ohi), (ilo, ihi) in zip(outer, inner))


def rel_slices(outer: Box, inner: Box) -> tuple[slice, ...]:
    """Slices addressing ``inner`` within a local array laid out as ``outer``."""
    return tuple(slice(ilo - olo, ihi - olo)
                 for (olo, _), (ilo, ihi) in zip(outer, inner))


@dataclass(frozen=True)
class SliceGroup:
    box: Box
    srcs: tuple[int, ...]
    dsts: tuple[int, ...]
    reduce: bool = False


@dataclass(frozen=True)
class CommStep:
    kind: str
    groups: tuple[SliceGroup, ...]

    def nbytes_moved(self, itemsize: int = 2) -> int:
        """Bytes crossing device boundaries (copies to self are free)."""
        total = 0
        for g in self.groups:
            nb = box_nbytes(g.box, itemsize)
            if g.reduce:
                # ring cost proxy: every non-root contribution moves once,
                # plus fan-out to every dst that is not a src
                total += nb * (len(g.srcs) - 1)
                total += nb * len([d for d in g.dsts if d not in g.srcs])
            else:
                for d in g.dsts:
                    if d not in g.srcs:
                        total += nb
        return total


@dataclass(frozen=True)
class Stage:
    """Steps that run concurrently (they touch disjoint device groups),
    followed by the annotation that holds once the stage completes."""

    steps: tuple[CommStep, ...]
    annot_after: HSPMD


@dataclass
class CommPlan:
    """Resolution result: ordered stages + bookkeeping.

    Each stage may carry several parallel steps (e.g. subgroup 0 does an
    AR while subgroup 1 does an AG — paper Fig 9's CommOp id=2); the final
    stage's annotation always equals the requested destination.
    """

    src: HSPMD | None = None
    dst: HSPMD | None = None
    stages: list[Stage] = field(default_factory=list)
    kind: str = ""  # classification label, e.g. "bottom:AR", "top:SplitAG+RS"

    def add(self, steps: CommStep | Sequence[CommStep],
            annot_after: HSPMD) -> None:
        if isinstance(steps, CommStep):
            steps = (steps,)
        self.stages.append(Stage(tuple(steps), annot_after))

    @property
    def steps(self) -> list[CommStep]:
        return [s for st in self.stages for s in st.steps]

    @property
    def annots(self) -> list[HSPMD]:
        return [st.annot_after for st in self.stages]

    # -- statistics for benchmarks / the cost model ------------------------
    def message_count(self) -> int:
        n = 0
        for s in self.steps:
            for g in s.groups:
                if g.reduce or s.kind in ("AR", "RS", "AG", "SplitAR",
                                          "SplitRS", "SplitAG"):
                    n += 1  # one collective launch per group
                else:
                    n += len([d for d in g.dsts if d not in g.srcs])
        return n

    def nbytes_moved(self, itemsize: int = 2) -> int:
        return sum(s.nbytes_moved(itemsize) for s in self.steps)

    def per_device_send_bytes(self, itemsize: int = 2) -> dict[int, int]:
        """Point-to-point send volume attribution (BSR/SR steps only)."""
        vol: dict[int, int] = {}
        for s in self.steps:
            if s.kind not in ("BSR", "SR"):
                continue
            for g in s.groups:
                src = g.srcs[0]
                for d in g.dsts:
                    if d != src:
                        vol[src] = vol.get(src, 0) + box_nbytes(g.box, itemsize)
        return vol

    def describe(self) -> str:
        lines = [f"CommPlan<{self.kind}> ({len(self.steps)} stage(s))"]
        for i, s in enumerate(self.steps):
            lines.append(f"  stage {i}: {s.kind} x{len(s.groups)} groups, "
                         f"{s.nbytes_moved()} B moved")
        return "\n".join(lines)
