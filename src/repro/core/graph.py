"""User-facing computation graph with HSPMD annotation deduction (paper §5.1-5.2).

The user writes a *single-device-view* program; leaf operators
(placeholders, parameters) and explicit :class:`CommOp` nodes carry
annotations — every other tensor's annotation is **deduced**:

* ``DG Union`` / ``HSize`` unification converts all inputs to the largest
  HSize (paper Fig 10) and requires aligned DG unions afterwards;
* per-subgroup ``DS`` deduction mirrors classical SPMD rules (the 3D x 2D
  Dot table of Fig 11 is implemented verbatim);
* ``HDim`` deduction follows the same rule table one level up.

Tensors may carry *multiple* annotations simultaneously (paper §6.1): all
deduction runs synchronously per annotation index, producing one annotated
graph per parallel strategy out of a single user graph.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence

from .annotations import DG, DS, DUP, HSPMD, PARTIAL
from .symbolic import Dim


class DeductionError(ValueError):
    pass


@dataclass
class Tensor:
    name: str
    shape: tuple[Dim, ...]
    annots: list[HSPMD] = field(default_factory=list)
    producer: "Op | None" = None

    @property
    def annot(self) -> HSPMD:
        if not self.annots:
            raise DeductionError(f"tensor {self.name!r} has no annotation")
        return self.annots[0]

    @property
    def n_strategies(self) -> int:
        return len(self.annots)

    def __repr__(self):
        return f"Tensor({self.name}, {self.shape}, {len(self.annots)} annot(s))"


@dataclass
class Op:
    kind: str
    inputs: list[Tensor]
    outputs: list[Tensor]
    attrs: dict = field(default_factory=dict)

    def __repr__(self):
        ins = ",".join(t.name for t in self.inputs)
        outs = ",".join(t.name for t in self.outputs)
        return f"Op<{self.kind}>({ins} -> {outs})"


# ---------------------------------------------------------------------------
# HSize / DG Union conversion (paper Fig 10)
# ---------------------------------------------------------------------------

def convert_hsize(annot: HSPMD, hsize: int) -> HSPMD:
    """Losslessly re-express ``annot`` with a larger HSize by splitting the
    outermost DS entry across new subgroups (semantic equivalence is
    preserved: same device -> shard mapping)."""
    if annot.hsize == hsize:
        return annot
    if annot.hsize != 1:
        raise DeductionError(
            f"can only convert HSize=1 annotations (got {annot.hsize} -> {hsize})")
    ds, dg = annot.dss[0], annot.dgs[0]
    if not ds.entries:
        raise DeductionError("cannot split an un-sharded single-device annot")
    d0, n0 = ds.entries[0]
    if n0 % hsize != 0:
        raise DeductionError(
            f"outermost entry {d0}:{n0} not divisible by HSize {hsize}")
    sub_n = n0 // hsize
    rest = ds.entries[1:]
    sub_entries = ([(d0, sub_n)] if sub_n > 1 else []) + list(rest)
    sub_ds = DS(sub_entries)
    per = len(dg) // hsize
    dgs = [dg.devices[i * per:(i + 1) * per] for i in range(hsize)]
    return HSPMD(dgs, [sub_ds] * hsize, hdim=d0)


def unify_inputs(annots: list[HSPMD]) -> list[HSPMD]:
    """Convert all input annotations to the largest HSize (Fig 10) and
    verify the DG unions align."""
    target = max(a.hsize for a in annots)
    out = [convert_hsize(a, target) if a.hsize < target else a for a in annots]
    base = out[0]
    for a in out[1:]:
        if not a.same_dg_union(base):
            raise DeductionError(
                "DG unions do not align after HSize conversion; insert a "
                "CommOp to reshard (paper §5.2)")
    return out


# ---------------------------------------------------------------------------
# per-op deduction rules
# ---------------------------------------------------------------------------

def _deduce_elementwise(ins: list[HSPMD], shapes) -> HSPMD:
    u = unify_inputs(ins)
    base = u[0]
    for a in u[1:]:
        if not (a.same_ds_union(base) and a.hdim == base.hdim
                and a.hsplits == base.hsplits):
            raise DeductionError(
                "elementwise operands must share sharding; insert CommOp")
    return base


def _dot_ds(x: DS, w: DS, x_ndim: int) -> DS:
    """Fig 11 (left): DS deduction for Dot(X[..., k], W[k, n]).

    Split on X's batch/m dims passes through; split on W's n dim becomes
    the output's last dim; matched contraction splits turn into Partial;
    Duplicate absorbs the rest.
    """
    n_dev = x.num_devices
    if w.num_devices != n_dev:
        raise DeductionError("operand subgroups have different device counts")
    kx = x.get(x_ndim - 1)          # X contraction split
    kw = w.get(0)                   # W contraction split
    if kx != kw:
        raise DeductionError(
            f"contraction dim split mismatch ({kx} vs {kw}); insert CommOp")
    entries: list[tuple[int, int]] = []
    for d in range(x_ndim - 1):     # batch / m dims
        n = x.get(d)
        if n > 1:
            entries.append((d, n))
    n_split = w.get(1)
    if n_split > 1:
        entries.append((x_ndim - 1, n_split))
    partial = x.get(PARTIAL) * w.get(PARTIAL) * kx
    if partial > 1:
        entries.append((PARTIAL, partial))
    used = 1
    for _, n in entries:
        used *= n
    if n_dev % used != 0:
        raise DeductionError(f"inconsistent sharding: {used} does not divide {n_dev}")
    dup = n_dev // used
    if dup > 1:
        entries.append((DUP, dup))
    return DS(entries)


def _dot_hdim(x_hdim: int, w_hdim: int, x_ndim: int) -> int:
    """Fig 11 (right): HDim deduction for Dot."""
    if x_hdim == PARTIAL or w_hdim == PARTIAL:
        return PARTIAL
    if x_hdim == x_ndim - 1 or w_hdim == 0:
        # contraction dim split across subgroups (must match on both sides)
        if (x_hdim == x_ndim - 1) != (w_hdim == 0):
            raise DeductionError("top-tier contraction split must match; "
                                 "insert CommOp")
        return PARTIAL
    if x_hdim >= 0:
        if w_hdim >= 0:
            raise DeductionError("both operands top-split on non-contraction "
                                 "dims; insert CommOp")
        return x_hdim
    if w_hdim == 1:
        return x_ndim - 1
    return DUP


def _deduce_dot(ins: list[HSPMD], shapes) -> HSPMD:
    x_ndim = len(shapes[0])
    if len(shapes[1]) != 2:
        raise DeductionError("Dot expects a 2D weight operand")
    xa, wa = unify_inputs(ins)
    dss = [_dot_ds(xs, ws, x_ndim) for xs, ws in zip(xa.dss, wa.dss)]
    hdim = _dot_hdim(xa.hdim, wa.hdim, x_ndim)
    hsplits = xa.hsplits if (xa.hdim == hdim and xa.hsplits) else None
    return HSPMD(xa.dgs, dss, hdim=hdim, hsplits=hsplits)


def _deduce_sum(ins: list[HSPMD], shapes, dim: int) -> HSPMD:
    (a,) = ins
    ndim = len(shapes[0])
    dss = []
    for ds in a.dss:
        entries = []
        partial = ds.get(PARTIAL)
        for d, n in ds.entries:
            if d == dim:
                partial *= n          # reduced dim's split becomes Partial
            elif d >= 0:
                nd = d - 1 if d > dim else d
                entries.append((nd, n))
            elif d == DUP:
                entries.append((DUP, n))
        if partial > 1:
            entries.append((PARTIAL, partial))
        dss.append(DS(entries))
    if a.hdim == dim:
        hdim = PARTIAL
    elif a.hdim > dim:
        hdim = a.hdim - 1
    else:
        hdim = a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim,
                 hsplits=a.hsplits if hdim == a.hdim else None)


def _deduce_transpose(ins: list[HSPMD], shapes, perm) -> HSPMD:
    """Sharded dims follow their tensor dims through the permutation."""
    (a,) = ins
    inv = {old: new for new, old in enumerate(perm)}
    dss = []
    for ds in a.dss:
        dss.append(DS([(inv[d] if d >= 0 else d, n) for d, n in ds.entries]))
    hdim = inv[a.hdim] if a.hdim >= 0 else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim, hsplits=a.hsplits)


def _deduce_reshape(ins: list[HSPMD], shapes, new_shape) -> HSPMD:
    """Paper §5.2: Reshape has specialized deduction.  Supported cases:
    every split dim must map to a dim of the new shape whose size is a
    multiple of the shard count and whose position is unambiguous
    (leading-dims product preserved); otherwise the user must insert a
    CommOp to replicate first."""
    (a,) = ins
    old_shape = shapes[0]

    def map_dim(d: int) -> int:
        # a dim maps if the product of dims before it is preserved
        import math
        before = math.prod(old_shape[:d])
        acc = 1
        for nd, size in enumerate(new_shape):
            if acc == before and new_shape[nd] % 1 == 0:
                return nd
            acc *= size
        raise DeductionError(
            f"reshape moves sharded dim {d}; insert CommOp to replicate")

    dss = []
    for ds in a.dss:
        entries = []
        for d, n in ds.entries:
            if d >= 0:
                nd = map_dim(d)
                if new_shape[nd] % n != 0:
                    raise DeductionError(
                        f"reshaped dim {nd} size {new_shape[nd]} not "
                        f"divisible by {n} shards")
                entries.append((nd, n))
            else:
                entries.append((d, n))
        dss.append(DS(entries))
    hdim = map_dim(a.hdim) if a.hdim >= 0 else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim, hsplits=a.hsplits)


DEDUCTION_RULES = {
    "gelu": lambda ins, shapes, attrs: ins[0],
    "relu": lambda ins, shapes, attrs: ins[0],
    "scale": lambda ins, shapes, attrs: ins[0],
    "add": lambda ins, shapes, attrs: _deduce_elementwise(ins, shapes),
    "mul": lambda ins, shapes, attrs: _deduce_elementwise(ins, shapes),
    "dot": lambda ins, shapes, attrs: _deduce_dot(ins, shapes),
    "sum": lambda ins, shapes, attrs: _deduce_sum(ins, shapes, attrs["dim"]),
    "transpose": lambda ins, shapes, attrs: _deduce_transpose(
        ins, shapes, attrs["perm"]),
    "reshape": lambda ins, shapes, attrs: _deduce_reshape(
        ins, shapes, attrs["new_shape"]),
}


# ---------------------------------------------------------------------------
# graph builder
# ---------------------------------------------------------------------------

class Graph:
    """Single-device-view program with declarative HSPMD annotations."""

    def __init__(self):
        self.ops: list[Op] = []
        self.tensors: dict[str, Tensor] = {}
        self._n = 0

    # -- leaves -------------------------------------------------------------
    def _add_tensor(self, name, shape, annots=None, producer=None) -> Tensor:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor {name}")
        t = Tensor(name, tuple(shape), list(annots or []), producer)
        self.tensors[name] = t
        return t

    def placeholder(self, name: str, shape,
                    annots: Sequence[HSPMD] | None = None) -> Tensor:
        t = self._add_tensor(name, shape, annots)
        self.ops.append(Op("placeholder", [], [t]))
        t.producer = self.ops[-1]
        return t

    def parameter(self, name: str, shape,
                  annots: Sequence[HSPMD] | None = None) -> Tensor:
        t = self._add_tensor(name, shape, annots)
        self.ops.append(Op("parameter", [], [t]))
        t.producer = self.ops[-1]
        return t

    # -- CommOp (§5.1) -------------------------------------------------------
    def comm(self, x: Tensor, annots: Sequence[HSPMD] | HSPMD | None = None,
             name: str | None = None) -> Tensor:
        if isinstance(annots, HSPMD):
            annots = [annots]
        name = name or f"{x.name}'"
        out = self._add_tensor(name, x.shape, list(annots or []))
        op = Op("comm", [x], [out], {"id": sum(1 for o in self.ops
                                               if o.kind == "comm") + 1})
        self.ops.append(op)
        out.producer = op
        return out

    # -- compute ops ----------------------------------------------------------
    def _compute(self, kind: str, ins: list[Tensor], out_shape,
                 name: str | None = None, **attrs) -> Tensor:
        name = name or f"{kind}_{self._n}"
        self._n += 1
        out = self._add_tensor(name, out_shape)
        op = Op(kind, list(ins), [out], dict(attrs))
        self.ops.append(op)
        out.producer = op
        return out

    def gelu(self, x, name=None):
        return self._compute("gelu", [x], x.shape, name)

    def relu(self, x, name=None):
        return self._compute("relu", [x], x.shape, name)

    def add(self, a, b, name=None):
        return self._compute("add", [a, b], a.shape, name)

    def mul(self, a, b, name=None):
        return self._compute("mul", [a, b], a.shape, name)

    def dot(self, x, w, name=None):
        out_shape = tuple(x.shape[:-1]) + (w.shape[-1],)
        return self._compute("dot", [x, w], out_shape, name)

    def sum(self, x, dim: int, name=None):
        out_shape = tuple(s for i, s in enumerate(x.shape) if i != dim)
        return self._compute("sum", [x], out_shape, name, dim=dim)

    def transpose(self, x, perm, name=None):
        out_shape = tuple(x.shape[p] for p in perm)
        return self._compute("transpose", [x], out_shape, name,
                             perm=tuple(perm))

    def reshape(self, x, new_shape, name=None):
        return self._compute("reshape", [x], tuple(new_shape), name,
                             new_shape=tuple(new_shape))

    # -- deduction (§5.2) -----------------------------------------------------
    def deduce(self) -> "Graph":
        """Fill in annotations for every tensor, per strategy index."""
        n_strat = max((len(t.annots) for t in self.tensors.values()
                       if t.annots), default=1)
        for op in self.ops:
            if op.kind in ("placeholder", "parameter", "comm"):
                for t in op.outputs:
                    if not t.annots:
                        raise DeductionError(f"leaf/comm {t.name} needs annots")
                    if len(t.annots) not in (1, n_strat):
                        raise DeductionError(
                            f"{t.name}: {len(t.annots)} annots, expected "
                            f"1 or {n_strat}")
                    if len(t.annots) == 1 and n_strat > 1:
                        t.annots = t.annots * n_strat
                continue
            rule = DEDUCTION_RULES.get(op.kind)
            if rule is None:
                raise DeductionError(f"no deduction rule for op {op.kind}")
            shapes = [t.shape for t in op.inputs]
            for t in op.outputs:
                t.annots = []
            for k in range(n_strat):
                ins = [t.annots[k] for t in op.inputs]
                out = rule(ins, shapes, op.attrs)
                for t in op.outputs:
                    t.annots.append(out)
        return self

    @property
    def comm_ops(self) -> list[Op]:
        return [o for o in self.ops if o.kind == "comm"]

    def parameters(self) -> list[Tensor]:
        return [o.outputs[0] for o in self.ops if o.kind == "parameter"]

    def placeholders(self) -> list[Tensor]:
        return [o.outputs[0] for o in self.ops if o.kind == "placeholder"]

    def annotation_points(self) -> list[Tensor]:
        """Tensors that carry *explicit* (non-deduced) annotations: leaves
        and CommOp outputs — exactly what a parallel-strategy bundle must
        cover (paper §6.1's multiple-annotation binding sites)."""
        return [o.outputs[0] for o in self.ops
                if o.kind in ("placeholder", "parameter", "comm")]

    def sinks(self) -> list[Tensor]:
        """Tensors no op consumes — the program's default outputs."""
        consumed = {id(t) for o in self.ops for t in o.inputs}
        return [o.outputs[0] for o in self.ops
                if o.outputs and id(o.outputs[0]) not in consumed]

    def deduction_report(self) -> "DeductionReport":
        """Run deduction and return a stable summary the API layer
        composes (tensor/op counts, per-strategy device universes)."""
        self.deduce()
        n_strat = max((len(t.annots) for t in self.tensors.values()
                       if t.annots), default=1)
        devices = []
        for k in range(n_strat):
            devs: set[int] = set()
            for t in self.tensors.values():
                if t.annots:
                    devs |= set(t.annots[k].devices)
            devices.append(tuple(sorted(devs)))
        return DeductionReport(
            n_strategies=n_strat,
            n_ops=len(self.ops),
            n_comm_ops=len(self.comm_ops),
            n_tensors=len(self.tensors),
            devices=tuple(devices),
        )


@dataclass(frozen=True)
class DeductionReport:
    """Stable result of annotation deduction over a graph."""

    n_strategies: int
    n_ops: int
    n_comm_ops: int
    n_tensors: int
    devices: tuple[tuple[int, ...], ...]  # per-strategy device universe
