"""User-facing computation graph with HSPMD annotation deduction (paper §5.1-5.2).

The user writes a *single-device-view* program; leaf operators
(placeholders, parameters) and explicit :class:`CommOp` nodes carry
annotations — every other tensor's annotation is **deduced**:

* ``DG Union`` / ``HSize`` unification converts all inputs to the largest
  HSize (paper Fig 10) and requires aligned DG unions afterwards;
* per-subgroup ``DS`` deduction mirrors classical SPMD rules (the 3D x 2D
  Dot table of Fig 11 is implemented verbatim);
* ``HDim`` deduction follows the same rule table one level up.

Tensors may carry *multiple* annotations simultaneously (paper §6.1): all
deduction runs synchronously per annotation index, producing one annotated
graph per parallel strategy out of a single user graph.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Sequence

from .annotations import DG, DS, DUP, HSPMD, PARTIAL
from .symbolic import Dim


class DeductionError(ValueError):
    pass


class GradError(ValueError):
    """Reverse-mode autodiff cannot differentiate this graph."""


@dataclass
class Tensor:
    name: str
    shape: tuple[Dim, ...]
    annots: list[HSPMD] = field(default_factory=list)
    producer: "Op | None" = None

    @property
    def annot(self) -> HSPMD:
        if not self.annots:
            raise DeductionError(f"tensor {self.name!r} has no annotation")
        return self.annots[0]

    @property
    def n_strategies(self) -> int:
        return len(self.annots)

    def __repr__(self):
        return f"Tensor({self.name}, {self.shape}, {len(self.annots)} annot(s))"


@dataclass
class Op:
    kind: str
    inputs: list[Tensor]
    outputs: list[Tensor]
    attrs: dict = field(default_factory=dict)

    def __repr__(self):
        ins = ",".join(t.name for t in self.inputs)
        outs = ",".join(t.name for t in self.outputs)
        return f"Op<{self.kind}>({ins} -> {outs})"


# ---------------------------------------------------------------------------
# HSize / DG Union conversion (paper Fig 10)
# ---------------------------------------------------------------------------

def convert_hsize(annot: HSPMD, hsize: int) -> HSPMD:
    """Losslessly re-express ``annot`` with a larger HSize by splitting the
    outermost DS entry across new subgroups (semantic equivalence is
    preserved: same device -> shard mapping)."""
    if annot.hsize == hsize:
        return annot
    if annot.hsize != 1:
        raise DeductionError(
            f"can only convert HSize=1 annotations (got {annot.hsize} -> {hsize})")
    ds, dg = annot.dss[0], annot.dgs[0]
    if not ds.entries:
        raise DeductionError("cannot split an un-sharded single-device annot")
    d0, n0 = ds.entries[0]
    if n0 % hsize != 0:
        raise DeductionError(
            f"outermost entry {d0}:{n0} not divisible by HSize {hsize}")
    sub_n = n0 // hsize
    rest = ds.entries[1:]
    sub_entries = ([(d0, sub_n)] if sub_n > 1 else []) + list(rest)
    sub_ds = DS(sub_entries)
    per = len(dg) // hsize
    dgs = [dg.devices[i * per:(i + 1) * per] for i in range(hsize)]
    return HSPMD(dgs, [sub_ds] * hsize, hdim=d0)


def unify_inputs(annots: list[HSPMD]) -> list[HSPMD]:
    """Convert all input annotations to the largest HSize (Fig 10) and
    verify the DG unions align."""
    target = max(a.hsize for a in annots)
    out = [convert_hsize(a, target) if a.hsize < target else a for a in annots]
    base = out[0]
    for a in out[1:]:
        if not a.same_dg_union(base):
            raise DeductionError(
                "DG unions do not align after HSize conversion; insert a "
                "CommOp to reshard (paper §5.2)")
    return out


# ---------------------------------------------------------------------------
# per-op deduction rules
# ---------------------------------------------------------------------------

def _deduce_elementwise(ins: list[HSPMD], shapes) -> HSPMD:
    u = unify_inputs(ins)
    base = u[0]
    for a in u[1:]:
        if not (a.same_ds_union(base) and a.hdim == base.hdim
                and a.hsplits == base.hsplits):
            raise DeductionError(
                "elementwise operands must share sharding; insert CommOp")
    return base


def _entries_from_coords(n_dev: int, coords: "dict[int, list[int]]") -> DS:
    """Reconstruct an ordered DS from per-device output coordinates.

    ``coords[d][p]`` is device position ``p``'s shard coordinate along
    output entry ``d`` (Split dim, PARTIAL, or DUP).  A valid DS is a
    mixed-radix decomposition of ``p``, so each entry's stride is the
    smallest position that bumps ONLY that coordinate; ordering entries
    by descending stride and re-checking every position either recovers
    the unique decomposition or proves none exists (interleaved
    coordinates — not representable; the caller must insert a CommOp)."""
    dims = {d: max(c) + 1 for d, c in coords.items() if max(c) > 0}
    ranked = []
    for d, n in dims.items():
        stride = next(
            (p for p in range(1, n_dev)
             if coords[d][p] == 1
             and all(coords[e][p] == 0 for e in dims if e != d)), None)
        if stride is None:
            raise DeductionError(
                "operand shardings interleave; insert CommOp")
        ranked.append((stride, d, n))
    ranked.sort(key=lambda t: -t[0])
    ds = DS([(d, n) for _, d, n in ranked])
    if ds.num_devices != n_dev:
        raise DeductionError(
            "operand shardings interleave; insert CommOp")
    for p in range(n_dev):
        c = ds.coords(p)
        for d in dims:
            if c.get(d, 0) != coords[d][p]:
                raise DeductionError(
                    "operand shardings interleave; insert CommOp")
    return ds


def _dot_ds(x: DS, w: DS, x_ndim: int) -> DS:
    """Fig 11 (left): DS deduction for Dot(X[..., k], W[k, n]).

    Split on X's batch/m dims passes through; split on W's n dim becomes
    the output's last dim; matched contraction splits turn into Partial;
    Duplicate absorbs the rest.  The output's entry ORDER is recovered
    from the two operands' device->coordinate decompositions (not a
    canonical batch/col/partial ordering): the order fixes which devices
    share a summand group, and e.g. the ``dw = x^T @ dy`` dots of the
    backward pass carry their contraction split OUTERMOST."""
    n_dev = x.num_devices
    if w.num_devices != n_dev:
        raise DeductionError("operand subgroups have different device counts")
    kx = x.get(x_ndim - 1)          # X contraction split
    kw = w.get(0)                   # W contraction split
    if kx != kw:
        raise DeductionError(
            f"contraction dim split mismatch ({kx} vs {kw}); insert CommOp")
    try:
        xp, wp = x.get(PARTIAL), w.get(PARTIAL)
        coords: dict[int, list[int]] = {d: [] for d in range(x_ndim)}
        coords[PARTIAL] = []
        dup_seen: dict[tuple, int] = {}
        coords[DUP] = []
        for p in range(n_dev):
            cx, cw = x.coords(p), w.coords(p)
            ck_x = cx.get(x_ndim - 1, 0)
            ck_w = cw.get(0, 0)
            if ck_x != ck_w:
                raise DeductionError(
                    "contraction chunks pair different shards across "
                    "devices")
            for d in range(x_ndim - 1):
                coords[d].append(cx.get(d, 0))
            coords[x_ndim - 1].append(cw.get(1, 0))
            # summand id: contraction chunk x pre-existing Partial coords
            coords[PARTIAL].append(
                (ck_x * xp + cx.get(PARTIAL, 0)) * wp + cw.get(PARTIAL, 0))
            key = tuple(coords[d][p] for d in range(x_ndim)) \
                + (coords[PARTIAL][p],)
            coords[DUP].append(dup_seen.setdefault(key, 0))
            dup_seen[key] += 1
        return _entries_from_coords(n_dev, coords)
    except DeductionError:
        # count-based Fig 11 fallback for layouts whose decompositions
        # don't pair positionally (symbolic placements that deduce but
        # never execute locally): batch splits, then col, then Partial
        entries: list[tuple[int, int]] = []
        for d in range(x_ndim - 1):
            n = x.get(d)
            if n > 1:
                entries.append((d, n))
        if w.get(1) > 1:
            entries.append((x_ndim - 1, w.get(1)))
        partial = x.get(PARTIAL) * w.get(PARTIAL) * kx
        if partial > 1:
            entries.append((PARTIAL, partial))
        used = 1
        for _, n in entries:
            used *= n
        if n_dev % used != 0:
            raise DeductionError(
                f"inconsistent sharding: {used} does not divide {n_dev}")
        if n_dev // used > 1:
            entries.append((DUP, n_dev // used))
        return DS(entries)


def _dot_hdim(x_hdim: int, w_hdim: int, x_ndim: int) -> int:
    """Fig 11 (right): HDim deduction for Dot."""
    if x_hdim == PARTIAL or w_hdim == PARTIAL:
        return PARTIAL
    if x_hdim == x_ndim - 1 or w_hdim == 0:
        # contraction dim split across subgroups (must match on both sides)
        if (x_hdim == x_ndim - 1) != (w_hdim == 0):
            raise DeductionError("top-tier contraction split must match; "
                                 "insert CommOp")
        return PARTIAL
    if x_hdim >= 0:
        if w_hdim >= 0:
            raise DeductionError("both operands top-split on non-contraction "
                                 "dims; insert CommOp")
        return x_hdim
    if w_hdim == 1:
        return x_ndim - 1
    return DUP


def _deduce_dot(ins: list[HSPMD], shapes) -> HSPMD:
    x_ndim = len(shapes[0])
    if len(shapes[1]) != 2:
        raise DeductionError("Dot expects a 2D weight operand")
    xa, wa = unify_inputs(ins)
    dss = [_dot_ds(xs, ws, x_ndim) for xs, ws in zip(xa.dss, wa.dss)]
    hdim = _dot_hdim(xa.hdim, wa.hdim, x_ndim)
    hsplits = xa.hsplits if (xa.hdim == hdim and xa.hsplits) else None
    return HSPMD(xa.dgs, dss, hdim=hdim, hsplits=hsplits)


def _deduce_sum(ins: list[HSPMD], shapes, dim: int) -> HSPMD:
    (a,) = ins
    ndim = len(shapes[0])
    dss = []
    for ds in a.dss:
        # entry ORDER is the device -> shard decomposition, so the
        # reduced dim's split becomes Partial IN PLACE (the device's
        # former shard coordinate is now its summand id) — appending it
        # at the end would pair devices with the wrong summand groups
        entries: list[tuple[int, int]] = []
        for d, n in ds.entries:
            if d == dim or d == PARTIAL:
                if entries and entries[-1][0] == PARTIAL:
                    entries[-1] = (PARTIAL, entries[-1][1] * n)
                else:
                    entries.append((PARTIAL, n))
            elif d >= 0:
                entries.append((d - 1 if d > dim else d, n))
            else:
                entries.append((DUP, n))
        if sum(1 for d, _ in entries if d == PARTIAL) > 1:
            raise DeductionError(
                "sum produces non-adjacent Partial entries (existing "
                "Partial + reduced split); insert CommOp to reduce first")
        dss.append(DS(entries))
    if a.hdim == dim:
        hdim = PARTIAL
    elif a.hdim > dim:
        hdim = a.hdim - 1
    else:
        hdim = a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim,
                 hsplits=a.hsplits if hdim == a.hdim else None)


def _deduce_transpose(ins: list[HSPMD], shapes, perm) -> HSPMD:
    """Sharded dims follow their tensor dims through the permutation."""
    (a,) = ins
    inv = {old: new for new, old in enumerate(perm)}
    dss = []
    for ds in a.dss:
        dss.append(DS([(inv[d] if d >= 0 else d, n) for d, n in ds.entries]))
    hdim = inv[a.hdim] if a.hdim >= 0 else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim, hsplits=a.hsplits)


def _deduce_reshape(ins: list[HSPMD], shapes, new_shape) -> HSPMD:
    """Paper §5.2: Reshape has specialized deduction.  Supported cases:
    every split dim must map to a dim of the new shape whose size is a
    multiple of the shard count and whose position is unambiguous
    (leading-dims product preserved); otherwise the user must insert a
    CommOp to replicate first."""
    (a,) = ins
    old_shape = shapes[0]

    from .symbolic import dim_multiple_of, dims_equal, prod_dims

    def map_dim(d: int) -> int:
        # a dim maps if the product of dims before it is preserved
        # (symbolic dims compare as canonicalized products)
        before = prod_dims(old_shape[:d])
        acc: Dim = 1
        for nd, size in enumerate(new_shape):
            if dims_equal(acc, before):
                return nd
            acc = prod_dims((acc, size))
        raise DeductionError(
            f"reshape moves sharded dim {d}; insert CommOp to replicate")

    dss = []
    for ds in a.dss:
        entries = []
        for d, n in ds.entries:
            if d >= 0:
                nd = map_dim(d)
                # symbolic sizes defer divisibility to bind time
                if dim_multiple_of(new_shape[nd], n) is False:
                    raise DeductionError(
                        f"reshaped dim {nd} size {new_shape[nd]} not "
                        f"divisible by {n} shards")
                entries.append((nd, n))
            else:
                entries.append((d, n))
        dss.append(DS(entries))
    hdim = map_dim(a.hdim) if a.hdim >= 0 else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim, hsplits=a.hsplits)


def _deduce_linear_grad(ins: list[HSPMD], shapes) -> HSPMD:
    """Elementwise deduction for ``x_grad``-style kernels (``relu_grad``,
    ``gelu_grad``, ``mul``'s backward uses): the FIRST operand (the
    upstream cotangent) may be Partial — the kernel is linear in it, so
    ``(sum_i dy_i) * mask == sum_i (dy_i * mask)`` and the Partial
    degree passes through.  Split dims must still agree."""
    u = unify_inputs(ins)
    dy = u[0]
    for a in u[1:]:
        for ds_dy, ds_a in zip(dy.dss, a.dss):
            if ds_a.has_partial:
                raise DeductionError(
                    "mask operand of a grad kernel is Partial; insert "
                    "CommOp to reduce it first")
            if ({d: n for d, n in ds_a.entries if d >= 0}
                    != {d: n for d, n in ds_dy.entries if d >= 0}):
                raise DeductionError(
                    "grad kernel operands have mismatched split dims; "
                    "insert CommOp")
    return dy


def _deduce_bcast(ins: list[HSPMD], shapes, dim: int) -> HSPMD:
    """Inverse of ``sum``'s dim bookkeeping: the new dim is inserted at
    ``dim`` (unsharded); split dims at or after it shift up.  Duplicate
    and Partial pass through (broadcast is linear)."""
    (a,) = ins
    dss = []
    for ds in a.dss:
        entries = []
        for d, n in ds.entries:
            if d >= dim:
                entries.append((d + 1, n))
            else:
                entries.append((d, n))
        dss.append(DS(entries))
    hdim = a.hdim + 1 if a.hdim >= dim else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim,
                 hsplits=a.hsplits if hdim == a.hdim else None)


def _deduce_embedding(ins: list[HSPMD], shapes) -> HSPMD:
    """Embedding lookup ``out[b..., :] = table[ids[b...], :]``.

    Indices are global, so the vocab dim (table dim 0) must not be
    split (insert a CommOp to replicate first); a split on the feature
    dim (table dim 1) becomes the output's last dim; ids splits pass
    through; the lookup is linear in the table, so a Partial table
    yields a Partial output, while Partial *indices* are meaningless.
    """
    ta, ia = unify_inputs(ins)
    ids_ndim = len(shapes[1])
    dss = []
    for ts, is_ in zip(ta.dss, ia.dss):
        if ts.get(0) > 1:
            raise DeductionError(
                "embedding table split along the vocab dim; insert a "
                "CommOp to replicate (indices are global)")
        if is_.get(PARTIAL) > 1:
            raise DeductionError("embedding indices cannot be Partial")
        entries: list[tuple[int, int]] = []
        for d in range(ids_ndim):
            n = is_.get(d)
            if n > 1:
                entries.append((d, n))
        n_split = ts.get(1)
        if n_split > 1:
            entries.append((ids_ndim, n_split))
        partial = ts.get(PARTIAL)
        if partial > 1:
            entries.append((PARTIAL, partial))
        n_dev = is_.num_devices
        used = 1
        for _, n in entries:
            used *= n
        if n_dev % used != 0:
            raise DeductionError(
                f"inconsistent embedding sharding: {used} does not "
                f"divide {n_dev}")
        if n_dev // used > 1:
            entries.append((DUP, n_dev // used))
        dss.append(DS(entries))
    if ia.hdim == PARTIAL:
        raise DeductionError("embedding indices cannot be Partial")
    if ia.hdim >= 0:
        if ta.hdim not in (DUP, PARTIAL):
            raise DeductionError(
                "both embedding operands top-split; insert CommOp")
        hdim = ia.hdim
    elif ta.hdim == 1:
        hdim = ids_ndim
    elif ta.hdim == PARTIAL:
        hdim = PARTIAL
    elif ta.hdim == 0:
        raise DeductionError(
            "embedding table top-split along the vocab dim; insert CommOp")
    else:
        hdim = DUP
    return HSPMD(ia.dgs, dss, hdim=hdim,
                 hsplits=ia.hsplits if hdim == ia.hdim else None)


def _deduce_embed_grad(ins: list[HSPMD], shapes) -> HSPMD:
    """VJP of embedding wrt the table: scatter-add of ``dy`` rows at
    ``ids``.  Batch splits collapse to Partial (each device scatters its
    slice of rows into a full-vocab buffer); a split feature dim maps to
    out dim 1; ids splits must match dy's batch splits."""
    da, ia = unify_inputs(ins)
    dy_ndim = len(shapes[0])
    dss = []
    for ds_, is_ in zip(da.dss, ia.dss):
        partial = ds_.get(PARTIAL)
        entries: list[tuple[int, int]] = []
        for d, n in ds_.entries:
            if d == dy_ndim - 1:
                entries.append((1, n))
            elif d >= 0:
                if is_.get(d) != n:
                    raise DeductionError(
                        f"embed_grad: dy batch dim {d} split {n} does not "
                        f"match ids split {is_.get(d)}")
                partial *= n
        if partial > 1:
            entries.append((PARTIAL, partial))
        n_dev = ds_.num_devices
        used = 1
        for _, n in entries:
            used *= n
        if n_dev % used != 0:
            raise DeductionError(
                f"inconsistent embed_grad sharding: {used} does not "
                f"divide {n_dev}")
        if n_dev // used > 1:
            entries.append((DUP, n_dev // used))
        dss.append(DS(entries))
    if da.hdim == dy_ndim - 1:
        hdim = 1
    elif da.hdim >= 0:
        hdim = PARTIAL
    else:
        hdim = da.hdim
    return HSPMD(da.dgs, dss, hdim=hdim)


def _deduce_softmax(ins: list[HSPMD], shapes) -> HSPMD:
    """Softmax normalizes the last dim: that dim must not be split (the
    normalizer needs every element) and the input must not be Partial
    (softmax is nonlinear in the summands)."""
    (a,) = ins
    ndim = len(shapes[0])
    for ds in a.dss:
        if ds.get(ndim - 1) > 1:
            raise DeductionError(
                "softmax dim is split; insert CommOp to gather it")
        if ds.has_partial:
            raise DeductionError(
                "softmax over a Partial tensor is nonlinear; insert "
                "CommOp to reduce first")
    if a.hdim == ndim - 1 or a.hdim == PARTIAL:
        raise DeductionError(
            "softmax dim top-split or Partial; insert CommOp")
    return a


def _deduce_norm(ins: list[HSPMD], shapes) -> HSPMD:
    """rmsnorm(x, w) / layernorm(x, w, b): the normalized (last) dim of
    x must be whole on every device; weights must be replicated along
    their feature dim (they multiply the un-split last dim)."""
    u = unify_inputs(ins)
    x = u[0]
    ndim = len(shapes[0])
    for ds in x.dss:
        if ds.get(ndim - 1) > 1:
            raise DeductionError(
                "normalized (last) dim is split; insert CommOp")
        if ds.has_partial:
            raise DeductionError(
                "norm over a Partial tensor is nonlinear; insert CommOp "
                "to reduce first")
    if x.hdim == ndim - 1 or x.hdim == PARTIAL:
        raise DeductionError(
            "normalized dim top-split or Partial; insert CommOp")
    for w in u[1:]:
        for ds in w.dss:
            if ds.get(0) > 1 or ds.has_partial:
                raise DeductionError(
                    "norm weights must be replicated; insert CommOp")
        if w.hdim != DUP:
            raise DeductionError(
                "norm weights must be replicated across subgroups")
    return x


def _deduce_gather(ins: list[HSPMD], shapes) -> HSPMD:
    """``out[b...] = x[b..., ids[b...]]`` — take along x's last axis.
    Indices are global along that axis, so it must not be split; leading
    splits must agree between x and ids; gather is linear in x, so a
    Partial x passes through, while Partial indices are meaningless."""
    xa, ia = unify_inputs(ins)
    x_ndim = len(shapes[0])
    dss = []
    for xs, is_ in zip(xa.dss, ia.dss):
        if xs.get(x_ndim - 1) > 1:
            raise DeductionError(
                "gathered (last) dim is split; insert CommOp to "
                "replicate (indices are global)")
        if is_.get(PARTIAL) > 1:
            raise DeductionError("gather indices cannot be Partial")
        entries: list[tuple[int, int]] = []
        for d in range(x_ndim - 1):
            if xs.get(d) != is_.get(d):
                raise DeductionError(
                    f"gather: x dim {d} split {xs.get(d)} does not match "
                    f"ids split {is_.get(d)}; insert CommOp")
            if is_.get(d) > 1:
                entries.append((d, is_.get(d)))
        partial = xs.get(PARTIAL)
        if partial > 1:
            entries.append((PARTIAL, partial))
        n_dev = is_.num_devices
        used = 1
        for _, n in entries:
            used *= n
        if n_dev % used != 0:
            raise DeductionError(
                f"inconsistent gather sharding: {used} does not divide "
                f"{n_dev}")
        if n_dev // used > 1:
            entries.append((DUP, n_dev // used))
        dss.append(DS(entries))
    if ia.hdim == PARTIAL or xa.hdim == x_ndim - 1:
        raise DeductionError(
            "gather indices Partial or gathered dim top-split; insert "
            "CommOp")
    if ia.hdim >= 0 and xa.hdim >= 0 and ia.hdim != xa.hdim:
        raise DeductionError(
            "gather operands top-split on different dims; insert CommOp")
    if ia.hdim >= 0:
        hdim = ia.hdim
    elif xa.hdim >= 0 or xa.hdim == PARTIAL:
        hdim = xa.hdim
    else:
        hdim = DUP
    return HSPMD(ia.dgs, dss, hdim=hdim,
                 hsplits=ia.hsplits if hdim == ia.hdim else None)


def _deduce_attention(ins: list[HSPMD], shapes) -> HSPMD:
    """attention(q, k, v): q (B,H,Sq,D); k/v (B,K,Sk,D) with H % K == 0.

    Head-dim aware: a TP split over dim 1 passes through when q and k/v
    carry the SAME shard count (H and K shards pair up groupwise under
    GQA); batch (dim 0) splits must match; sequence and head_dim splits
    have no local kernel (softmax spans the key sequence) and Partial
    operands are nonlinear — both demand a CommOp first."""
    qa, ka, va = unify_inputs(ins)
    H, K = shapes[0][1], shapes[1][1]
    dss = []
    for qs, ks, vs in zip(qa.dss, ka.dss, va.dss):
        if ks.entries != vs.entries:
            raise DeductionError(
                "attention k and v must share one sharding; insert CommOp")
        for ds, who in ((qs, "q"), (ks, "k/v")):
            if ds.has_partial:
                raise DeductionError(
                    f"attention {who} is Partial (softmax is nonlinear); "
                    f"insert CommOp to reduce first")
            if ds.get(2) > 1 or ds.get(3) > 1:
                raise DeductionError(
                    f"attention {who} split along sequence/head_dim; "
                    f"insert CommOp")
        if qs.get(0) != ks.get(0):
            raise DeductionError(
                "attention batch split mismatch between q and k/v; "
                "insert CommOp")
        n = qs.get(1)
        if ks.get(1) != n:
            raise DeductionError(
                f"attention head split mismatch (q {n} vs k/v "
                f"{ks.get(1)} shards); TP over heads must shard q and "
                f"k/v with the same group count")
        if n > 1:
            if isinstance(H, int) and H % n != 0:
                raise DeductionError(
                    f"{H} query heads not divisible by {n} shards")
            if isinstance(K, int) and K % n != 0:
                raise DeductionError(
                    f"{K} kv heads not divisible by {n} shards")
        dss.append(qs)
    hdims = {qa.hdim, ka.hdim, va.hdim}
    if PARTIAL in hdims:
        raise DeductionError("attention over top-tier Partial; insert CommOp")
    if hdims - {DUP} and (len(hdims - {DUP}) > 1
                          or next(iter(hdims - {DUP})) not in (0, 1)):
        raise DeductionError(
            "attention operands top-split beyond batch/head dims or on "
            "different dims; insert CommOp")
    if qa.hdim != ka.hdim or ka.hdim != va.hdim:
        raise DeductionError(
            "attention operands must share one top-tier split; insert "
            "CommOp")
    return HSPMD(qa.dgs, dss, hdim=qa.hdim, hsplits=qa.hsplits)


def _deduce_norm_grad_x(ins: list[HSPMD], shapes) -> HSPMD:
    """VJP of rmsnorm/layernorm wrt x: linear in ``dy`` (Partial passes
    through); the activation must match dy's splits, the weight must be
    replicated (same constraints the forward op already enforced)."""
    u = unify_inputs(ins)
    dy, x = u[0], u[1]
    for ds_dy, ds_x in zip(dy.dss, x.dss):
        if ds_x.has_partial:
            raise DeductionError(
                "norm_grad_x activation is Partial; insert CommOp")
        if ({d: n for d, n in ds_x.entries if d >= 0}
                != {d: n for d, n in ds_dy.entries if d >= 0}):
            raise DeductionError(
                "norm_grad_x operands have mismatched split dims; "
                "insert CommOp")
    for w in u[2:]:
        for ds in w.dss:
            if ds.get(0) > 1 or ds.has_partial:
                raise DeductionError(
                    "norm_grad_x weight must be replicated; insert CommOp")
    return dy


def _deduce_reduce_to_vector(ins: list[HSPMD], shapes) -> HSPMD:
    """norm_grad_w / norm_grad_b: reduce ``dy (..., d)`` over every
    leading dim to a ``(d,)`` vector.  Leading splits collapse to
    Partial summands (each device reduces its slice); a Partial dy stays
    Partial (the reduction is linear); the last dim is whole by the
    forward norm's own deduction."""
    u = unify_inputs(ins)
    dy = u[0]
    dy_ndim = len(shapes[0])
    dss = []
    for k, ds in enumerate(dy.dss):
        if ds.get(dy_ndim - 1) > 1:
            raise DeductionError(
                "norm grad feature (last) dim is split; insert CommOp")
        for other in u[1:]:
            if other.dss[k].has_partial:
                raise DeductionError(
                    "norm grad activation is Partial; insert CommOp")
        partial = ds.get(PARTIAL)
        for d, n in ds.entries:
            if d >= 0:
                partial *= n
        entries: list[tuple[int, int]] = []
        if partial > 1:
            entries.append((PARTIAL, partial))
        n_dev = ds.num_devices
        used = partial if partial > 1 else 1
        if n_dev // used > 1:
            entries.append((DUP, n_dev // used))
        dss.append(DS(entries))
    if dy.hdim == dy_ndim - 1:
        raise DeductionError(
            "norm grad feature dim top-split; insert CommOp")
    hdim = PARTIAL if (dy.hdim >= 0 or dy.hdim == PARTIAL) else DUP
    return HSPMD(dy.dgs, dss, hdim=hdim)


def _deduce_gather_grad(ins: list[HSPMD], shapes) -> HSPMD:
    """VJP of gather: a one-hot scatter along the appended last dim —
    elementwise over the leading dims, so dy's annotation carries over
    (the new dim is whole everywhere, as the forward op required)."""
    u = unify_inputs(ins)
    dy, ids = u
    for ds in ids.dss:
        if ds.has_partial:
            raise DeductionError("gather_grad indices cannot be Partial")
    return dy


DEDUCTION_RULES = {
    "gelu": lambda ins, shapes, attrs: ins[0],
    "relu": lambda ins, shapes, attrs: ins[0],
    "silu": lambda ins, shapes, attrs: ins[0],
    "rsqrt": lambda ins, shapes, attrs: ins[0],
    "scale": lambda ins, shapes, attrs: ins[0],
    "add": lambda ins, shapes, attrs: _deduce_elementwise(ins, shapes),
    "mul": lambda ins, shapes, attrs: _deduce_elementwise(ins, shapes),
    "dot": lambda ins, shapes, attrs: _deduce_dot(ins, shapes),
    "sum": lambda ins, shapes, attrs: _deduce_sum(ins, shapes, attrs["dim"]),
    "transpose": lambda ins, shapes, attrs: _deduce_transpose(
        ins, shapes, attrs["perm"]),
    "reshape": lambda ins, shapes, attrs: _deduce_reshape(
        ins, shapes, attrs["new_shape"]),
    "embedding": lambda ins, shapes, attrs: _deduce_embedding(ins, shapes),
    "softmax": lambda ins, shapes, attrs: _deduce_softmax(ins, shapes),
    "rmsnorm": lambda ins, shapes, attrs: _deduce_norm(ins, shapes),
    "layernorm": lambda ins, shapes, attrs: _deduce_norm(ins, shapes),
    "div": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "gather": lambda ins, shapes, attrs: _deduce_gather(ins, shapes),
    "attention": lambda ins, shapes, attrs: _deduce_attention(ins, shapes),
    # backward-only kernels (reverse-mode autodiff, Graph.backward)
    "relu_grad": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "gelu_grad": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "silu_grad": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "mul_grad": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "softmax_grad": lambda ins, shapes, attrs: _deduce_linear_grad(
        ins, shapes),
    "norm_grad_x": lambda ins, shapes, attrs: _deduce_norm_grad_x(
        ins, shapes),
    "norm_grad_w": lambda ins, shapes, attrs: _deduce_reduce_to_vector(
        ins, shapes),
    "norm_grad_b": lambda ins, shapes, attrs: _deduce_reduce_to_vector(
        ins, shapes),
    "gather_grad": lambda ins, shapes, attrs: _deduce_gather_grad(ins, shapes),
    # attn_grad_k/v output k/v-shaped grads, but the split DIMS and
    # shard COUNTS equal dy's (head-group splits pair q and kv heads),
    # so the linear-grad rule's pass-through of dy's annotation is exact
    "attn_grad_q": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "attn_grad_k": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "attn_grad_v": lambda ins, shapes, attrs: _deduce_linear_grad(ins, shapes),
    "bcast": lambda ins, shapes, attrs: _deduce_bcast(
        ins, shapes, attrs["dim"]),
    "embed_grad": lambda ins, shapes, attrs: _deduce_embed_grad(ins, shapes),
}

# ops whose outputs carry EXPLICIT annotations (not deduced): graph
# leaves, CommOps, and the autodiff gradient seed
LEAF_KINDS = ("placeholder", "parameter", "comm", "ones")


# ---------------------------------------------------------------------------
# cotangent annotations (reverse-mode autodiff, paper §5.2 one level down)
# ---------------------------------------------------------------------------

def cotangent_annot(a: HSPMD) -> HSPMD:
    """The canonical annotation of a tensor's gradient: Split stays
    Split (the grad of a shard is the shard of the grad), while
    Duplicate and Partial SWAP — a replicated tensor consumed by many
    devices accumulates per-device grad summands (Partial), and a
    Partial tensor's summands each receive the full grad (Duplicate).
    This is the transpose of the linear map the placement realizes."""
    def swap(d: int) -> int:
        return PARTIAL if d == DUP else (DUP if d == PARTIAL else d)

    dss = [DS([(swap(d), n) for d, n in ds.entries]) for ds in a.dss]
    return HSPMD(a.dgs, dss, hdim=swap(a.hdim), hsplits=a.hsplits)


def departialize(a: HSPMD) -> HSPMD:
    """``a`` with every Partial entry turned into Duplicate (the
    annotation after an in-group all-reduce): the full-value carrier of
    the same placement geometry."""
    dss = []
    for ds in a.dss:
        m: dict[int, int] = {}
        order: list[int] = []
        for d, n in ds.entries:
            d = DUP if d == PARTIAL else d
            if d in m:
                m[d] *= n
            else:
                m[d] = n
                order.append(d)
        dss.append(DS([(d, m[d]) for d in order]))
    hdim = DUP if a.hdim == PARTIAL else a.hdim
    return HSPMD(a.dgs, dss, hdim=hdim, hsplits=a.hsplits)


def annots_equal(a: HSPMD, b: HSPMD) -> bool:
    """Exact placement equality (entry order matters: it fixes the
    device -> shard coordinate decomposition)."""
    return (a.same_dg_union(b)
            and all(x.entries == y.entries for x, y in zip(a.dss, b.dss))
            and a.hdim == b.hdim and a.hsplits == b.hsplits)


# ---------------------------------------------------------------------------
# graph builder
# ---------------------------------------------------------------------------

class Graph:
    """Single-device-view program with declarative HSPMD annotations."""

    def __init__(self):
        self.ops: list[Op] = []
        self.tensors: dict[str, Tensor] = {}
        self._n = 0
        # reverse-mode autodiff provenance (Graph.backward): forward
        # tensor name -> its gradient tensor's name, and the loss the
        # backward extension was seeded from
        self.grad_map: dict[str, str] = {}
        self.loss_name: str | None = None

    # -- leaves -------------------------------------------------------------
    def _add_tensor(self, name, shape, annots=None, producer=None) -> Tensor:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor {name}")
        t = Tensor(name, tuple(shape), list(annots or []), producer)
        self.tensors[name] = t
        return t

    def placeholder(self, name: str, shape,
                    annots: Sequence[HSPMD] | None = None) -> Tensor:
        t = self._add_tensor(name, shape, annots)
        self.ops.append(Op("placeholder", [], [t]))
        t.producer = self.ops[-1]
        return t

    def parameter(self, name: str, shape,
                  annots: Sequence[HSPMD] | None = None) -> Tensor:
        t = self._add_tensor(name, shape, annots)
        self.ops.append(Op("parameter", [], [t]))
        t.producer = self.ops[-1]
        return t

    # -- CommOp (§5.1) -------------------------------------------------------
    def comm(self, x: Tensor, annots: Sequence[HSPMD] | HSPMD | None = None,
             name: str | None = None) -> Tensor:
        if isinstance(annots, HSPMD):
            annots = [annots]
        name = name or f"{x.name}'"
        out = self._add_tensor(name, x.shape, list(annots or []))
        op = Op("comm", [x], [out], {"id": sum(1 for o in self.ops
                                               if o.kind == "comm") + 1})
        self.ops.append(op)
        out.producer = op
        return out

    # -- compute ops ----------------------------------------------------------
    def _compute(self, kind: str, ins: list[Tensor], out_shape,
                 name: str | None = None, **attrs) -> Tensor:
        name = name or f"{kind}_{self._n}"
        self._n += 1
        out = self._add_tensor(name, out_shape)
        op = Op(kind, list(ins), [out], dict(attrs))
        self.ops.append(op)
        out.producer = op
        return out

    def gelu(self, x, name=None):
        return self._compute("gelu", [x], x.shape, name)

    def relu(self, x, name=None):
        return self._compute("relu", [x], x.shape, name)

    def add(self, a, b, name=None):
        return self._compute("add", [a, b], a.shape, name)

    def mul(self, a, b, name=None):
        return self._compute("mul", [a, b], a.shape, name)

    def dot(self, x, w, name=None):
        out_shape = tuple(x.shape[:-1]) + (w.shape[-1],)
        return self._compute("dot", [x, w], out_shape, name)

    def sum(self, x, dim: int, name=None):
        out_shape = tuple(s for i, s in enumerate(x.shape) if i != dim)
        return self._compute("sum", [x], out_shape, name, dim=dim)

    def bcast(self, x, dim: int, size, name=None):
        """Insert a broadcast dim of ``size`` at ``dim`` (inverse of
        ``sum``) — e.g. lifting a ``(d,)`` bias onto ``(B, S, d)``."""
        out_shape = tuple(x.shape[:dim]) + (size,) + tuple(x.shape[dim:])
        return self._compute("bcast", [x], out_shape, name, dim=dim,
                             size=size)

    def transpose(self, x, perm, name=None):
        out_shape = tuple(x.shape[p] for p in perm)
        return self._compute("transpose", [x], out_shape, name,
                             perm=tuple(perm))

    def reshape(self, x, new_shape, name=None):
        return self._compute("reshape", [x], tuple(new_shape), name,
                             new_shape=tuple(new_shape))

    def embedding(self, table, ids, name=None):
        """Row lookup ``out[b..., :] = table[ids[b...], :]`` (the token
        embedding of a language model; indices are global vocab ids)."""
        if len(table.shape) != 2:
            raise ValueError("embedding expects a 2D (vocab, dim) table")
        out_shape = tuple(ids.shape) + (table.shape[-1],)
        return self._compute("embedding", [table, ids], out_shape, name)

    def silu(self, x, name=None):
        return self._compute("silu", [x], x.shape, name)

    def rsqrt(self, x, name=None):
        return self._compute("rsqrt", [x], x.shape, name)

    def div(self, a, b, name=None):
        """Elementwise ``a / b`` (same shapes; linear in ``a``)."""
        return self._compute("div", [a, b], a.shape, name)

    def scale(self, x, factor: float, name=None):
        return self._compute("scale", [x], x.shape, name,
                             factor=float(factor))

    def softmax(self, x, name=None):
        """Softmax over the LAST dim."""
        return self._compute("softmax", [x], x.shape, name)

    def rmsnorm(self, x, w, eps: float = 1e-5, name=None):
        """RMSNorm over the last dim: ``x * rsqrt(mean(x^2) + eps) * w``."""
        return self._compute("rmsnorm", [x, w], x.shape, name,
                             norm="rms", eps=float(eps))

    def layernorm(self, x, w, b, eps: float = 1e-5, name=None):
        """LayerNorm over the last dim: ``(x - mu) * rsqrt(var + eps) * w + b``."""
        return self._compute("layernorm", [x, w, b], x.shape, name,
                             norm="layer", eps=float(eps))

    def gather(self, x, ids, name=None):
        """``out[b...] = x[b..., ids[b...]]`` — take along x's last axis
        (the label-probability pick of a cross-entropy loss)."""
        if len(ids.shape) != len(x.shape) - 1:
            raise ValueError(
                f"gather expects ids with rank {len(x.shape) - 1}, got "
                f"{len(ids.shape)}")
        return self._compute("gather", [x, ids], tuple(ids.shape), name)

    def attention(self, q, k, v, causal: bool = True, name=None):
        """Scaled-dot-product attention: ``q (B, H, Sq, D)``, ``k``/``v``
        ``(B, K, Sk, D)`` with ``H % K == 0`` (GQA).  Lowered per device
        to the Pallas flash kernel or the pure-XLA reference according
        to ``kernels.policy`` (see ``runtime.program``)."""
        for t in (q, k, v):
            if len(t.shape) != 4:
                raise ValueError("attention expects 4D (B, heads, S, D)")
        H, K = q.shape[1], k.shape[1]
        if isinstance(H, int) and isinstance(K, int) and H % K != 0:
            raise ValueError(
                f"attention query heads {H} not a multiple of kv heads {K}")
        return self._compute("attention", [q, k, v], q.shape, name,
                             causal=bool(causal))

    def transformer_block(self, cfg, **kw):
        """Append one full transformer block (pre-norm attention + MLP)
        shaped by a ``configs`` ModelConfig; see ``models.graph_block``
        for the layout and the TP×DP×PP annotation helper."""
        from ..models.graph_block import build_block
        return build_block(self, cfg, **kw)

    # -- reverse-mode autodiff ----------------------------------------------
    def _bwd(self, kind: str, ins: list[Tensor], out_shape, anchor: str,
             grad_of: str | None = None, name: str | None = None,
             **attrs) -> Tensor:
        """Append one backward op and deduce its annotations immediately
        (the forward graph is already annotated, and backward ops are
        built in dataflow order, so every input is annotated).  Every
        backward op carries ``phase="bwd"`` plus ``fwd_anchor`` — the
        forward tensor whose (virtual) pipeline stage it executes in —
        and grad-producing ops additionally carry ``grad_of``."""
        t = self._compute(kind, list(ins), out_shape, name, **attrs)
        op = t.producer
        op.attrs["phase"] = "bwd"
        op.attrs["fwd_anchor"] = anchor
        if grad_of is not None:
            op.attrs["grad_of"] = grad_of
        rule = DEDUCTION_RULES[kind]
        shapes = [i.shape for i in ins]
        n = max(len(i.annots) for i in ins)
        t.annots = [rule([i.annots[k] for i in ins], shapes, op.attrs)
                    for k in range(n)]
        return t

    def _bwd_comm(self, x: Tensor, annots, anchor: str,
                  grad_of: str | None = None,
                  name: str | None = None) -> Tensor:
        # a Partial gradient whose SPLIT structure also changes (e.g.
        # dw [(Partial,dp),(1,tp)] -> a replicated param) is not one
        # collective; all-reduce in place first, then redistribute
        hops, hop_needed = [], False
        for have, tgt in zip(x.annots, list(annots)):
            def _splits(a):
                return [{d: n for d, n in ds.entries if d >= 0}
                        for ds in a.dss]
            if (have.has_partial and not annots_equal(have, tgt)
                    and any(_splits(have)) and _splits(have) != _splits(tgt)
                    and have.same_dg_union(tgt)):
                hops.append(departialize(have))
                hop_needed = True
            else:
                hops.append(have)
        if hop_needed:
            x = self._bwd_comm(x, hops, anchor)
        out = self.comm(x, list(annots), name=name)
        op = out.producer
        op.attrs["phase"] = "bwd"
        op.attrs["fwd_anchor"] = anchor
        if grad_of is not None:
            op.attrs["grad_of"] = grad_of
        return out

    def _canonicalize_grad(self, gt: Tensor, x: Tensor, anchor: str,
                           grad_of: str) -> Tensor:
        """Reshard gradient contribution ``gt`` onto ``x``'s cotangent
        placement (:func:`cotangent_annot`) so backward deduction always
        sees the same sharding patterns the forward graph used.

        Where the cotangent keeps a Partial that communication cannot
        create (comm resolution never *introduces* summands), the
        departialized full-value carrier is used instead; a Partial
        contribution that must cross device groups is all-reduced in
        its own group first (Partial tensors cannot move across unions,
        paper §4.3)."""
        n = len(x.annots)
        wants = [cotangent_annot(a) for a in x.annots]
        targets: list[HSPMD] = []
        need = False
        for k in range(n):
            have, want = gt.annots[k], wants[k]
            if annots_equal(have, want) or \
                    annots_equal(have, departialize(want)):
                targets.append(have)
                continue
            targets.append(departialize(want) if want.has_partial
                           else want)
            need = True
        if not need:
            return gt
        hops: list[HSPMD] = []
        hop_needed = False
        for k in range(n):
            have, tgt = gt.annots[k], targets[k]
            if have.has_partial and not annots_equal(have, tgt) and (
                    have.hsize != tgt.hsize
                    or not have.same_dg_union(tgt)):
                hops.append(departialize(have))
                hop_needed = True
            else:
                hops.append(have)
        if hop_needed:
            gt = self._bwd_comm(gt, hops, anchor)
        return self._bwd_comm(gt, targets, anchor, grad_of=grad_of)

    def backward(self, loss: "Tensor | str | None" = None,
                 wrt: "Sequence[Tensor | str] | None" = None
                 ) -> dict[str, str]:
        """Extend this *deduced* forward graph in place with its
        reverse-mode backward pass (the joint fwd+bwd training graph).

        A per-op-kind VJP registry (:data:`VJP_RULES`) emits each
        operator's backward as ordinary graph ops, so the existing
        deduction rules propagate DS annotations through the backward
        half unchanged; gradient contributions are resharded onto each
        tensor's cotangent placement (Split stays Split, Duplicate and
        Partial swap), accumulated across consumers, and finally every
        parameter gradient is communicated onto the parameter's OWN
        annotation (Partial -> Duplicate becomes an all-reduce, Partial
        -> Split a reduce-scatter over the DP dim — resolved by §4 comm
        resolution like any other CommOp).

        ``loss`` defaults to the graph's single scalar sink; ``wrt``
        to all parameters.  Returns (and stores as ``self.grad_map``)
        the ``forward tensor name -> gradient tensor name`` provenance.
        """
        if self.grad_map:
            raise GradError("graph already extended with a backward pass")
        if loss is None:
            scalars = [t for t in self.sinks() if tuple(t.shape) == ()]
            if len(scalars) != 1:
                raise GradError(
                    f"graph has {len(scalars)} scalar sink(s); pass "
                    f"loss= to pick the tensor to differentiate")
            loss_t = scalars[0]
        else:
            name = loss.name if isinstance(loss, Tensor) else loss
            if name not in self.tensors:
                raise GradError(f"unknown loss tensor {name!r}")
            loss_t = self.tensors[name]
        if tuple(loss_t.shape) != ():
            raise GradError(
                f"loss {loss_t.name!r} must be scalar; got shape "
                f"{loss_t.shape} (reduce it with sum)")
        if not loss_t.annots:
            raise GradError(
                "run deduce() before backward(): autodiff propagates "
                "the deduced annotations through the backward ops")
        params = [p if isinstance(p, Tensor) else self.tensors[p]
                  for p in (wrt if wrt is not None else self.parameters())]

        fwd_ops = list(self.ops)
        contributions: dict[str, list[Tensor]] = {}
        grad_map: dict[str, str] = {}

        # seed: dL/dL == 1 on the loss's cotangent placement (a Partial
        # loss — per-device summands — receives a Duplicate seed).  The
        # full-value carrier (departialize) is essential: a Duplicate
        # entry in the loss swaps to Partial in the cotangent, and a
        # "ones" op materializing 1.0 per summand would represent a seed
        # of n, silently scaling every gradient
        seed = self._add_tensor(
            f"d/{loss_t.name}", (),
            [departialize(cotangent_annot(a)) for a in loss_t.annots])
        seed_op = Op("ones", [], [seed],
                     {"phase": "bwd", "grad_of": loss_t.name,
                      "fwd_anchor": loss_t.name})
        self.ops.append(seed_op)
        seed.producer = seed_op
        contributions[loss_t.name] = [seed]

        def combine(t: Tensor) -> "Tensor | None":
            contribs = contributions.get(t.name)
            if not contribs:
                return None
            n = len(t.annots)
            if len(contribs) > 1 and any(
                    not annots_equal(c.annots[k], contribs[0].annots[k])
                    for c in contribs[1:] for k in range(n)):
                # mixed Partial/Duplicate carriers: converge on the
                # full-value carrier of the cotangent placement
                wants = [cotangent_annot(a) for a in t.annots]
                targets = [
                    contribs[0].annots[k]
                    if all(annots_equal(c.annots[k], contribs[0].annots[k])
                           for c in contribs[1:])
                    else departialize(wants[k])
                    for k in range(n)]
                contribs = [
                    c if all(annots_equal(c.annots[k], targets[k])
                             for k in range(n))
                    else self._bwd_comm(c, targets, anchor=t.name)
                    for c in contribs]
            acc = contribs[0]
            for c in contribs[1:]:
                acc = self._bwd("add", [acc, c], tuple(t.shape),
                                anchor=t.name, grad_of=t.name)
            grad_map[t.name] = acc.name
            return acc

        for op in reversed(fwd_ops):
            if op.kind in ("placeholder", "parameter"):
                continue
            out = op.outputs[0]
            dy = combine(out)
            if dy is None:
                continue  # not on the loss path
            rule = VJP_RULES.get(op.kind)
            if rule is None:
                raise GradError(f"no VJP rule for op kind {op.kind!r}")
            for x, gt in zip(op.inputs, rule(self, op, dy)):
                if gt is None:
                    continue
                gt = self._canonicalize_grad(gt, x, anchor=out.name,
                                             grad_of=x.name)
                contributions.setdefault(x.name, []).append(gt)

        # parameter gradients: reduce onto the parameter's own placement
        # so the optimizer applies elementwise sharded updates — a
        # Duplicate(DP) param's Partial grad all-reduces, a Split param's
        # Partial grad reduce-scatters (comm_resolve picks the operator)
        for p in params:
            gt = combine(p)
            if gt is None:
                raise GradError(
                    f"parameter {p.name!r} is not on the loss path")
            if any(not annots_equal(gt.annots[k], p.annots[k])
                   for k in range(len(p.annots))):
                gt = self._bwd_comm(gt, list(p.annots), anchor=p.name,
                                    grad_of=p.name, name=f"d/{p.name}")
            grad_map[p.name] = gt.name
        for op in fwd_ops:         # input grads are useful fetches too
            if op.kind == "placeholder" and \
                    op.outputs[0].name not in grad_map:
                combine(op.outputs[0])
        self.grad_map = grad_map
        self.loss_name = loss_t.name
        return grad_map

    # -- deduction (§5.2) -----------------------------------------------------
    def deduce(self) -> "Graph":
        """Fill in annotations for every tensor, per strategy index."""
        n_strat = max((len(t.annots) for t in self.tensors.values()
                       if t.annots), default=1)
        for op in self.ops:
            if op.kind in LEAF_KINDS:
                for t in op.outputs:
                    if not t.annots:
                        raise DeductionError(f"leaf/comm {t.name} needs annots")
                    if len(t.annots) not in (1, n_strat):
                        raise DeductionError(
                            f"{t.name}: {len(t.annots)} annots, expected "
                            f"1 or {n_strat}")
                    if len(t.annots) == 1 and n_strat > 1:
                        t.annots = t.annots * n_strat
                continue
            rule = DEDUCTION_RULES.get(op.kind)
            if rule is None:
                raise DeductionError(f"no deduction rule for op {op.kind}")
            shapes = [t.shape for t in op.inputs]
            for t in op.outputs:
                t.annots = []
            for k in range(n_strat):
                ins = [t.annots[k] for t in op.inputs]
                out = rule(ins, shapes, op.attrs)
                for t in op.outputs:
                    t.annots.append(out)
        return self

    @property
    def comm_ops(self) -> list[Op]:
        return [o for o in self.ops if o.kind == "comm"]

    def parameters(self) -> list[Tensor]:
        return [o.outputs[0] for o in self.ops if o.kind == "parameter"]

    def placeholders(self) -> list[Tensor]:
        return [o.outputs[0] for o in self.ops if o.kind == "placeholder"]

    def annotation_points(self) -> list[Tensor]:
        """Tensors that carry *explicit* (non-deduced) annotations: leaves
        and CommOp outputs — exactly what a parallel-strategy bundle must
        cover (paper §6.1's multiple-annotation binding sites)."""
        return [o.outputs[0] for o in self.ops
                if o.kind in ("placeholder", "parameter", "comm")]

    def sinks(self) -> list[Tensor]:
        """Tensors no op consumes — the program's default outputs."""
        consumed = {id(t) for o in self.ops for t in o.inputs}
        return [o.outputs[0] for o in self.ops
                if o.outputs and id(o.outputs[0]) not in consumed]

    def deduction_report(self) -> "DeductionReport":
        """Run deduction and return a stable summary the API layer
        composes (tensor/op counts, per-strategy device universes)."""
        self.deduce()
        n_strat = max((len(t.annots) for t in self.tensors.values()
                       if t.annots), default=1)
        devices = []
        for k in range(n_strat):
            devs: set[int] = set()
            for t in self.tensors.values():
                if t.annots:
                    devs |= set(t.annots[k].devices)
            devices.append(tuple(sorted(devs)))
        return DeductionReport(
            n_strategies=n_strat,
            n_ops=len(self.ops),
            n_comm_ops=len(self.comm_ops),
            n_tensors=len(self.tensors),
            devices=tuple(devices),
        )


# ---------------------------------------------------------------------------
# per-op-kind VJP registry (reverse-mode autodiff)
# ---------------------------------------------------------------------------
#
# Each rule takes ``(g, op, dy)`` — the graph, the forward op, and the
# (already combined) gradient of the op's output — and returns one
# gradient contribution per op input (``None`` for non-differentiable
# inputs such as integer indices).  Rules emit ordinary graph ops via
# ``g._bwd`` so DS/HDim deduction runs through them unchanged; the
# caller (``Graph.backward``) reshards every contribution onto the
# input's cotangent placement.

def _vjp_elementwise_act(kind_grad: str):
    def vjp(g: "Graph", op: Op, dy: Tensor) -> list:
        (x,) = op.inputs
        anchor = op.outputs[0].name
        return [g._bwd(kind_grad, [dy, x], tuple(x.shape), anchor,
                       grad_of=x.name)]
    return vjp


def _vjp_scale(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    return [g._bwd("scale", [dy], tuple(x.shape), op.outputs[0].name,
                   grad_of=x.name, factor=op.attrs.get("factor", 1.0))]


def _vjp_add(g: "Graph", op: Op, dy: Tensor) -> list:
    return [dy, dy]


def _vjp_mul(g: "Graph", op: Op, dy: Tensor) -> list:
    a, b = op.inputs
    anchor = op.outputs[0].name
    da = g._bwd("mul_grad", [dy, b], tuple(a.shape), anchor, grad_of=a.name)
    db = g._bwd("mul_grad", [dy, a], tuple(b.shape), anchor, grad_of=b.name)
    return [da, db]


def _vjp_dot(g: "Graph", op: Op, dy: Tensor) -> list:
    x, w = op.inputs
    anchor = op.outputs[0].name
    wt = g._bwd("transpose", [w], (w.shape[1], w.shape[0]), anchor,
                perm=(1, 0))
    dx = g._bwd("dot", [dy, wt], tuple(x.shape), anchor, grad_of=x.name)
    if len(x.shape) == 2:
        x2, dy2 = x, dy
    else:
        # symbolic leading dims flatten as expression trees (prod_dims)
        # and bind alongside the rest of the shape at compile time
        from .symbolic import prod_dims
        m = prod_dims(x.shape[:-1])
        x2 = g._bwd("reshape", [x], (m, x.shape[-1]), anchor,
                    new_shape=(m, x.shape[-1]))
        dy2 = g._bwd("reshape", [dy], (m, w.shape[1]), anchor,
                     new_shape=(m, w.shape[1]))
    xt = g._bwd("transpose", [x2], (x2.shape[1], x2.shape[0]), anchor,
                perm=(1, 0))
    dw = g._bwd("dot", [xt, dy2], tuple(w.shape), anchor, grad_of=w.name)
    return [dx, dw]


def _vjp_sum(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    dim = op.attrs["dim"]
    return [g._bwd("bcast", [dy], tuple(x.shape), op.outputs[0].name,
                   grad_of=x.name, dim=dim, size=x.shape[dim])]


def _vjp_bcast(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    return [g._bwd("sum", [dy], tuple(x.shape), op.outputs[0].name,
                   grad_of=x.name, dim=op.attrs["dim"])]


def _vjp_transpose(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    perm = op.attrs["perm"]
    inv = [0] * len(perm)
    for new, old in enumerate(perm):
        inv[old] = new
    return [g._bwd("transpose", [dy], tuple(x.shape), op.outputs[0].name,
                   grad_of=x.name, perm=tuple(inv))]


def _vjp_reshape(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    return [g._bwd("reshape", [dy], tuple(x.shape), op.outputs[0].name,
                   grad_of=x.name, new_shape=tuple(x.shape))]


def _vjp_embedding(g: "Graph", op: Op, dy: Tensor) -> list:
    table, ids = op.inputs
    dt = g._bwd("embed_grad", [dy, ids], tuple(table.shape),
                op.outputs[0].name, grad_of=table.name,
                vocab=table.shape[0])
    return [dt, None]  # integer indices carry no gradient


def _vjp_comm(g: "Graph", op: Op, dy: Tensor) -> list:
    # the redistribution map is linear; its transpose is realized by the
    # caller's cotangent resharding of this contribution (an AR/RS/AG/BSR
    # mirroring the forward CommOp), so the rule itself is the identity
    return [dy]


def _vjp_softmax(g: "Graph", op: Op, dy: Tensor) -> list:
    (x,) = op.inputs
    y = op.outputs[0]
    return [g._bwd("softmax_grad", [dy, y], tuple(x.shape), y.name,
                   grad_of=x.name)]


def _vjp_rsqrt(g: "Graph", op: Op, dy: Tensor) -> list:
    # d(x^-1/2)/dx = -x^-3/2 / 2 = -y^3 / 2, from the saved output
    (x,) = op.inputs
    y = op.outputs[0]
    t = dy
    for _ in range(3):
        t = g._bwd("mul_grad", [t, y], tuple(x.shape), y.name)
    return [g._bwd("scale", [t], tuple(x.shape), y.name, grad_of=x.name,
                   factor=-0.5)]


def _vjp_div(g: "Graph", op: Op, dy: Tensor) -> list:
    a, b = op.inputs
    y = op.outputs[0]
    da = g._bwd("div", [dy, b], tuple(a.shape), y.name, grad_of=a.name)
    # db = -dy * a / b^2 = -(dy * y) / b, reusing the saved quotient
    t = g._bwd("mul_grad", [dy, y], tuple(b.shape), y.name)
    t = g._bwd("div", [t, b], tuple(b.shape), y.name)
    db = g._bwd("scale", [t], tuple(b.shape), y.name, grad_of=b.name,
                factor=-1.0)
    return [da, db]


def _vjp_norm(g: "Graph", op: Op, dy: Tensor) -> list:
    x, w = op.inputs[0], op.inputs[1]
    anchor = op.outputs[0].name
    attrs = {"norm": op.attrs.get("norm", "rms"),
             "eps": op.attrs.get("eps", 1e-5)}
    dx = g._bwd("norm_grad_x", [dy, x, w], tuple(x.shape), anchor,
                grad_of=x.name, **attrs)
    dw = g._bwd("norm_grad_w", [dy, x], tuple(w.shape), anchor,
                grad_of=w.name, **attrs)
    grads = [dx, dw]
    if len(op.inputs) == 3:       # layernorm bias
        b = op.inputs[2]
        grads.append(g._bwd("norm_grad_b", [dy], tuple(b.shape), anchor,
                            grad_of=b.name))
    return grads


def _vjp_gather(g: "Graph", op: Op, dy: Tensor) -> list:
    x, ids = op.inputs
    dx = g._bwd("gather_grad", [dy, ids], tuple(x.shape),
                op.outputs[0].name, grad_of=x.name)
    return [dx, None]  # integer indices carry no gradient


def _vjp_attention(g: "Graph", op: Op, dy: Tensor) -> list:
    q, k, v = op.inputs
    anchor = op.outputs[0].name
    causal = op.attrs.get("causal", True)
    return [g._bwd(kind, [dy, q, k, v], tuple(t.shape), anchor,
                   grad_of=t.name, causal=causal)
            for kind, t in (("attn_grad_q", q), ("attn_grad_k", k),
                            ("attn_grad_v", v))]


VJP_RULES = {
    "gelu": _vjp_elementwise_act("gelu_grad"),
    "relu": _vjp_elementwise_act("relu_grad"),
    "silu": _vjp_elementwise_act("silu_grad"),
    "scale": _vjp_scale,
    "add": _vjp_add,
    "mul": _vjp_mul,
    "dot": _vjp_dot,
    "sum": _vjp_sum,
    "bcast": _vjp_bcast,
    "transpose": _vjp_transpose,
    "reshape": _vjp_reshape,
    "embedding": _vjp_embedding,
    "comm": _vjp_comm,
    "softmax": _vjp_softmax,
    "rsqrt": _vjp_rsqrt,
    "div": _vjp_div,
    "rmsnorm": _vjp_norm,
    "layernorm": _vjp_norm,
    "gather": _vjp_gather,
    "attention": _vjp_attention,
}


@dataclass(frozen=True)
class DeductionReport:
    """Stable result of annotation deduction over a graph."""

    n_strategies: int
    n_ops: int
    n_comm_ops: int
    n_tensors: int
    devices: tuple[tuple[int, ...], ...]  # per-strategy device universe
