"""Batched-send-receive (BSR) planning (paper §4.3, Fig 8) and the fused
multi-tensor variant used by dynamic graph switching (paper §6.2, Fig 12).

The planner builds a *BSR table* mapping every finest-grained slice to its
owner devices and the devices that need it, then picks a sender per
(slice, receiver) with the paper's three heuristics:

  (I)   local copy when the receiver already owns the slice,
  (II)  prefer the highest-bandwidth owner->receiver link,
  (III) tie-break on the lowest cumulative send load.

``plan_bsr_naive`` omits (II)/(III) and fusion — the paper's Fig 18 / Table 2
baseline ("Unfused BSR w/o Heuristics", minimal rank id sends).

Fusion (``fuse``): transfers between the same (src, dst) pair — across *all*
tensors of a switch — are coalesced into one message to amortize launch
latency; the fused plan also shares one global cumulative-load state so
heuristic (III) balances the whole transition, not each tensor separately.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from .annotations import HSPMD
from .plan import (Box, CommStep, SliceGroup, box_intersect, box_nbytes)
from .topology import Topology, UniformTopology


class PartialBsrError(ValueError):
    """BSR cannot move *Partial* tensors (paper §4.3 Discussions)."""


@dataclass
class BsrEntry:
    """One row of the BSR table: a fine slice, who owns it, who needs it."""

    box: Box
    owners: tuple[int, ...]
    needers: tuple[int, ...]
    tensor: str = ""
    itemsize: int = 2


@dataclass
class BsrAssignment:
    src: int
    dst: int
    box: Box
    tensor: str = ""
    itemsize: int = 2
    local: bool = False

    @property
    def nbytes(self) -> int:
        return box_nbytes(self.box, self.itemsize)


@dataclass
class BsrPlan:
    assignments: list[BsrAssignment] = field(default_factory=list)
    fused: bool = True

    # -- statistics (paper Table 2 / Fig 18) -------------------------------
    def transfers(self) -> list[BsrAssignment]:
        return [a for a in self.assignments if not a.local]

    def local_copies(self) -> list[BsrAssignment]:
        return [a for a in self.assignments if a.local]

    def message_count(self) -> int:
        """Messages after (optional) per-pair fusion."""
        xs = self.transfers()
        if not self.fused:
            return len(xs)
        return len({(a.src, a.dst) for a in xs})

    def total_bytes(self) -> int:
        return sum(a.nbytes for a in self.transfers())

    def per_sender_bytes(self, topology: Topology | None = None,
                         fast_threshold: float | None = None
                         ) -> dict[int, tuple[int, int]]:
        """Per-sender (fast-link bytes, slow-link bytes) — the Table 2 shape.

        A link is "fast" when its bandwidth exceeds ``fast_threshold``
        (defaults to the mean of observed link bandwidths).
        """
        topology = topology or UniformTopology()
        bands = {(a.src, a.dst): topology.bandwidth(a.src, a.dst)
                 for a in self.transfers()}
        if fast_threshold is None:
            fast_threshold = (sum(bands.values()) / len(bands)) if bands else 0.0
        out: dict[int, tuple[int, int]] = {}
        for a in self.transfers():
            fast, slow = out.get(a.src, (0, 0))
            if bands[(a.src, a.dst)] >= fast_threshold:
                fast += a.nbytes
            else:
                slow += a.nbytes
            out[a.src] = (fast, slow)
        return out

    def est_time(self, topology: Topology | None = None,
                 launch_us: float = 10.0) -> float:
        """Completion-time proxy: max over senders of serialized send time,
        plus per-message launch latency."""
        topology = topology or UniformTopology()
        per_sender: dict[int, float] = {}
        for a in self.transfers():
            per_sender[a.src] = per_sender.get(a.src, 0.0) + \
                topology.time_for(a.src, a.dst, a.nbytes)
        t = max(per_sender.values(), default=0.0)
        return t + self.message_count() * launch_us * 1e-6

    def to_step(self) -> CommStep:
        groups = tuple(
            SliceGroup(a.box, (a.src,), (a.dst,), reduce=False)
            for a in self.transfers())
        return CommStep("BSR", groups)


# ---------------------------------------------------------------------------
# table construction
# ---------------------------------------------------------------------------

def _cuts(boxes: list[Box], ndim: int) -> list[list[int]]:
    cuts = [set() for _ in range(ndim)]
    for b in boxes:
        for d, (lo, hi) in enumerate(b):
            cuts[d].add(lo)
            cuts[d].add(hi)
    return [sorted(c) for c in cuts]


def build_table(src: HSPMD, dst: HSPMD, shape: tuple[int, ...],
                tensor: str = "", itemsize: int = 2) -> list[BsrEntry]:
    """Finest-grained slice table (paper Fig 8, left)."""
    if src.has_partial or dst.has_partial:
        raise PartialBsrError(
            f"BSR cannot repartition Partial tensors (tensor={tensor!r})")
    src_boxes = {d: src.device_box(d, shape) for d in src.devices}
    dst_boxes = {d: dst.device_box(d, shape) for d in dst.devices}

    entries: list[BsrEntry] = []
    # Fine slices are generated per *receiver* box, refined against source
    # cuts only — this keeps the table linear in receivers for the common
    # aligned cases while remaining exact.
    cut_lists = _cuts(list(src_boxes.values()), len(shape))
    for recv, rbox in dst_boxes.items():
        # refine rbox by source cuts
        dim_segs: list[list[tuple[int, int]]] = []
        for d, (lo, hi) in enumerate(rbox):
            pts = [lo] + [c for c in cut_lists[d] if lo < c < hi] + [hi]
            dim_segs.append(list(zip(pts[:-1], pts[1:])))
        # enumerate cells
        def rec(d: int, prefix: list[tuple[int, int]]):
            if d == len(shape):
                cell = tuple(prefix)
                owners = tuple(dev for dev, b in src_boxes.items()
                               if box_intersect(b, cell) == cell)
                if not owners:
                    raise AssertionError(f"no owner for slice {cell}")
                entries.append(BsrEntry(cell, owners, (recv,), tensor, itemsize))
                return
            for seg in dim_segs[d]:
                rec(d + 1, prefix + [seg])
        rec(0, [])
    # merge needers of identical (box, owners, tensor) rows
    merged: dict[tuple, BsrEntry] = {}
    for e in entries:
        key = (e.box, e.owners, e.tensor)
        if key in merged:
            m = merged[key]
            m.needers = tuple(sorted(set(m.needers) | set(e.needers)))
        else:
            merged[key] = dataclasses.replace(e)
    return list(merged.values())


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

def _plan(entries: list[BsrEntry], topology: Topology,
          use_heuristics: bool, send_load: dict[int, int]) -> list[BsrAssignment]:
    out: list[BsrAssignment] = []
    for e in entries:
        for recv in e.needers:
            # heuristic (I): local copy
            if recv in e.owners:
                out.append(BsrAssignment(recv, recv, e.box, e.tensor,
                                         e.itemsize, local=True))
                continue
            if use_heuristics:
                # (II) highest bandwidth, (III) lowest cumulative send load
                sender = min(
                    e.owners,
                    key=lambda s: (-topology.bandwidth(s, recv),
                                   send_load.get(s, 0), s))
            else:
                sender = min(e.owners)  # minimal rank id (paper baseline)
            a = BsrAssignment(sender, recv, e.box, e.tensor, e.itemsize)
            send_load[sender] = send_load.get(sender, 0) + a.nbytes
            out.append(a)
    return out


def plan_bsr(src: HSPMD, dst: HSPMD, shape: tuple[int, ...],
             topology: Topology | None = None, tensor: str = "",
             itemsize: int = 2) -> BsrPlan:
    """Single-tensor BSR with heuristics + per-pair fusion."""
    topology = topology or UniformTopology()
    entries = build_table(src, dst, shape, tensor, itemsize)
    return BsrPlan(_plan(entries, topology, True, {}), fused=True)


def plan_bsr_naive(src: HSPMD, dst: HSPMD, shape: tuple[int, ...],
                   tensor: str = "", itemsize: int = 2) -> BsrPlan:
    """Paper baseline: min-rank-id senders, no fusion."""
    entries = build_table(src, dst, shape, tensor, itemsize)
    return BsrPlan(_plan(entries, UniformTopology(), False, {}), fused=False)


def plan_fused_bsr(tensors: list[tuple[str, HSPMD, HSPMD, tuple[int, ...], int]],
                   topology: Topology | None = None) -> BsrPlan:
    """Fused multi-tensor BSR (paper §6.2): one global table, one shared
    cumulative-load state, per-pair message fusion across tensors.

    ``tensors``: (name, src annot, dst annot, global shape, itemsize).
    """
    topology = topology or UniformTopology()
    entries: list[BsrEntry] = []
    for name, src, dst, shape, itemsize in tensors:
        entries.extend(build_table(src, dst, shape, name, itemsize))
    send_load: dict[int, int] = {}
    return BsrPlan(_plan(entries, topology, True, send_load), fused=True)


def plan_unfused_bsr(tensors: list[tuple[str, HSPMD, HSPMD, tuple[int, ...], int]],
                     topology: Topology | None = None) -> BsrPlan:
    """Per-tensor planning (heuristics on, but load state and fusion do not
    span tensors) — the paper's middle baseline in Fig 18."""
    topology = topology or UniformTopology()
    out: list[BsrAssignment] = []
    for name, src, dst, shape, itemsize in tensors:
        entries = build_table(src, dst, shape, name, itemsize)
        out.extend(_plan(entries, topology, True, {}))
    return BsrPlan(out, fused=False)
