"""Local shard semantics of compute operators.

Progressive specialization (paper §5.3) turns every compute op into a
*device-local* computation over local shards: elementwise ops apply
pointwise, ``dot`` with a split contraction dim produces a Partial
summand, ``sum`` over a split dim produces a summand, and so on — the
annotation deduction rules (``core.graph.DEDUCTION_RULES``) guarantee
the local results compose back into the global value.

The kernels here are parameterized by the array namespace (``numpy`` for
the virtual-device simulator executor, ``jax.numpy`` for the shard_map
runtime) so both execution backends share ONE definition of what each
op computes — the basis of the differential bit-exactness tests.
"""

from __future__ import annotations

GELU_C = 0.7978845608028654  # sqrt(2/pi)


# kernels that compute through transcendentals (or true division) and
# therefore always produce floating-point outputs, regardless of input
# integerness — the dtype-widening set of ``result_dtype``
_FLOAT_KINDS = frozenset((
    "gelu", "gelu_grad", "scale", "silu", "silu_grad", "softmax",
    "softmax_grad", "rsqrt", "div", "rmsnorm", "layernorm",
    "norm_grad_x", "norm_grad_w", "norm_grad_b", "attention",
    "attn_grad_q", "attn_grad_k", "attn_grad_v",
))


def result_dtype(kind: str, in_dtypes):
    """The output dtype BOTH executors cast to: numpy promotion over the
    inputs, widened to floating for transcendental kernels (numpy would
    otherwise promote int inputs to float64 while jax stays in float32,
    silently diverging the executors)."""
    import numpy as np
    if kind == "ones":             # the autodiff gradient seed: no inputs
        return np.dtype(np.float32)
    if kind in ("embedding", "embed_grad", "gather", "gather_grad"):
        # integer indices must not promote the value dtype (numpy's
        # f32+int32 -> f64 would diverge from jax); the value operand is
        # the first input in all four kinds
        dt = np.dtype(in_dtypes[0])
        if kind in ("gather", "gather_grad") and \
                not np.issubdtype(dt, np.floating):
            dt = np.dtype(np.float32)
        return dt
    dt = np.result_type(*in_dtypes)
    if kind in _FLOAT_KINDS and not np.issubdtype(dt, np.floating):
        dt = np.dtype(np.float32)  # not result_type: int32+f32 -> f64
    return dt


def _softmax_lastdim(xp, x):
    """Max-subtracted softmax over the last axis (the same math
    ``jax.nn.softmax`` performs), in the input dtype."""
    m = xp.max(x, axis=-1, keepdims=True)
    e = xp.exp(x - m)
    return e / xp.sum(e, axis=-1, keepdims=True)


def _norm_stats(xp, x, attrs):
    """(normalized x̂ in float32, rsqrt factor r) for ``rmsnorm`` /
    ``layernorm`` and their VJPs — shared so forward and backward agree
    on the exact normalization math (mirrors ``models.layers``)."""
    import numpy as np
    xf = x.astype(np.float32)
    eps = np.float32(attrs.get("eps", 1e-5))
    if attrs.get("norm", "rms") == "layer":
        mu = xp.mean(xf, axis=-1, keepdims=True)
        xc = xf - mu
        var = xp.mean(xc * xc, axis=-1, keepdims=True)
        r = 1.0 / xp.sqrt(var + eps)
        return xc * r, r
    ms = xp.mean(xf * xf, axis=-1, keepdims=True)
    r = 1.0 / xp.sqrt(ms + eps)
    return xf * r, r


def _attn_probs(xp, q, k, attrs):
    """(probs float32, repeated K) of the attention composite — the
    reference math of ``kernels.ref.flash_attention_ref``, parameterized
    by the array namespace so both executors share it."""
    import numpy as np
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    rep = h // kh
    kq = xp.repeat(k, rep, axis=1)
    logits = xp.einsum("bhqd,bhkd->bhqk", q, kq).astype(np.float32)
    logits = logits / np.float32(np.sqrt(np.float32(d)))
    if attrs.get("causal", True):
        qi = np.arange(sq)
        ki = np.arange(sk)
        mask = xp.asarray(ki[None, :] <= qi[:, None])
        logits = xp.where(mask[None, None], logits, np.float32(-1e30))
    return _softmax_lastdim(xp, logits), kq


def _fold_gqa(xp, dkq, kh):
    """Sum a per-query-head (b, H, sk, d) gradient back onto the
    (b, K, sk, d) kv heads (``repeat``'s transpose)."""
    b, h, sk, d = dkq.shape
    rep = h // kh
    return xp.sum(xp.reshape(dkq, (b, kh, rep, sk, d)), axis=2)


def local_apply(kind: str, xp, ins, attrs, out_shape):
    """Apply compute op ``kind`` to device-local input shards.

    ``out_shape`` is the device-local output shape (needed by ``reshape``,
    whose local target shape is annotation-dependent).
    """
    if kind == "gelu":
        x = ins[0]
        return 0.5 * x * (1.0 + xp.tanh(GELU_C * (x + 0.044715 * x * x * x)))
    if kind == "relu":
        return xp.maximum(ins[0], 0)
    if kind == "scale":
        return ins[0] * attrs.get("factor", 1.0)
    if kind == "add":
        return ins[0] + ins[1]
    if kind == "mul":
        return ins[0] * ins[1]
    if kind == "dot":
        return xp.matmul(ins[0], ins[1])
    if kind == "sum":
        return xp.sum(ins[0], axis=attrs["dim"])
    if kind == "transpose":
        return xp.transpose(ins[0], attrs["perm"])
    if kind == "reshape":
        return xp.reshape(ins[0], out_shape)
    if kind == "embedding":
        table, ids = ins
        return xp.take(table, ids, axis=0)
    if kind == "silu":
        x = ins[0]
        return x / (1.0 + xp.exp(-x))
    if kind == "rsqrt":
        return 1.0 / xp.sqrt(ins[0])
    if kind == "div":
        return ins[0] / ins[1]
    if kind == "softmax":
        return _softmax_lastdim(xp, ins[0])
    if kind in ("rmsnorm", "layernorm"):
        x = ins[0]
        w = ins[1]
        xhat, _ = _norm_stats(xp, x, attrs)
        y = xhat.astype(x.dtype) * w
        if kind == "layernorm":
            y = y + ins[2]
        return y
    if kind == "gather":          # pick one element along the last axis
        x, ids = ins
        return xp.take_along_axis(x, ids[..., None], axis=-1)[..., 0]
    if kind == "attention":       # q (B,H,Sq,D); k/v (B,K,Sk,D), GQA
        q, k, v = ins
        probs, _ = _attn_probs(xp, q, k, attrs)
        rep = q.shape[1] // k.shape[1]
        vq = xp.repeat(v, rep, axis=1)
        return xp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), vq)
    # -- backward-only kernels (reverse-mode autodiff) ----------------------
    if kind == "ones":            # gradient seed dL/dL == 1
        return xp.ones(out_shape)
    if kind == "relu_grad":
        dy, x = ins
        return dy * (x > 0)
    if kind == "gelu_grad":
        dy, x = ins
        u = GELU_C * (x + 0.044715 * x * x * x)
        t = xp.tanh(u)
        du = GELU_C * (1.0 + 3 * 0.044715 * x * x)
        return dy * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du)
    if kind == "mul_grad":        # dy * other; linear in dy (Partial-safe)
        return ins[0] * ins[1]
    if kind == "bcast":           # VJP of sum: replicate along the new dim
        return xp.broadcast_to(xp.expand_dims(ins[0], attrs["dim"]),
                               out_shape)
    if kind == "embed_grad":      # VJP of embedding: scatter-add rows
        dy, ids = ins
        d = dy.shape[-1]
        dyf = xp.reshape(dy, (-1, d))
        idf = xp.reshape(ids, (-1,))
        buf = xp.zeros(out_shape, dy.dtype)
        if hasattr(buf, "at"):    # jax.numpy functional index update
            return buf.at[idf].add(dyf)
        import numpy as _np
        _np.add.at(buf, idf, dyf)
        return buf
    if kind == "silu_grad":
        dy, x = ins
        s = 1.0 / (1.0 + xp.exp(-x))
        return dy * (s * (1.0 + x * (1.0 - s)))
    if kind == "softmax_grad":    # dx = y * (dy - <dy, y>); linear in dy
        dy, y = ins
        return y * (dy - xp.sum(dy * y, axis=-1, keepdims=True))
    if kind == "norm_grad_x":     # VJP of rmsnorm/layernorm wrt x
        import numpy as np
        dy, x, w = ins
        xhat, r = _norm_stats(xp, x, attrs)
        dxhat = (dy * w).astype(np.float32)
        d = np.float32(x.shape[-1])
        if attrs.get("norm", "rms") == "layer":
            return r * (dxhat
                        - xp.mean(dxhat, axis=-1, keepdims=True)
                        - xhat * xp.mean(dxhat * xhat, axis=-1,
                                         keepdims=True))
        return r * dxhat - (xhat * r) * xp.sum(
            dxhat * xhat, axis=-1, keepdims=True) / d
    if kind == "norm_grad_w":     # dw = sum_lead(dy * x̂); linear in dy
        import numpy as np
        dy, x = ins
        xhat, _ = _norm_stats(xp, x, attrs)
        t = dy.astype(np.float32) * xhat
        return xp.sum(xp.reshape(t, (-1, t.shape[-1])), axis=0)
    if kind == "norm_grad_b":     # db = sum_lead(dy)
        dy = ins[0]
        return xp.sum(xp.reshape(dy, (-1, dy.shape[-1])), axis=0)
    if kind == "gather_grad":     # one-hot scatter along the last axis
        import numpy as np
        dy, ids = ins
        onehot = (xp.arange(out_shape[-1]) == ids[..., None])
        return onehot.astype(dy.dtype) * dy[..., None]
    if kind in ("attn_grad_q", "attn_grad_k", "attn_grad_v"):
        import numpy as np
        dy, q, k, v = ins
        probs, kq = _attn_probs(xp, q, k, attrs)
        kh = k.shape[1]
        rep = q.shape[1] // kh
        dyf = dy.astype(np.float32)
        if kind == "attn_grad_v":
            dvq = xp.einsum("bhqk,bhqd->bhkd", probs, dyf)
            return _fold_gqa(xp, dvq, kh)
        vq = xp.repeat(v, rep, axis=1).astype(np.float32)
        dp = xp.einsum("bhqd,bhkd->bhqk", dyf, vq)
        ds = probs * (dp - xp.sum(dp * probs, axis=-1, keepdims=True))
        scale = np.float32(1.0) / np.float32(np.sqrt(np.float32(q.shape[-1])))
        if kind == "attn_grad_q":
            return xp.einsum("bhqk,bhkd->bhqd",
                             ds, kq.astype(np.float32)) * scale
        dkq = xp.einsum("bhqk,bhqd->bhkd", ds, q.astype(np.float32)) * scale
        return _fold_gqa(xp, dkq, kh)
    raise NotImplementedError(f"no local semantics for op kind {kind!r}")


#: kinds whose local semantics are already pointwise / last-axis only,
#: so the stacked (n, *local) call IS the per-shard call, bit for bit
_STACK_TRANSPARENT = frozenset((
    "gelu", "relu", "scale", "add", "mul", "silu", "rsqrt", "div",
    "softmax", "gather", "relu_grad", "gelu_grad", "mul_grad",
    "silu_grad", "softmax_grad", "gather_grad",
))

#: kinds that fold the class axis into the batch axis and call the
#: plain local kernel once (batched einsums process each slice exactly
#: as the unbatched call would)
_STACK_BATCHFOLD = frozenset((
    "attention", "attn_grad_q", "attn_grad_k", "attn_grad_v",
))


def stacked_apply(kind: str, xp, ins, attrs, out_shape, n: int):
    """Apply ``kind`` to ``n`` same-shaped device shards at once.

    Every input of ``ins`` is the class-stacked buffer ``(n, *local)``
    (one row per device of a specialization class; ``core.lowered_ir``),
    ``out_shape`` the per-device local output shape.  Returns the
    stacked ``(n, *out_shape)`` result, or ``None`` when the kind has no
    vectorized form — the caller then falls back to the per-device loop.

    Bit-exactness contract: row ``j`` of the result must equal
    ``local_apply(kind, xp, [x[j] for x in ins], attrs, out_shape)``
    exactly.  Each adapter below only re-indexes axes (shifting them
    past the stack axis, folding it into a batch dim, or replicating a
    weight across rows); no reassociation of float reductions happens,
    because numpy applies the same last-axis / contraction loops per
    slice of a batched call.
    """
    if kind in _STACK_TRANSPARENT:
        return local_apply(kind, xp, ins, attrs, out_shape)
    if kind in _STACK_BATCHFOLD:
        b = ins[0].shape[1]
        folded = [xp.reshape(x, (-1,) + x.shape[2:]) for x in ins]
        y = local_apply(kind, xp, folded, attrs, None)
        return xp.reshape(y, (n, b) + y.shape[1:])
    if kind == "dot":
        a, b = ins
        if a.ndim < 3 or b.ndim < 3:
            return None           # 1-D operand: matmul semantics differ
        if a.ndim > b.ndim:
            b = xp.reshape(b, (n,) + (1,) * (a.ndim - b.ndim)
                           + b.shape[1:])
        elif b.ndim > a.ndim:
            a = xp.reshape(a, (n,) + (1,) * (b.ndim - a.ndim)
                           + a.shape[1:])
        return xp.matmul(a, b)
    if kind == "sum":
        d = attrs["dim"]
        return xp.sum(ins[0], axis=(d + 1 if d >= 0 else d))
    if kind == "transpose":
        return xp.transpose(ins[0],
                            (0,) + tuple(p + 1 for p in attrs["perm"]))
    if kind == "reshape":
        return xp.reshape(ins[0], (n,) + tuple(out_shape))
    if kind == "bcast":
        d = attrs["dim"]
        return xp.broadcast_to(
            xp.expand_dims(ins[0], d + 1 if d >= 0 else d),
            (n,) + tuple(out_shape))
    if kind == "ones":
        return xp.ones((n,) + tuple(out_shape))
    if kind == "embedding":
        table, ids = ins
        rows = xp.arange(n)[:, None]
        picked = table[rows, xp.reshape(ids, (n, -1))]
        return xp.reshape(picked, (n,) + tuple(out_shape))
    if kind == "embed_grad":
        import numpy as _np
        dy, ids = ins
        d = dy.shape[-1]
        dyf = xp.reshape(dy, (n, -1, d))
        idf = xp.reshape(ids, (n, -1))
        buf = xp.zeros((n,) + tuple(out_shape), dy.dtype)
        _np.add.at(buf, (xp.arange(n)[:, None], idf), dyf)
        return buf
    if kind in ("rmsnorm", "layernorm"):
        x, w = ins[0], ins[1]
        wr = xp.reshape(w, (n,) + (1,) * (x.ndim - 2) + w.shape[1:])
        xhat, _ = _norm_stats(xp, x, attrs)
        y = xhat.astype(x.dtype) * wr
        if kind == "layernorm":
            y = y + xp.reshape(ins[2],
                               (n,) + (1,) * (x.ndim - 2) + w.shape[1:])
        return y
    if kind == "norm_grad_x":
        import numpy as np
        dy, x, w = ins
        wr = xp.reshape(w, (n,) + (1,) * (x.ndim - 2) + w.shape[1:])
        xhat, r = _norm_stats(xp, x, attrs)
        dxhat = (dy * wr).astype(np.float32)
        d = np.float32(x.shape[-1])
        if attrs.get("norm", "rms") == "layer":
            return r * (dxhat
                        - xp.mean(dxhat, axis=-1, keepdims=True)
                        - xhat * xp.mean(dxhat * xhat, axis=-1,
                                         keepdims=True))
        return r * dxhat - (xhat * r) * xp.sum(
            dxhat * xhat, axis=-1, keepdims=True) / d
    if kind == "norm_grad_w":
        import numpy as np
        dy, x = ins
        xhat, _ = _norm_stats(xp, x, attrs)
        t = dy.astype(np.float32) * xhat
        return xp.sum(xp.reshape(t, (n, -1, t.shape[-1])), axis=1)
    if kind == "norm_grad_b":
        dy = ins[0]
        return xp.sum(xp.reshape(dy, (n, -1, dy.shape[-1])), axis=1)
    return None


# ---------------------------------------------------------------------------
# microbatch role propagation (pipeline schedules, paper §5.4)
# ---------------------------------------------------------------------------
#
# Splitting the batch into microbatches is itself an SPMD-style split —
# along *time* instead of devices.  Every tensor relates to the
# microbatch axis in one of the DS ways (reusing the annotation dim
# vocabulary, ``annotations.DUP``/``PARTIAL``):
#
#   role >= 0   Split: the tensor's dim ``role`` is the batch dim; each
#               microbatch computes a 1/m slice of it,
#   role == DUP       the tensor is microbatch-invariant (parameters),
#   role == PARTIAL   each microbatch holds a summand (a loss or grad
#               accumulated across microbatches).
#
# ``microbatch_role`` is the per-op propagation rule — the same table
# shape as DEDUCTION_RULES, one tier up.  It is what lets Session.run
# reduce per-microbatch outputs correctly (sum Partial, concat Split,
# take-one Duplicate) and lets the micro-plan compiler scale shapes.

MB_DUP = -1       # mirrors annotations.DUP
MB_PARTIAL = -2   # mirrors annotations.PARTIAL


class MicrobatchError(ValueError):
    """The graph cannot be split along the batch dim at this op."""


def cotangent_role(role: int) -> int:
    """The microbatch role of a tensor's GRADIENT: a per-microbatch
    slice's grad is a per-microbatch slice; a microbatch-invariant
    tensor (parameters) accumulates per-microbatch grad summands
    (Partial); a Partial tensor (the loss) receives an invariant seed.
    The same Duplicate <-> Partial duality as annotation cotangents,
    one tier up."""
    if role == MB_DUP:
        return MB_PARTIAL
    if role == MB_PARTIAL:
        return MB_DUP
    return role


def microbatch_role(kind: str, in_roles, attrs, in_ndims) -> int:
    """Propagate the microbatch role through one compute op.

    ``in_roles`` follow the DS dim vocabulary above; ``in_ndims`` are the
    input ranks (the Dot rule needs them).  Raises
    :class:`MicrobatchError` where no per-microbatch computation exists
    (nonlinearity over Partial, Split mixed with full-shape Duplicate...).
    """
    if kind in ("gelu", "relu", "silu", "rsqrt", "softmax"):
        (r,) = in_roles
        if r == MB_PARTIAL:
            raise MicrobatchError(
                f"{kind} is nonlinear; cannot apply it per-microbatch to "
                f"an accumulated (Partial) value")
        if kind == "softmax" and r == in_ndims[0] - 1:
            raise MicrobatchError(
                "softmax over the microbatch (batch) dim; per-microbatch "
                "slices cannot reproduce the full normalization")
        return r
    if kind == "div":
        a, b = in_roles
        if b == MB_PARTIAL:
            raise MicrobatchError(
                "div by a microbatch-Partial value is nonlinear in the "
                "microbatch sum")
        if a == b:
            return a
        if a == MB_PARTIAL and b == MB_DUP:
            return MB_PARTIAL     # (sum_i x_i) / y == sum_i (x_i / y)
        raise MicrobatchError(
            f"div operands have incompatible microbatch roles ({a} vs {b})")
    if kind in ("rmsnorm", "layernorm"):
        r = in_roles[0]
        if r == MB_PARTIAL:
            raise MicrobatchError(
                f"{kind} is nonlinear; cannot normalize an accumulated "
                f"(Partial) value per-microbatch")
        if r == in_ndims[0] - 1:
            raise MicrobatchError(
                f"{kind} normalizes the microbatch (batch) dim")
        if any(x != MB_DUP for x in in_roles[1:]):
            raise MicrobatchError(
                f"{kind} weights must be microbatch-invariant")
        return r
    if kind == "gather":
        rx, ri = in_roles
        if ri == MB_PARTIAL:
            raise MicrobatchError("gather indices cannot be Partial")
        if rx >= 0 and rx == in_ndims[0] - 1:
            raise MicrobatchError(
                "gather's indexed (last) dim is the microbatch dim")
        if rx == ri:
            return rx
        if rx == MB_DUP and ri >= 0:
            return ri             # per-microbatch index slice
        if rx == MB_PARTIAL and ri == MB_DUP:
            return MB_PARTIAL     # gather is linear in x
        raise MicrobatchError(
            f"gather operand microbatch roles ({rx}, {ri}) are unsupported")
    if kind == "attention":
        rq = in_roles[0]
        if any(r != rq for r in in_roles):
            raise MicrobatchError(
                "attention operands must share one microbatch role")
        if rq == MB_DUP or rq == 0:
            return rq             # batch dim slices independently
        raise MicrobatchError(
            f"attention microbatch role {rq} is unsupported (only the "
            f"batch dim 0 slices through causal attention)")
    if kind == "scale":           # linear: every role passes through
        return in_roles[0]
    if kind in ("add", "mul"):
        a, b = in_roles
        if a == b:
            if kind == "mul" and a == MB_PARTIAL:
                raise MicrobatchError(
                    "mul of two microbatch-Partial values is nonlinear "
                    "in the microbatch sum")
            return a
        if kind == "mul" and {a, b} == {MB_PARTIAL, MB_DUP}:
            return MB_PARTIAL     # (sum_i x_i) * y == sum_i (x_i * y)
        raise MicrobatchError(
            f"{kind} operands have incompatible microbatch roles "
            f"({a} vs {b}); a per-microbatch slice cannot combine with a "
            f"full-batch operand")
    if kind == "dot":
        rx, rw = in_roles
        x_ndim = in_ndims[0]
        if rx == MB_PARTIAL and rw == MB_PARTIAL:
            raise MicrobatchError("dot of two microbatch-Partial values")
        if rx == MB_PARTIAL or rw == MB_PARTIAL:
            other = rw if rx == MB_PARTIAL else rx
            if other != MB_DUP:
                raise MicrobatchError(
                    "dot mixes a microbatch-Partial operand with a "
                    "per-microbatch slice")
            return MB_PARTIAL     # dot is linear in either operand
        if rx == MB_DUP and rw == MB_DUP:
            return MB_DUP
        if rx >= 0 and rw == MB_DUP:
            if rx == x_ndim - 1:
                raise MicrobatchError(
                    "X's contraction dim is the batch dim but W is "
                    "microbatch-invariant; shapes cannot match")
            return rx             # batch/m dims pass through
        if rx == x_ndim - 1 and rw == 0:
            return MB_PARTIAL     # contraction split over microbatches
        if rx == MB_DUP and rw == 1:
            return x_ndim - 1
        raise MicrobatchError(
            f"dot operand microbatch roles ({rx}, {rw}) are unsupported")
    if kind == "sum":
        (r,) = in_roles
        dim = attrs["dim"]
        if r == dim:
            return MB_PARTIAL     # reduced batch dim -> accumulate
        if r >= 0:
            return r - 1 if r > dim else r
        return r                  # DUP / PARTIAL (linear) pass through
    if kind == "transpose":
        (r,) = in_roles
        if r < 0:
            return r
        inv = {old: new for new, old in enumerate(attrs["perm"])}
        return inv[r]
    if kind == "reshape":
        (r,) = in_roles
        return r                  # mapped by the caller (needs shapes)
    if kind == "embedding":
        rt, ri = in_roles
        if rt == MB_DUP and ri == MB_DUP:
            return MB_DUP
        if rt == MB_DUP and ri >= 0:
            return ri             # per-microbatch token slice
        raise MicrobatchError(
            f"embedding operand microbatch roles ({rt}, {ri}) are "
            f"unsupported")
    if kind == "ones":
        return MB_DUP             # the gradient seed is batch-invariant
    if kind in ("relu_grad", "gelu_grad", "mul_grad", "silu_grad",
                "softmax_grad"):
        dy, x = in_roles
        if dy == x:
            return dy
        if dy == MB_PARTIAL and x == MB_DUP:
            return MB_PARTIAL     # linear in dy
        raise MicrobatchError(
            f"{kind} operands have incompatible microbatch roles "
            f"({dy} vs {x})")
    if kind in ("norm_grad_x", "attn_grad_q", "attn_grad_k", "attn_grad_v"):
        dy = in_roles[0]
        rest = in_roles[1:]
        if all(r == dy for r in rest) or (
                dy == MB_PARTIAL and all(r == MB_DUP for r in rest)):
            # norm_grad_x carries a microbatch-invariant weight operand
            if kind == "norm_grad_x" and in_roles[2] not in (dy, MB_DUP):
                raise MicrobatchError(
                    "norm_grad_x weight must be microbatch-invariant")
            return dy             # linear in dy
        if kind == "norm_grad_x" and in_roles[1] == dy \
                and in_roles[2] == MB_DUP:
            return dy
        raise MicrobatchError(
            f"{kind} operands have incompatible microbatch roles "
            f"{in_roles}")
    if kind in ("norm_grad_w", "norm_grad_b"):
        dy = in_roles[0]
        if kind == "norm_grad_w" and in_roles[1] not in (dy, MB_DUP):
            raise MicrobatchError(
                "norm_grad_w activation role must match dy")
        if dy >= 0 or dy == MB_PARTIAL:
            return MB_PARTIAL     # per-microbatch summand of the sum_lead
        return MB_DUP
    if kind == "gather_grad":
        dy, ri = in_roles
        if ri == MB_PARTIAL:
            raise MicrobatchError("gather_grad indices cannot be Partial")
        if dy == ri or (dy == MB_PARTIAL and ri == MB_DUP):
            return dy             # scatter over leading dims; linear in dy
        raise MicrobatchError(
            f"gather_grad operand microbatch roles ({dy}, {ri}) are "
            f"unsupported")
    if kind == "bcast":
        (r,) = in_roles
        if r < 0:
            return r
        return r + 1 if r >= attrs["dim"] else r
    if kind == "embed_grad":
        dy, _ = in_roles
        if dy >= 0:
            return MB_PARTIAL     # scatter-add over the batch slice
        return dy
    raise NotImplementedError(f"no microbatch rule for op kind {kind!r}")


def flops(kind: str, in_shapes, out_shape, attrs) -> int:
    """Analytic FLOP count of one (global) compute op — the compute term
    of the roofline estimate attached to compiled plans."""
    import math
    numel = math.prod(out_shape) if out_shape else 0
    if kind == "dot":
        k = in_shapes[0][-1]
        return 2 * numel * k
    if kind == "sum":
        return math.prod(in_shapes[0])
    if kind in ("gelu",):
        return 8 * numel
    if kind in ("gelu_grad",):
        return 14 * numel         # tanh + polynomial derivative terms
    if kind in ("relu", "scale", "add", "mul", "mul_grad", "relu_grad",
                "div"):
        return numel
    if kind == "embed_grad":
        return math.prod(in_shapes[0])  # one add per dy element
    if kind in ("silu", "softmax_grad"):
        return 4 * numel
    if kind == "silu_grad":
        return 6 * numel
    if kind == "rsqrt":
        return 2 * numel
    if kind == "softmax":
        return 5 * numel              # max, sub, exp, sum, div
    if kind == "rmsnorm":
        return 4 * numel
    if kind == "layernorm":
        return 6 * numel
    if kind == "norm_grad_x":
        return 10 * numel             # recompute x̂ + two row reductions
    if kind == "norm_grad_w":
        return 3 * math.prod(in_shapes[0])
    if kind == "norm_grad_b":
        return math.prod(in_shapes[0])
    if kind == "gather_grad":
        return numel                  # one-hot select per output element
    if kind in ("attention", "attn_grad_q", "attn_grad_k", "attn_grad_v"):
        # q (B,H,Sq,D); k/v (B,K,Sk,D).  QK^T and PV are 2*B*H*Sq*Sk*D
        # each; softmax ~5*B*H*Sq*Sk; grads recompute probs + two more
        # score-shaped matmuls
        qs = in_shapes[0] if kind == "attention" else in_shapes[1]
        ks = in_shapes[1] if kind == "attention" else in_shapes[2]
        b, h, sq, d = qs
        sk = ks[2]
        scores = b * h * sq * sk
        mm = 2 * scores * d
        if kind == "attention":
            return 2 * mm + 5 * scores
        return 4 * mm + 7 * scores
    # transpose / reshape / bcast / embedding / ones / gather move data
    return 0
