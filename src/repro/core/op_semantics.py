"""Local shard semantics of compute operators.

Progressive specialization (paper §5.3) turns every compute op into a
*device-local* computation over local shards: elementwise ops apply
pointwise, ``dot`` with a split contraction dim produces a Partial
summand, ``sum`` over a split dim produces a summand, and so on — the
annotation deduction rules (``core.graph.DEDUCTION_RULES``) guarantee
the local results compose back into the global value.

The kernels here are parameterized by the array namespace (``numpy`` for
the virtual-device simulator executor, ``jax.numpy`` for the shard_map
runtime) so both execution backends share ONE definition of what each
op computes — the basis of the differential bit-exactness tests.
"""

from __future__ import annotations

GELU_C = 0.7978845608028654  # sqrt(2/pi)


def result_dtype(kind: str, in_dtypes):
    """The output dtype BOTH executors cast to: numpy promotion over the
    inputs, widened to floating for transcendental kernels (numpy would
    otherwise promote int inputs to float64 while jax stays in float32,
    silently diverging the executors)."""
    import numpy as np
    dt = np.result_type(*in_dtypes)
    if kind in ("gelu", "scale") and not np.issubdtype(dt, np.floating):
        dt = np.dtype(np.float32)  # not result_type: int32+f32 -> f64
    return dt


def local_apply(kind: str, xp, ins, attrs, out_shape):
    """Apply compute op ``kind`` to device-local input shards.

    ``out_shape`` is the device-local output shape (needed by ``reshape``,
    whose local target shape is annotation-dependent).
    """
    if kind == "gelu":
        x = ins[0]
        return 0.5 * x * (1.0 + xp.tanh(GELU_C * (x + 0.044715 * x * x * x)))
    if kind == "relu":
        return xp.maximum(ins[0], 0)
    if kind == "scale":
        return ins[0] * attrs.get("factor", 1.0)
    if kind == "add":
        return ins[0] + ins[1]
    if kind == "mul":
        return ins[0] * ins[1]
    if kind == "dot":
        return xp.matmul(ins[0], ins[1])
    if kind == "sum":
        return xp.sum(ins[0], axis=attrs["dim"])
    if kind == "transpose":
        return xp.transpose(ins[0], attrs["perm"])
    if kind == "reshape":
        return xp.reshape(ins[0], out_shape)
    raise NotImplementedError(f"no local semantics for op kind {kind!r}")


def flops(kind: str, in_shapes, out_shape, attrs) -> int:
    """Analytic FLOP count of one (global) compute op — the compute term
    of the roofline estimate attached to compiled plans."""
    import math
    numel = math.prod(out_shape) if out_shape else 0
    if kind == "dot":
        k = in_shapes[0][-1]
        return 2 * numel * k
    if kind == "sum":
        return math.prod(in_shapes[0])
    if kind in ("gelu",):
        return 8 * numel
    if kind in ("relu", "scale", "add", "mul"):
        return numel
    return 0  # transpose / reshape are data movement
