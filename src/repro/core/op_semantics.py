"""Local shard semantics of compute operators.

Progressive specialization (paper §5.3) turns every compute op into a
*device-local* computation over local shards: elementwise ops apply
pointwise, ``dot`` with a split contraction dim produces a Partial
summand, ``sum`` over a split dim produces a summand, and so on — the
annotation deduction rules (``core.graph.DEDUCTION_RULES``) guarantee
the local results compose back into the global value.

The kernels here are parameterized by the array namespace (``numpy`` for
the virtual-device simulator executor, ``jax.numpy`` for the shard_map
runtime) so both execution backends share ONE definition of what each
op computes — the basis of the differential bit-exactness tests.
"""

from __future__ import annotations

GELU_C = 0.7978845608028654  # sqrt(2/pi)


def result_dtype(kind: str, in_dtypes):
    """The output dtype BOTH executors cast to: numpy promotion over the
    inputs, widened to floating for transcendental kernels (numpy would
    otherwise promote int inputs to float64 while jax stays in float32,
    silently diverging the executors)."""
    import numpy as np
    if kind == "ones":             # the autodiff gradient seed: no inputs
        return np.dtype(np.float32)
    if kind in ("embedding", "embed_grad"):
        # integer indices must not promote the value dtype (numpy's
        # f32+int32 -> f64 would diverge from jax); the value operand is
        # the first input in both kinds
        return np.dtype(in_dtypes[0])
    dt = np.result_type(*in_dtypes)
    if kind in ("gelu", "gelu_grad", "scale") and \
            not np.issubdtype(dt, np.floating):
        dt = np.dtype(np.float32)  # not result_type: int32+f32 -> f64
    return dt


def local_apply(kind: str, xp, ins, attrs, out_shape):
    """Apply compute op ``kind`` to device-local input shards.

    ``out_shape`` is the device-local output shape (needed by ``reshape``,
    whose local target shape is annotation-dependent).
    """
    if kind == "gelu":
        x = ins[0]
        return 0.5 * x * (1.0 + xp.tanh(GELU_C * (x + 0.044715 * x * x * x)))
    if kind == "relu":
        return xp.maximum(ins[0], 0)
    if kind == "scale":
        return ins[0] * attrs.get("factor", 1.0)
    if kind == "add":
        return ins[0] + ins[1]
    if kind == "mul":
        return ins[0] * ins[1]
    if kind == "dot":
        return xp.matmul(ins[0], ins[1])
    if kind == "sum":
        return xp.sum(ins[0], axis=attrs["dim"])
    if kind == "transpose":
        return xp.transpose(ins[0], attrs["perm"])
    if kind == "reshape":
        return xp.reshape(ins[0], out_shape)
    if kind == "embedding":
        table, ids = ins
        return xp.take(table, ids, axis=0)
    # -- backward-only kernels (reverse-mode autodiff) ----------------------
    if kind == "ones":            # gradient seed dL/dL == 1
        return xp.ones(out_shape)
    if kind == "relu_grad":
        dy, x = ins
        return dy * (x > 0)
    if kind == "gelu_grad":
        dy, x = ins
        u = GELU_C * (x + 0.044715 * x * x * x)
        t = xp.tanh(u)
        du = GELU_C * (1.0 + 3 * 0.044715 * x * x)
        return dy * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du)
    if kind == "mul_grad":        # dy * other; linear in dy (Partial-safe)
        return ins[0] * ins[1]
    if kind == "bcast":           # VJP of sum: replicate along the new dim
        return xp.broadcast_to(xp.expand_dims(ins[0], attrs["dim"]),
                               out_shape)
    if kind == "embed_grad":      # VJP of embedding: scatter-add rows
        dy, ids = ins
        d = dy.shape[-1]
        dyf = xp.reshape(dy, (-1, d))
        idf = xp.reshape(ids, (-1,))
        buf = xp.zeros(out_shape, dy.dtype)
        if hasattr(buf, "at"):    # jax.numpy functional index update
            return buf.at[idf].add(dyf)
        import numpy as _np
        _np.add.at(buf, idf, dyf)
        return buf
    raise NotImplementedError(f"no local semantics for op kind {kind!r}")


# ---------------------------------------------------------------------------
# microbatch role propagation (pipeline schedules, paper §5.4)
# ---------------------------------------------------------------------------
#
# Splitting the batch into microbatches is itself an SPMD-style split —
# along *time* instead of devices.  Every tensor relates to the
# microbatch axis in one of the DS ways (reusing the annotation dim
# vocabulary, ``annotations.DUP``/``PARTIAL``):
#
#   role >= 0   Split: the tensor's dim ``role`` is the batch dim; each
#               microbatch computes a 1/m slice of it,
#   role == DUP       the tensor is microbatch-invariant (parameters),
#   role == PARTIAL   each microbatch holds a summand (a loss or grad
#               accumulated across microbatches).
#
# ``microbatch_role`` is the per-op propagation rule — the same table
# shape as DEDUCTION_RULES, one tier up.  It is what lets Session.run
# reduce per-microbatch outputs correctly (sum Partial, concat Split,
# take-one Duplicate) and lets the micro-plan compiler scale shapes.

MB_DUP = -1       # mirrors annotations.DUP
MB_PARTIAL = -2   # mirrors annotations.PARTIAL


class MicrobatchError(ValueError):
    """The graph cannot be split along the batch dim at this op."""


def cotangent_role(role: int) -> int:
    """The microbatch role of a tensor's GRADIENT: a per-microbatch
    slice's grad is a per-microbatch slice; a microbatch-invariant
    tensor (parameters) accumulates per-microbatch grad summands
    (Partial); a Partial tensor (the loss) receives an invariant seed.
    The same Duplicate <-> Partial duality as annotation cotangents,
    one tier up."""
    if role == MB_DUP:
        return MB_PARTIAL
    if role == MB_PARTIAL:
        return MB_DUP
    return role


def microbatch_role(kind: str, in_roles, attrs, in_ndims) -> int:
    """Propagate the microbatch role through one compute op.

    ``in_roles`` follow the DS dim vocabulary above; ``in_ndims`` are the
    input ranks (the Dot rule needs them).  Raises
    :class:`MicrobatchError` where no per-microbatch computation exists
    (nonlinearity over Partial, Split mixed with full-shape Duplicate...).
    """
    if kind in ("gelu", "relu"):
        (r,) = in_roles
        if r == MB_PARTIAL:
            raise MicrobatchError(
                f"{kind} is nonlinear; cannot apply it per-microbatch to "
                f"an accumulated (Partial) value")
        return r
    if kind == "scale":           # linear: every role passes through
        return in_roles[0]
    if kind in ("add", "mul"):
        a, b = in_roles
        if a == b:
            if kind == "mul" and a == MB_PARTIAL:
                raise MicrobatchError(
                    "mul of two microbatch-Partial values is nonlinear "
                    "in the microbatch sum")
            return a
        if kind == "mul" and {a, b} == {MB_PARTIAL, MB_DUP}:
            return MB_PARTIAL     # (sum_i x_i) * y == sum_i (x_i * y)
        raise MicrobatchError(
            f"{kind} operands have incompatible microbatch roles "
            f"({a} vs {b}); a per-microbatch slice cannot combine with a "
            f"full-batch operand")
    if kind == "dot":
        rx, rw = in_roles
        x_ndim = in_ndims[0]
        if rx == MB_PARTIAL and rw == MB_PARTIAL:
            raise MicrobatchError("dot of two microbatch-Partial values")
        if rx == MB_PARTIAL or rw == MB_PARTIAL:
            other = rw if rx == MB_PARTIAL else rx
            if other != MB_DUP:
                raise MicrobatchError(
                    "dot mixes a microbatch-Partial operand with a "
                    "per-microbatch slice")
            return MB_PARTIAL     # dot is linear in either operand
        if rx == MB_DUP and rw == MB_DUP:
            return MB_DUP
        if rx >= 0 and rw == MB_DUP:
            if rx == x_ndim - 1:
                raise MicrobatchError(
                    "X's contraction dim is the batch dim but W is "
                    "microbatch-invariant; shapes cannot match")
            return rx             # batch/m dims pass through
        if rx == x_ndim - 1 and rw == 0:
            return MB_PARTIAL     # contraction split over microbatches
        if rx == MB_DUP and rw == 1:
            return x_ndim - 1
        raise MicrobatchError(
            f"dot operand microbatch roles ({rx}, {rw}) are unsupported")
    if kind == "sum":
        (r,) = in_roles
        dim = attrs["dim"]
        if r == dim:
            return MB_PARTIAL     # reduced batch dim -> accumulate
        if r >= 0:
            return r - 1 if r > dim else r
        return r                  # DUP / PARTIAL (linear) pass through
    if kind == "transpose":
        (r,) = in_roles
        if r < 0:
            return r
        inv = {old: new for new, old in enumerate(attrs["perm"])}
        return inv[r]
    if kind == "reshape":
        (r,) = in_roles
        return r                  # mapped by the caller (needs shapes)
    if kind == "embedding":
        rt, ri = in_roles
        if rt == MB_DUP and ri == MB_DUP:
            return MB_DUP
        if rt == MB_DUP and ri >= 0:
            return ri             # per-microbatch token slice
        raise MicrobatchError(
            f"embedding operand microbatch roles ({rt}, {ri}) are "
            f"unsupported")
    if kind == "ones":
        return MB_DUP             # the gradient seed is batch-invariant
    if kind in ("relu_grad", "gelu_grad", "mul_grad"):
        dy, x = in_roles
        if dy == x:
            return dy
        if dy == MB_PARTIAL and x == MB_DUP:
            return MB_PARTIAL     # linear in dy
        raise MicrobatchError(
            f"{kind} operands have incompatible microbatch roles "
            f"({dy} vs {x})")
    if kind == "bcast":
        (r,) = in_roles
        if r < 0:
            return r
        return r + 1 if r >= attrs["dim"] else r
    if kind == "embed_grad":
        dy, _ = in_roles
        if dy >= 0:
            return MB_PARTIAL     # scatter-add over the batch slice
        return dy
    raise NotImplementedError(f"no microbatch rule for op kind {kind!r}")


def flops(kind: str, in_shapes, out_shape, attrs) -> int:
    """Analytic FLOP count of one (global) compute op — the compute term
    of the roofline estimate attached to compiled plans."""
    import math
    numel = math.prod(out_shape) if out_shape else 0
    if kind == "dot":
        k = in_shapes[0][-1]
        return 2 * numel * k
    if kind == "sum":
        return math.prod(in_shapes[0])
    if kind in ("gelu",):
        return 8 * numel
    if kind in ("gelu_grad",):
        return 14 * numel         # tanh + polynomial derivative terms
    if kind in ("relu", "scale", "add", "mul", "mul_grad", "relu_grad"):
        return numel
    if kind == "embed_grad":
        return math.prod(in_shapes[0])  # one add per dy element
    # transpose / reshape / bcast / embedding / ones are data movement
    return 0
