"""Symbolic shape extension (paper §5.5).

Annotations define the sharding *pattern*; concrete shard shapes resolve at
runtime.  Tensor metadata may carry symbolic dims (e.g. ``B`` for batch,
``S`` for sequence); constraint-preserving arithmetic (``B // 2`` when
splitting the batch dim) is tracked as expression trees and bound to
integers when inputs arrive.  Binding validates divisibility so invalid
symbol usage is rejected before it can produce shape-mismatched
communication (paper footnote 3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Union

Dim = Union[int, "SymExpr"]


class SymExpr:
    """Base for symbolic dimension expressions."""

    def __add__(self, o): return _binop("+", self, o)
    def __radd__(self, o): return _binop("+", o, self)
    def __mul__(self, o): return _binop("*", self, o)
    def __rmul__(self, o): return _binop("*", o, self)
    def __floordiv__(self, o): return _binop("//", self, o)
    def __sub__(self, o): return _binop("-", self, o)

    def bind(self, env: Mapping[str, int]) -> int:
        raise NotImplementedError

    def free_symbols(self) -> set[str]:
        raise NotImplementedError


@dataclass(frozen=True)
class Sym(SymExpr):
    name: str

    def bind(self, env):
        if self.name not in env:
            raise KeyError(f"unbound symbol {self.name!r}")
        return int(env[self.name])

    def free_symbols(self):
        return {self.name}

    def __repr__(self):
        return self.name


@dataclass(frozen=True)
class BinOp(SymExpr):
    op: str
    lhs: Dim
    rhs: Dim

    def bind(self, env):
        l = self.lhs.bind(env) if isinstance(self.lhs, SymExpr) else self.lhs
        r = self.rhs.bind(env) if isinstance(self.rhs, SymExpr) else self.rhs
        if self.op == "+":
            return l + r
        if self.op == "-":
            return l - r
        if self.op == "*":
            return l * r
        if self.op == "//":
            if r == 0 or l % r != 0:
                raise ValueError(
                    f"symbolic dim {self!r} binds to non-divisible {l}//{r} "
                    f"— invalid symbol usage (paper §5.5 verification)")
            return l // r
        raise ValueError(self.op)

    def free_symbols(self):
        out = set()
        for x in (self.lhs, self.rhs):
            if isinstance(x, SymExpr):
                out |= x.free_symbols()
        return out

    def __repr__(self):
        return f"({self.lhs}{self.op}{self.rhs})"


def _binop(op: str, l, r) -> BinOp:
    return BinOp(op, l, r)


def bind_shape(shape: tuple[Dim, ...], env: Mapping[str, int]) -> tuple[int, ...]:
    out = []
    for d in shape:
        out.append(d.bind(env) if isinstance(d, SymExpr) else int(d))
        if out[-1] <= 0:
            raise ValueError(f"dim {d!r} bound to non-positive {out[-1]}")
    return tuple(out)


def is_concrete(shape: tuple[Dim, ...]) -> bool:
    return all(not isinstance(d, SymExpr) for d in shape)


def free_symbols(shape: tuple[Dim, ...]) -> set[str]:
    out: set[str] = set()
    for d in shape:
        if isinstance(d, SymExpr):
            out |= d.free_symbols()
    return out
