"""Symbolic shape extension (paper §5.5).

Annotations define the sharding *pattern*; concrete shard shapes resolve at
runtime.  Tensor metadata may carry symbolic dims (e.g. ``B`` for batch,
``S`` for sequence); constraint-preserving arithmetic (``B // 2`` when
splitting the batch dim) is tracked as expression trees and bound to
integers when inputs arrive.  Binding validates divisibility so invalid
symbol usage is rejected before it can produce shape-mismatched
communication (paper footnote 3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Union

Dim = Union[int, "SymExpr"]


class SymExpr:
    """Base for symbolic dimension expressions."""

    def __add__(self, o): return _binop("+", self, o)
    def __radd__(self, o): return _binop("+", o, self)
    def __mul__(self, o): return _binop("*", self, o)
    def __rmul__(self, o): return _binop("*", o, self)
    def __floordiv__(self, o): return _binop("//", self, o)
    def __sub__(self, o): return _binop("-", self, o)

    def bind(self, env: Mapping[str, int]) -> int:
        raise NotImplementedError

    def free_symbols(self) -> set[str]:
        raise NotImplementedError


@dataclass(frozen=True)
class Sym(SymExpr):
    name: str

    def bind(self, env):
        if self.name not in env:
            raise KeyError(f"unbound symbol {self.name!r}")
        return int(env[self.name])

    def free_symbols(self):
        return {self.name}

    def __repr__(self):
        return self.name


@dataclass(frozen=True)
class BinOp(SymExpr):
    op: str
    lhs: Dim
    rhs: Dim

    def bind(self, env):
        l = self.lhs.bind(env) if isinstance(self.lhs, SymExpr) else self.lhs
        r = self.rhs.bind(env) if isinstance(self.rhs, SymExpr) else self.rhs
        if self.op == "+":
            return l + r
        if self.op == "-":
            return l - r
        if self.op == "*":
            return l * r
        if self.op == "//":
            if r == 0 or l % r != 0:
                raise ValueError(
                    f"symbolic dim {self!r} binds to non-divisible {l}//{r} "
                    f"— invalid symbol usage (paper §5.5 verification)")
            return l // r
        raise ValueError(self.op)

    def free_symbols(self):
        out = set()
        for x in (self.lhs, self.rhs):
            if isinstance(x, SymExpr):
                out |= x.free_symbols()
        return out

    def __repr__(self):
        return f"({self.lhs}{self.op}{self.rhs})"


def _binop(op: str, l, r) -> BinOp:
    return BinOp(op, l, r)


def prod_dims(dims) -> Dim:
    """Product of a run of dims, staying an ``int`` when every factor is
    concrete and an expression tree otherwise (the flattened leading dim
    of a >2D dot VJP over symbolic batch/seq axes)."""
    out: Dim = 1
    for d in dims:
        if isinstance(out, int) and isinstance(d, int):
            out *= d
        elif isinstance(out, int) and out == 1:
            out = d
        else:
            out = out * d
    return out


def _prod_key(d: Dim):
    """``(coefficient, sorted symbol names)`` canonical form of a pure
    product expression; ``None`` for anything else (sums, floordivs)."""
    if isinstance(d, int):
        return (d, ())
    if isinstance(d, Sym):
        return (1, (d.name,))
    if isinstance(d, BinOp) and d.op == "*":
        l, r = _prod_key(d.lhs), _prod_key(d.rhs)
        if l is None or r is None:
            return None
        return (l[0] * r[0], tuple(sorted(l[1] + r[1])))
    return None


def dims_equal(a: Dim, b: Dim) -> bool:
    """Dim equality that recognizes product expressions up to factor
    order (``B*S == S*B``); concrete ints compare numerically, other
    expressions structurally."""
    if isinstance(a, int) and isinstance(b, int):
        return a == b
    ka, kb = _prod_key(a), _prod_key(b)
    if ka is not None and kb is not None:
        return ka == kb
    return a == b


def dim_multiple_of(d: Dim, n: int):
    """``True``/``False`` when divisibility by ``n`` is provable from the
    dim alone; ``None`` when a symbolic dim defers the check to bind
    time (``annotations.local_box`` re-validates on concrete shapes)."""
    if isinstance(d, int):
        return d % n == 0
    k = _prod_key(d)
    if k is not None and k[0] % n == 0:
        return True
    return None


def bind_shape(shape: tuple[Dim, ...], env: Mapping[str, int]) -> tuple[int, ...]:
    out = []
    for d in shape:
        out.append(d.bind(env) if isinstance(d, SymExpr) else int(d))
        if out[-1] <= 0:
            raise ValueError(f"dim {d!r} bound to non-positive {out[-1]}")
    return tuple(out)


def is_concrete(shape: tuple[Dim, ...]) -> bool:
    return all(not isinstance(d, SymExpr) for d in shape)


def free_symbols(shape: tuple[Dim, ...]) -> set[str]:
    out: set[str] = set()
    for d in shape:
        if isinstance(d, SymExpr):
            out |= d.free_symbols()
    return out
