"""Pipeline schedule engine: 1F1B / GPipe / interleaved timetables over
`CompiledPlan.pipelines`.

Progressive specialization (paper §5.3-5.4) builds the *spatial* half of
a strategy — per-device executable graphs linked into pipelines.  This
module supplies the *temporal* half: given the pipeline's stage count and
a microbatch count it emits an explicit per-stage timetable of
``(slot, stage, microbatch, phase)`` :class:`Tick`\\ s for the three
canonical synchronous schedules,

* **GPipe** — all ``m`` forwards flow through, then all ``m`` backwards
  drain back; every stage holds up to ``m`` in-flight microbatches,
* **1F1B** — each stage warms up with ``min(S-1-stage, m)`` forwards and
  then strictly alternates one-forward-one-backward, bounding in-flight
  microbatches by the stage depth instead of ``m`` (JaxPP / Megatron's
  memory-bounded schedule),
* **interleaved 1F1B** — Megatron's virtual-stage schedule: each of the
  ``S`` physical stages (devices) holds ``v`` model chunks, so the model
  traverses the device ring ``v`` times through ``S*v`` *virtual*
  stages.  ``Tick.stage`` is then the virtual stage index; the owning
  device is ``stage % S`` (chunk ``stage // S``).  The per-device unit
  order is Megatron's (warmup of ``2*(S-1-s) + (v-1)*S`` forwards, then
  strict 1F1B alternation over virtual microbatch units); slots come
  from a uniform-tick list scheduling of that order, so the emitted
  timetable is dependency-valid by construction and ``v=1`` degenerates
  to exactly the 1F1B table.

Uniform 1F1B/GPipe share the fill/drain shape the analytic cost model
prices (``costmodel.fill_drain_count``): with uniform fwd/bwd tick costs
the timetable spans exactly ``2 * (m + S - 1)`` slots.  ``validate``
checks the dependency structure (fwd follows the previous virtual
stage, bwd follows the next virtual stage, one tick per *device* per
slot); :class:`ScheduleStats` surfaces ticks / bubbles / p2p message
counts on ``CompiledPlan`` and ``RunResult``.

Ticks need not be uniform: ``price_schedule`` re-times any valid
timetable under per-``(stage, phase)`` durations (seconds, priced from
``costmodel.pipeline_tick_durations`` for analytic strategies) by the
same list scheduling — each tick starts when its device is free and its
dependencies have finished — yielding a :class:`PricedSchedule` with
real start/finish times, the priced **makespan** and the
**bubble fraction** (idle device-time share).  With all durations equal
to 1 the priced makespan reproduces the slot count exactly, which is
what pins the closed-form ``2*(m+S-1)`` uniform case to the priced
path.

The second half of the module maps a *graph* onto the timetable:
``microbatch_roles`` propagates how each tensor relates to the batch
split (Split / Duplicate / Partial — ``op_semantics.microbatch_role``),
``microbatch_graph`` scales a deduced graph's shapes down to one
microbatch, ``assign_stages`` buckets ops into (virtual) pipeline
stages, ``infer_virtual_stages`` counts how many chunks per device a
graph's dataflow actually makes, and ``combine_outputs`` reduces
per-microbatch fetches back to full-batch values (sum Partial,
concatenate Split, take-one Duplicate).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Mapping

import numpy as np

from . import op_semantics
from .annotations import DUP, PARTIAL
from .graph import Graph
from .op_semantics import MB_DUP, MB_PARTIAL, MicrobatchError
from .specialize import Pipeline

SCHEDULES = ("1f1b", "gpipe", "interleaved")


class ScheduleError(ValueError):
    """Invalid schedule request (unknown kind, bad microbatch count)."""


@dataclass(frozen=True)
class Tick:
    """One unit of pipeline work: virtual ``stage`` runs ``phase`` for
    ``microbatch`` during time ``slot``.  Slots are the uniform-duration
    ordering; real (non-uniform) durations are applied by
    :func:`price_schedule`."""

    slot: int
    stage: int            # VIRTUAL stage index (== physical when v == 1)
    microbatch: int
    phase: str            # "fwd" | "bwd"


@dataclass(frozen=True)
class ScheduleStats:
    """Static accounting of one timetable.

    ``makespan`` / ``bubble_fraction`` are *priced*: computed by
    re-timing the timetable under per-(stage, phase) tick durations
    (:func:`price_schedule`; uniform 1.0 by default, in which case
    ``makespan == n_slots``)."""

    n_ticks: int          # compute ticks actually scheduled (2 * m * S * v)
    n_slots: int          # timeline length in slots
    bubbles: int          # idle (device, slot) cells across the timetable
    p2p_messages: int     # stage-boundary sends (fwd activations + bwd grads)
    makespan: float = 0.0        # priced end-to-end time
    bubble_fraction: float = 0.0  # idle share of device-time, priced

    def summary(self) -> str:
        return (f"{self.n_ticks} ticks over {self.n_slots} slots, "
                f"{self.bubbles} bubbles, {self.p2p_messages} p2p msgs, "
                f"makespan {self.makespan:g} "
                f"({self.bubble_fraction:.0%} bubble)")


@dataclass
class PipelineSchedule:
    """An explicit timetable: ``ticks`` ordered by (slot, stage).

    ``n_stages`` is the *physical* stage (device) count;
    ``virtual_per_stage`` is Megatron's ``v`` — model chunks per device —
    so ticks index ``n_virtual = n_stages * virtual_per_stage`` virtual
    stages and ``device_of`` maps them back to devices."""

    kind: str
    n_stages: int
    num_microbatches: int
    ticks: list[Tick] = field(default_factory=list)
    virtual_per_stage: int = 1

    @property
    def n_virtual(self) -> int:
        """Virtual stage count ``S * v`` (== ``n_stages`` when v=1)."""
        return self.n_stages * self.virtual_per_stage

    def device_of(self, stage: int) -> int:
        """Physical stage (device) owning virtual ``stage`` — Megatron's
        layout: chunk ``stage // S`` lives on device ``stage % S``."""
        return stage % self.n_stages

    @property
    def n_slots(self) -> int:
        return max(t.slot for t in self.ticks) + 1 if self.ticks else 0

    @property
    def fill_drain_slots(self) -> int:
        """Timeline length in fwd+bwd *pairs* — the ``(m + S - 1)``
        fill/drain count the cost model prices."""
        return self.n_slots // 2

    def stage_ticks(self, stage: int) -> list[Tick]:
        return [t for t in self.ticks if t.stage == stage]

    def device_ticks(self, device: int) -> list[Tick]:
        """All ticks on one physical device, across its chunks."""
        return [t for t in self.ticks if self.device_of(t.stage) == device]

    def by_slot(self) -> dict[int, list[Tick]]:
        out: dict[int, list[Tick]] = {}
        for t in self.ticks:
            out.setdefault(t.slot, []).append(t)
        return out

    def peak_in_flight(self, stage: int) -> int:
        """Max microbatches forwarded but not yet backwarded at virtual
        ``stage`` (the activation-memory bound the 1F1B schedule exists
        to cap)."""
        live = peak = 0
        for t in sorted(self.stage_ticks(stage), key=lambda t: t.slot):
            live += 1 if t.phase == "fwd" else -1
            peak = max(peak, live)
        return peak

    def peak_in_flight_device(self, device: int) -> int:
        """Max in-flight microbatch activations held by one DEVICE,
        summed over its ``v`` chunks — the quantity interleaving trades
        against bubble time."""
        live = peak = 0
        for t in sorted(self.device_ticks(device), key=lambda t: t.slot):
            live += 1 if t.phase == "fwd" else -1
            peak = max(peak, live)
        return peak

    def warmup_depth(self, stage: int) -> int:
        """Forward ticks this virtual stage runs before its first
        backward."""
        n = 0
        for t in sorted(self.stage_ticks(stage), key=lambda t: t.slot):
            if t.phase == "bwd":
                break
            n += 1
        return n

    def stats(self, durations: "Mapping[tuple[int, str], float] | None"
              = None) -> ScheduleStats:
        """Accounting of this timetable; ``durations`` maps
        ``(virtual stage, phase) -> seconds`` (default: uniform 1.0, so
        the priced makespan equals the slot count)."""
        m, s = self.num_microbatches, self.n_stages
        boundaries = sum(1 for vs in range(self.n_virtual - 1)
                         if self.device_of(vs) != self.device_of(vs + 1))
        priced = price_schedule(self, durations)
        return ScheduleStats(
            n_ticks=len(self.ticks),
            n_slots=self.n_slots,
            bubbles=s * self.n_slots - len(self.ticks),
            p2p_messages=2 * m * boundaries,
            makespan=priced.makespan,
            bubble_fraction=priced.bubble_fraction)

    def describe(self) -> str:
        v = self.virtual_per_stage
        lines = [f"{self.kind} schedule: {self.n_stages} stage(s)"
                 + (f" x {v} chunk(s)" if v > 1 else "")
                 + f" x {self.num_microbatches} microbatch(es), "
                 + self.stats().summary()]
        by_slot = self.by_slot()
        for dev in range(self.n_stages):
            row = []
            for slot in range(self.n_slots):
                tick = next((t for t in by_slot.get(slot, ())
                             if self.device_of(t.stage) == dev), None)
                if tick is None:
                    row.append("  .   " if v > 1 else "  .  ")
                elif v > 1:
                    chunk = chr(ord("a") + tick.stage // self.n_stages)
                    row.append(f"{tick.phase[0].upper()}"
                               f"{tick.microbatch}{chunk}".ljust(6))
                else:
                    row.append(f"{tick.phase[0].upper()}"
                               f"{tick.microbatch:<3d} ")
            label = f"device {dev}" if v > 1 else f"stage {dev}"
            lines.append(f"  {label}: " + "".join(row))
        return "\n".join(lines)


@dataclass(frozen=True)
class PricedSchedule:
    """A timetable re-timed under per-(virtual stage, phase) durations:
    each tick starts when its device is free AND its dependencies have
    finished (the same list-scheduling rule that generated the slots,
    with real durations)."""

    schedule: PipelineSchedule
    starts: dict          # (stage, microbatch, phase) -> start seconds
    finishes: dict        # (stage, microbatch, phase) -> finish seconds
    makespan: float       # max finish time across all ticks
    busy: dict            # device -> total busy seconds

    @property
    def bubble_fraction(self) -> float:
        """Idle share of total device-time under the priced timetable."""
        if self.makespan <= 0.0:
            return 0.0
        total = self.schedule.n_stages * self.makespan
        return 1.0 - sum(self.busy.values()) / total

    def start(self, stage: int, microbatch: int, phase: str) -> float:
        return self.starts[(stage, microbatch, phase)]

    def finish(self, stage: int, microbatch: int, phase: str) -> float:
        return self.finishes[(stage, microbatch, phase)]


def price_schedule(sched: PipelineSchedule,
                   durations: "Mapping[tuple[int, str], float] | "
                              "Callable[[int, str], float] | None" = None,
                   *,
                   comm: "Mapping[tuple[int, str], float] | "
                         "Callable[[int, str], float] | None" = None,
                   overlap: bool = False) -> PricedSchedule:
    """Re-time ``sched`` under non-uniform tick durations.

    ``durations`` maps ``(virtual stage, phase) -> seconds`` (mapping or
    callable; default uniform 1.0).  Ticks are processed in slot order —
    each starts at ``max(device free, dependency finishes)`` — so with
    uniform durations the makespan equals the slot count exactly, and
    with per-stage costs (``costmodel.pipeline_tick_durations``) the
    makespan is the critical-path time of the timetable the executors
    would actually run.

    ``comm`` optionally maps ``(virtual stage, phase) -> seconds`` of
    communication attributable to the tick (P2P sends plus, on backward
    ticks, eager grad-reduce issue).  A synchronous executor serializes
    it after compute, so each tick costs ``compute + comm``; with
    ``overlap=True`` the tick is priced as the async executor runs it —
    comm streams behind the next tick's compute, so the tick occupies
    ``max(compute, comm)``.  Because ``max(a, b) <= a + b`` for
    non-negative costs, overlap pricing can never exceed sync pricing of
    the same (durations, comm) split.  ``comm=None`` (the default)
    prices exactly as before this knob existed, whatever ``overlap``.
    """
    if durations is None:
        get = lambda s, ph: 1.0                      # noqa: E731
    elif callable(durations):
        get = durations
    else:
        get = lambda s, ph: float(durations[(s, ph)])  # noqa: E731
    if comm is None:
        cget = lambda s, ph: 0.0                     # noqa: E731
    elif callable(comm):
        cget = comm
    else:
        cget = lambda s, ph: float(comm[(s, ph)])    # noqa: E731
    starts: dict = {}
    finishes: dict = {}
    avail: dict[int, float] = {}
    busy: dict[int, float] = {}
    nv = sched.n_virtual
    for t in sched.ticks:                 # (slot, stage) order: deps first
        key = (t.stage, t.microbatch, t.phase)
        deps = []
        if t.phase == "fwd":
            if t.stage > 0:
                deps.append((t.stage - 1, t.microbatch, "fwd"))
        else:
            if t.stage < nv - 1:
                deps.append((t.stage + 1, t.microbatch, "bwd"))
            deps.append((t.stage, t.microbatch, "fwd"))
        dev = sched.device_of(t.stage)
        start = avail.get(dev, 0.0)
        for d in deps:
            if d not in finishes:
                raise ScheduleError(
                    f"cannot price invalid schedule: tick {key} runs "
                    f"before its dependency {d}")
            start = max(start, finishes[d])
        comp = get(t.stage, t.phase)
        cdur = cget(t.stage, t.phase)
        dur = max(comp, cdur) if overlap else comp + cdur
        starts[key] = start
        finishes[key] = start + dur
        avail[dev] = start + dur
        busy[dev] = busy.get(dev, 0.0) + dur
    makespan = max(finishes.values(), default=0.0)
    return PricedSchedule(sched, starts, finishes, makespan, busy)


def _closed_form_ticks(kind: str, s_total: int, m: int) -> list[Tick]:
    """The 1F1B/GPipe closed-form slots (see ``build_schedule``)."""
    ticks: list[Tick] = []
    for s in range(s_total):
        if kind == "gpipe":
            for j in range(m):
                ticks.append(Tick(s + j, s, j, "fwd"))
                ticks.append(Tick(m + 2 * s_total - 2 - s + j, s, j, "bwd"))
        else:  # 1f1b
            warm = min(s_total - 1 - s, m)
            for j in range(m):
                if j < warm:
                    slot = s + j
                else:
                    slot = 2 * s_total - 2 - s + 2 * (j - warm)
                ticks.append(Tick(slot, s, j, "fwd"))
                ticks.append(Tick(2 * s_total - 1 - s + 2 * j, s, j, "bwd"))
    ticks.sort(key=lambda t: (t.slot, t.stage))
    return ticks


def _interleaved_units(s_total: int, v: int,
                       m: int) -> tuple[list, list]:
    """Megatron's virtual-microbatch unit orders: microbatches advance in
    groups of (up to) ``S``; within a group all ``v`` chunks of the group
    run before the next group starts (chunk-major forward, reverse
    chunk-major backward)."""
    fwd: list[tuple[int, int]] = []
    bwd: list[tuple[int, int]] = []
    lo = 0
    while lo < m:
        group = min(s_total, m - lo)
        for c in range(v):
            fwd.extend((c, lo + i) for i in range(group))
        for c in reversed(range(v)):
            bwd.extend((c, lo + i) for i in range(group))
        lo += group
    return fwd, bwd


def _interleaved_ticks(s_total: int, v: int, m: int) -> list[Tick]:
    """Emit the interleaved timetable by list-scheduling Megatron's
    per-device unit order: device ``s`` warms up with
    ``min(2*(S-1-s) + (v-1)*S, m*v)`` forwards, then alternates strictly
    1F1B over virtual units.  A time-stepped greedy assigns slots — each
    device fires its next unit once all dependencies finished in an
    earlier slot — so the result is dependency-valid by construction."""
    fwd_units, bwd_units = _interleaved_units(s_total, v, m)
    orders: list[list[tuple[str, int, int]]] = []
    for s in range(s_total):
        w = min(2 * (s_total - 1 - s) + (v - 1) * s_total, m * v)
        units = [("fwd", c, j) for c, j in fwd_units[:w]]
        for i, (c, j) in enumerate(bwd_units):
            if w + i < len(fwd_units):
                fc, fj = fwd_units[w + i]
                units.append(("fwd", fc, fj))
            units.append(("bwd", c, j))
        orders.append(units)

    ticks: list[Tick] = []
    progress = [0] * s_total
    done: dict[tuple[int, int, str], int] = {}
    n_v = s_total * v
    total = 2 * m * v * s_total
    slot = 0
    while len(ticks) < total:
        fired: list[tuple[int, int, int, str]] = []
        for s in range(s_total):
            if progress[s] >= len(orders[s]):
                continue
            phase, c, j = orders[s][progress[s]]
            vs = c * s_total + s
            if phase == "fwd":
                deps = [(vs - 1, j, "fwd")] if vs > 0 else []
            else:
                deps = [(vs, j, "fwd")]
                if vs < n_v - 1:
                    deps.append((vs + 1, j, "bwd"))
            if all(d in done for d in deps):
                fired.append((s, vs, j, phase))
        if not fired:
            raise ScheduleError(
                f"interleaved schedule deadlocked at slot {slot} "
                f"(S={s_total}, v={v}, m={m})")
        for s, vs, j, phase in fired:
            ticks.append(Tick(slot, vs, j, phase))
            progress[s] += 1
        for _, vs, j, phase in fired:
            done[(vs, j, phase)] = slot
        slot += 1
    ticks.sort(key=lambda t: (t.slot, t.stage))
    return ticks


def build_schedule(n_stages: int, num_microbatches: int,
                   kind: str = "1f1b",
                   virtual_stages_per_device: int = 1) -> PipelineSchedule:
    """Construct the per-stage timetable for ``kind``.

    Closed forms (uniform tick durations; ``S`` stages, ``m``
    microbatches, ``w_s = min(S-1-s, m)`` warmup forwards):

    =====  =========================================  ====================
    kind   fwd(j, s) slot                             bwd(j, s) slot
    =====  =========================================  ====================
    gpipe  ``s + j``                                  ``m + 2S - 2 - s + j``
    1f1b   warmup ``s + j``; steady                   ``2S - 1 - s + 2j``
           ``2S - 2 - s + 2(j - w_s)``
    =====  =========================================  ====================

    Both span ``2 (m + S - 1)`` slots — 1F1B trades nothing in makespan
    (for uniform ticks) but caps in-flight microbatches at the stage
    depth instead of ``m``.

    ``kind="interleaved"`` additionally takes
    ``virtual_stages_per_device`` (Megatron's ``v``): each device holds
    ``v`` model chunks and the timetable runs over ``S*v`` virtual
    stages (``Tick.stage`` is then the virtual index; the device is
    ``stage % S``).  ``v=1`` is exactly the 1F1B table.  Interleaving
    shrinks the fill/drain bubble ~``1/v`` at the price of holding up to
    ``2(S-1) + (v-1)S + 1`` in-flight microbatches per device.
    """
    if kind not in SCHEDULES:
        raise ScheduleError(f"unknown schedule {kind!r} (have {SCHEDULES})")
    if n_stages < 1:
        raise ScheduleError(f"need at least one stage (got {n_stages})")
    if num_microbatches < 1:
        raise ScheduleError(
            f"need at least one microbatch (got {num_microbatches})")
    v = virtual_stages_per_device
    if v < 1:
        raise ScheduleError(
            f"need at least one virtual stage per device (got {v})")
    if kind != "interleaved" and v != 1:
        raise ScheduleError(
            f"virtual_stages_per_device={v} requires kind='interleaved' "
            f"(got {kind!r})")
    s_total, m = n_stages, num_microbatches
    if kind == "interleaved" and v > 1:
        # Megatron's constraint: microbatches advance in groups of S, so
        # a trailing partial group would cross the first group's drain
        # and deadlock the 1F1B alternation.  A single (possibly
        # partial) group never overlaps itself, so m <= S is also fine.
        if m % s_total != 0 and m > s_total:
            raise ScheduleError(
                f"interleaved schedule needs num_microbatches divisible "
                f"by the stage count (or <= it): got m={m}, S={s_total}")
        ticks = _interleaved_ticks(s_total, v, m)
    else:  # 1f1b, gpipe, and interleaved at v=1 (degenerate, same table)
        ticks = _closed_form_ticks("gpipe" if kind == "gpipe" else "1f1b",
                                   s_total, m)
    sched = PipelineSchedule(kind, s_total, m, ticks, virtual_per_stage=v)
    validate(sched)
    return sched


def validate(sched: PipelineSchedule) -> None:
    """Assert the timetable is executable: each device runs one tick per
    slot, forwards follow the previous (virtual) stage, backwards follow
    the next (virtual) stage and the microbatch's own forward."""
    seen: dict[tuple[int, int, str], int] = {}
    busy: set[tuple[int, int]] = set()
    nv = sched.n_virtual
    for t in sched.ticks:
        if not 0 <= t.stage < nv:
            raise ScheduleError(
                f"tick stage {t.stage} out of range for {nv} virtual "
                f"stage(s)")
        key = (t.stage, t.microbatch, t.phase)
        if key in seen:
            raise ScheduleError(f"duplicate tick {key}")
        seen[key] = t.slot
        cell = (sched.device_of(t.stage), t.slot)
        if cell in busy:
            raise ScheduleError(
                f"device {cell[0]} runs two ticks in slot {t.slot}")
        busy.add(cell)
    expect = 2 * nv * sched.num_microbatches
    if len(sched.ticks) != expect:
        raise ScheduleError(
            f"{len(sched.ticks)} ticks scheduled, expected {expect}")

    def slot_of(stage: int, j: int, phase: str) -> int:
        slot = seen.get((stage, j, phase))
        if slot is None:
            raise ScheduleError(
                f"missing tick ({stage}, mb={j}, {phase})")
        return slot

    for (stage, j, phase), slot in seen.items():
        if phase == "fwd":
            if stage > 0 and slot_of(stage - 1, j, "fwd") >= slot:
                raise ScheduleError(
                    f"fwd(mb={j}) at stage {stage} precedes stage "
                    f"{stage - 1}")
        else:
            if stage < nv - 1 and \
                    slot_of(stage + 1, j, "bwd") >= slot:
                raise ScheduleError(
                    f"bwd(mb={j}) at stage {stage} precedes stage "
                    f"{stage + 1}")
            if slot_of(stage, j, "fwd") >= slot:
                raise ScheduleError(
                    f"bwd(mb={j}) at stage {stage} precedes its fwd")


# ---------------------------------------------------------------------------
# microbatch roles over a graph
# ---------------------------------------------------------------------------

def microbatch_roles(graph: Graph, batch_dim: int = 0) -> dict[str, int]:
    """Tensor name -> microbatch role (``op_semantics`` vocabulary):
    placeholders are Split along ``batch_dim``, parameters Duplicate,
    everything else propagates through ``op_semantics.microbatch_role``
    (reshape's split dim is remapped here, where shapes are known)."""
    roles: dict[str, int] = {}
    for op in graph.ops:
        out = op.outputs[0] if op.outputs else None
        if op.kind == "placeholder":
            if len(out.shape) <= batch_dim:
                raise MicrobatchError(
                    f"placeholder {out.name!r} has no batch dim "
                    f"{batch_dim} to split")
            roles[out.name] = batch_dim
            continue
        if op.kind == "parameter":
            roles[out.name] = MB_DUP
            continue
        grad_of = op.attrs.get("grad_of")
        if grad_of is not None:
            # gradient duality: a tensor's grad relates to the batch
            # split exactly as the tensor does, with Duplicate <->
            # Partial swapped (parameters accumulate grad summands
            # across microbatches; the Partial loss seeds an invariant
            # gradient) — op_semantics.cotangent_role
            roles[out.name] = op_semantics.cotangent_role(roles[grad_of])
            continue
        if op.kind == "comm":
            roles[out.name] = roles[op.inputs[0].name]
            continue
        in_roles = [roles[t.name] for t in op.inputs]
        try:
            role = op_semantics.microbatch_role(
                op.kind, in_roles, op.attrs,
                [len(t.shape) for t in op.inputs])
        except MicrobatchError as e:
            raise MicrobatchError(f"{out.name!r}: {e}") from None
        if op.kind == "reshape" and role >= 0:
            role = _map_reshape_dim(role, op.inputs[0].shape,
                                    op.attrs["new_shape"], out.name)
        roles[out.name] = role
    return roles


def _map_reshape_dim(d: int, old_shape, new_shape, name: str) -> int:
    """The batch dim survives a reshape iff the leading-dims product is
    preserved (the same rule annotation deduction uses; symbolic dims
    compare as canonicalized products)."""
    from .symbolic import dims_equal, prod_dims
    before = prod_dims(old_shape[:d])
    acc = 1
    for nd, size in enumerate(new_shape):
        if dims_equal(acc, before):
            return nd
        acc = prod_dims((acc, size))
    raise MicrobatchError(
        f"{name!r}: reshape moves the microbatch (batch) dim {d}")


def microbatch_graph(graph: Graph, num_microbatches: int,
                     roles: dict[str, int] | None = None,
                     shape_env: dict[str, int] | None = None) -> Graph:
    """A deep copy of ``graph`` with every Split-role shape scaled down
    to one microbatch (reshape targets rewritten alongside; symbolic
    dims are bound through ``shape_env`` first).  The copy keeps the
    installed annotations, so it compiles through the normal
    specialization path."""
    import copy

    from .symbolic import bind_shape, free_symbols

    roles = roles if roles is not None else microbatch_roles(graph)
    m = num_microbatches
    micro = copy.deepcopy(graph)
    env = dict(shape_env or {})
    for name, t in micro.tensors.items():
        if free_symbols(t.shape) <= set(env):
            t.shape = bind_shape(t.shape, env)
        d = roles[name]
        if d < 0:
            continue
        size = t.shape[d]
        if not isinstance(size, int):
            raise MicrobatchError(
                f"{name!r}: symbolic batch dim {size!r}; pass shape_env "
                f"to bind it before microbatching")
        if size % m != 0:
            raise MicrobatchError(
                f"{name!r}: batch dim {size} not divisible by "
                f"{m} microbatches")
        t.shape = t.shape[:d] + (size // m,) + t.shape[d + 1:]
    for op in micro.ops:
        if op.kind == "reshape" and roles[op.outputs[0].name] >= 0:
            op.attrs["new_shape"] = tuple(op.outputs[0].shape)
        if op.kind == "bcast":     # sum's VJP: re-aim at the scaled dim
            op.attrs["size"] = op.outputs[0].shape[op.attrs["dim"]]
    return micro


# ---------------------------------------------------------------------------
# op -> stage assignment + output combination
# ---------------------------------------------------------------------------

def _stage_walk(graph: Graph, strategy: int, pipelines: list[Pipeline]
                ) -> tuple[dict[int, int], dict[int, int], int, int]:
    """Walk ``graph.ops`` in program order assigning each op a physical
    stage and an interleave *chunk*.

    The physical stage is the deepest stage any of the op's tensors
    touches (stage-boundary CommOps thereby land with the *sending*
    chunk — the receive completes at the next chunk's first tick).  The
    chunk index counts how many times the dataflow has wrapped from a
    deep stage back to a shallower one: a graph that traverses the
    device ring ``v`` times (Megatron's interleaved layer assignment)
    yields chunks ``0..v-1``.  Leaf ops (placeholders/parameters) stay
    in chunk 0 — they are state, not scheduled work — and do not
    advance the walk.

    Backward ops (autodiff; ``op.attrs["phase"] == "bwd"``) do not
    advance the walk either — their dataflow traverses the ring in
    REVERSE, which would otherwise read as spurious wrap-arounds.  Each
    backward op instead inherits the (stage, chunk) of its forward
    anchor (``op.attrs["fwd_anchor"]``, the forward tensor whose VJP
    produced it): a stage's bwd tick runs exactly the backward of the
    ops its fwd tick ran.

    Returns ``(phys, chunk, n_stages, n_chunks)`` with ``phys`` /
    ``chunk`` keyed by ``id(op)``.
    """
    dev_stage: dict[int, int] = {}
    n_stages = 1
    for p in pipelines:
        n_stages = max(n_stages, p.n_stages)
        for d in p.devices():
            s = p.stage_of(d)
            dev_stage[d] = max(dev_stage.get(d, 0), s)
    phys: dict[int, int] = {}
    chunk: dict[int, int] = {}
    cur_stage = 0
    cur_chunk = 0
    for op in graph.ops:
        if op.attrs.get("phase") == "bwd":
            continue               # anchored below, after the fwd walk
        stages = [dev_stage.get(d, 0)
                  for t in op.inputs + op.outputs
                  for d in t.annots[strategy].devices]
        s = max(stages, default=0)
        phys[id(op)] = s
        if op.kind in ("placeholder", "parameter"):
            chunk[id(op)] = 0
            continue
        if s < cur_stage:          # dataflow wrapped around the ring
            cur_chunk += 1
        cur_stage = s
        chunk[id(op)] = cur_chunk
    for op in graph.ops:
        if op.attrs.get("phase") != "bwd":
            continue
        anchor = op.attrs.get("fwd_anchor")
        aop = graph.tensors[anchor].producer if anchor else None
        if aop is not None and id(aop) in phys:
            phys[id(op)] = phys[id(aop)]
            chunk[id(op)] = chunk[id(aop)]
        else:
            phys[id(op)] = 0
            chunk[id(op)] = 0
    return phys, chunk, n_stages, cur_chunk + 1


def infer_virtual_stages(graph: Graph, strategy: int,
                         pipelines: list[Pipeline]) -> int:
    """How many model chunks per device this graph's dataflow makes
    (Megatron's ``v``): 1 + the number of times program order wraps from
    a deep pipeline stage back to a shallower one.  ``v > 1`` graphs can
    only be scheduled with ``kind="interleaved"``."""
    return _stage_walk(graph, strategy, pipelines)[3]


def assign_stages(graph: Graph, strategy: int,
                  pipelines: list[Pipeline],
                  virtual_stages_per_device: int = 1) -> dict[int, int]:
    """Map ``id(op) -> (virtual) stage index``.  A device's stage is its
    position in its pipeline; an op runs at the deepest stage any of its
    tensors touches, so a *forward* stage-boundary CommOp lands on the
    receiving stage (the activation send completes the hop), while a
    *wrap-around* CommOp (deep stage back to a shallow one) lands on the
    sending stage — its receive completes at the next chunk's first
    tick (see ``_stage_walk``).

    With ``virtual_stages_per_device = v > 1`` ops are additionally
    bucketed into interleave chunks (``_stage_walk``): an op in chunk
    ``c`` at physical stage ``s`` runs at virtual stage ``c*S + s`` —
    the tick indices ``build_schedule(kind="interleaved")`` emits.
    Raises if the graph wraps more times than ``v`` allows."""
    phys, chunk, n_stages, n_chunks = _stage_walk(graph, strategy,
                                                  pipelines)
    v = virtual_stages_per_device
    if n_chunks > v:
        raise ScheduleError(
            f"graph dataflow makes {n_chunks} chunk(s) per device but "
            f"virtual_stages_per_device={v}; schedule it with "
            f"kind='interleaved' and v >= {n_chunks}")
    out: dict[int, int] = {}
    for op in graph.ops:
        out[id(op)] = chunk[id(op)] * n_stages + phys[id(op)]
    return out


def combine_outputs(per_mb: list[dict], roles: dict[str, int],
                    full_shapes: dict[str, tuple[int, ...]],
                    full_annots: dict[str, object]) -> dict:
    """Reduce per-microbatch fetches to full-batch ShardedTensors.

    Partial -> sequential per-shard sum in microbatch order (both
    executors' per-microbatch shards are bit-exact, so the combined
    shards are too); Duplicate -> microbatch 0's shards; Split(d) ->
    gather each microbatch globally, concatenate along ``d`` and
    re-scatter under the full-batch annotation.
    """
    from .simulator import ShardedTensor, gather, scatter

    out: dict[str, ShardedTensor] = {}
    for name in per_mb[0]:
        role = roles[name]
        shards = [r[name] for r in per_mb]
        annot = full_annots[name]
        if role == MB_PARTIAL:
            parts = {d: a.copy() for d, a in shards[0].parts.items()}
            for st in shards[1:]:
                for d in parts:
                    parts[d] = parts[d] + st.parts[d]
            out[name] = ShardedTensor(full_shapes[name], annot, parts)
        elif role == MB_DUP:
            out[name] = ShardedTensor(full_shapes[name], annot,
                                      dict(shards[0].parts))
        else:
            if annot.has_partial:
                raise MicrobatchError(
                    f"cannot reconstruct Split-role fetch {name!r} under "
                    f"a Partial annotation; fetch the reduced value "
                    f"instead")
            full = np.concatenate([gather(s) for s in shards], axis=role)
            out[name] = scatter(full, annot)
    return out


__all__ = [
    "PipelineSchedule", "PricedSchedule", "ScheduleError", "ScheduleStats",
    "Tick", "SCHEDULES", "assign_stages", "build_schedule",
    "combine_outputs", "infer_virtual_stages", "microbatch_graph",
    "microbatch_roles", "price_schedule", "validate",
]

# re-exported for callers reasoning about roles without op_semantics
assert (MB_DUP, MB_PARTIAL) == (DUP, PARTIAL)
