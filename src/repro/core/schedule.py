"""Pipeline schedule engine: 1F1B / GPipe timetables over `CompiledPlan.pipelines`.

Progressive specialization (paper §5.3-5.4) builds the *spatial* half of
a strategy — per-device executable graphs linked into pipelines.  This
module supplies the *temporal* half: given the pipeline's stage count and
a microbatch count it emits an explicit per-stage timetable of
``(slot, stage, microbatch, phase)`` :class:`Tick`\\ s for the two
canonical synchronous schedules,

* **GPipe** — all ``m`` forwards flow through, then all ``m`` backwards
  drain back; every stage holds up to ``m`` in-flight microbatches,
* **1F1B** — each stage warms up with ``min(S-1-stage, m)`` forwards and
  then strictly alternates one-forward-one-backward, bounding in-flight
  microbatches by the stage depth instead of ``m`` (JaxPP / Megatron's
  memory-bounded schedule).

Both schedules share the fill/drain shape the analytic cost model prices
(``costmodel.fill_drain_count``): with uniform fwd/bwd tick costs the
timetable spans exactly ``2 * (m + S - 1)`` slots.  ``validate`` checks
the dependency structure (fwd follows the previous stage, bwd follows the
next stage, one tick per stage per slot); :class:`ScheduleStats` surfaces
ticks / bubbles / p2p message counts on ``CompiledPlan`` and
``RunResult``.

The second half of the module maps a *graph* onto the timetable:
``microbatch_roles`` propagates how each tensor relates to the batch
split (Split / Duplicate / Partial — ``op_semantics.microbatch_role``),
``microbatch_graph`` scales a deduced graph's shapes down to one
microbatch, ``assign_stages`` buckets ops into pipeline stages, and
``combine_outputs`` reduces per-microbatch fetches back to full-batch
values (sum Partial, concatenate Split, take-one Duplicate).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import op_semantics
from .annotations import DUP, PARTIAL
from .graph import Graph
from .op_semantics import MB_DUP, MB_PARTIAL, MicrobatchError
from .specialize import Pipeline

SCHEDULES = ("1f1b", "gpipe")


class ScheduleError(ValueError):
    """Invalid schedule request (unknown kind, bad microbatch count)."""


@dataclass(frozen=True)
class Tick:
    """One unit of pipeline work: ``stage`` runs ``phase`` for
    ``microbatch`` during time ``slot`` (uniform fwd/bwd durations)."""

    slot: int
    stage: int
    microbatch: int
    phase: str            # "fwd" | "bwd"


@dataclass(frozen=True)
class ScheduleStats:
    """Static accounting of one timetable."""

    n_ticks: int          # compute ticks actually scheduled (2 * m * S)
    n_slots: int          # timeline length in slots
    bubbles: int          # idle (stage, slot) cells across the timetable
    p2p_messages: int     # stage-boundary sends (fwd activations + bwd grads)

    def summary(self) -> str:
        return (f"{self.n_ticks} ticks over {self.n_slots} slots, "
                f"{self.bubbles} bubbles, {self.p2p_messages} p2p msgs")


@dataclass
class PipelineSchedule:
    """An explicit timetable: ``ticks`` ordered by (slot, stage)."""

    kind: str
    n_stages: int
    num_microbatches: int
    ticks: list[Tick] = field(default_factory=list)

    @property
    def n_slots(self) -> int:
        return max(t.slot for t in self.ticks) + 1 if self.ticks else 0

    @property
    def fill_drain_slots(self) -> int:
        """Timeline length in fwd+bwd *pairs* — the ``(m + S - 1)``
        fill/drain count the cost model prices."""
        return self.n_slots // 2

    def stage_ticks(self, stage: int) -> list[Tick]:
        return [t for t in self.ticks if t.stage == stage]

    def by_slot(self) -> dict[int, list[Tick]]:
        out: dict[int, list[Tick]] = {}
        for t in self.ticks:
            out.setdefault(t.slot, []).append(t)
        return out

    def peak_in_flight(self, stage: int) -> int:
        """Max microbatches forwarded but not yet backwarded at ``stage``
        (the activation-memory bound the 1F1B schedule exists to cap)."""
        live = peak = 0
        for t in sorted(self.stage_ticks(stage), key=lambda t: t.slot):
            live += 1 if t.phase == "fwd" else -1
            peak = max(peak, live)
        return peak

    def warmup_depth(self, stage: int) -> int:
        """Forward ticks this stage runs before its first backward."""
        n = 0
        for t in sorted(self.stage_ticks(stage), key=lambda t: t.slot):
            if t.phase == "bwd":
                break
            n += 1
        return n

    def stats(self) -> ScheduleStats:
        m, s = self.num_microbatches, self.n_stages
        return ScheduleStats(
            n_ticks=len(self.ticks),
            n_slots=self.n_slots,
            bubbles=s * self.n_slots - len(self.ticks),
            p2p_messages=2 * m * (s - 1))

    def describe(self) -> str:
        lines = [f"{self.kind} schedule: {self.n_stages} stage(s) x "
                 f"{self.num_microbatches} microbatch(es), "
                 + self.stats().summary()]
        by_slot = self.by_slot()
        for s in range(self.n_stages):
            row = []
            for slot in range(self.n_slots):
                tick = next((t for t in by_slot.get(slot, ())
                             if t.stage == s), None)
                row.append("  .  " if tick is None else
                           f"{tick.phase[0].upper()}{tick.microbatch:<3d} ")
            lines.append(f"  stage {s}: " + "".join(row))
        return "\n".join(lines)


def build_schedule(n_stages: int, num_microbatches: int,
                   kind: str = "1f1b") -> PipelineSchedule:
    """Construct the per-stage timetable for ``kind``.

    Closed forms (uniform tick durations; ``S`` stages, ``m``
    microbatches, ``w_s = min(S-1-s, m)`` warmup forwards):

    =====  =========================================  ====================
    kind   fwd(j, s) slot                             bwd(j, s) slot
    =====  =========================================  ====================
    gpipe  ``s + j``                                  ``m + 2S - 2 - s + j``
    1f1b   warmup ``s + j``; steady                   ``2S - 1 - s + 2j``
           ``2S - 2 - s + 2(j - w_s)``
    =====  =========================================  ====================

    Both span ``2 (m + S - 1)`` slots — 1F1B trades nothing in makespan
    (for uniform ticks) but caps in-flight microbatches at the stage
    depth instead of ``m``.
    """
    if kind not in SCHEDULES:
        raise ScheduleError(f"unknown schedule {kind!r} (have {SCHEDULES})")
    if n_stages < 1:
        raise ScheduleError(f"need at least one stage (got {n_stages})")
    if num_microbatches < 1:
        raise ScheduleError(
            f"need at least one microbatch (got {num_microbatches})")
    s_total, m = n_stages, num_microbatches
    ticks: list[Tick] = []
    for s in range(s_total):
        if kind == "gpipe":
            for j in range(m):
                ticks.append(Tick(s + j, s, j, "fwd"))
                ticks.append(Tick(m + 2 * s_total - 2 - s + j, s, j, "bwd"))
        else:  # 1f1b
            warm = min(s_total - 1 - s, m)
            for j in range(m):
                if j < warm:
                    slot = s + j
                else:
                    slot = 2 * s_total - 2 - s + 2 * (j - warm)
                ticks.append(Tick(slot, s, j, "fwd"))
                ticks.append(Tick(2 * s_total - 1 - s + 2 * j, s, j, "bwd"))
    ticks.sort(key=lambda t: (t.slot, t.stage))
    sched = PipelineSchedule(kind, s_total, m, ticks)
    validate(sched)
    return sched


def validate(sched: PipelineSchedule) -> None:
    """Assert the timetable is executable: each stage runs one tick per
    slot, forwards follow the previous stage, backwards follow the next
    stage and the microbatch's own forward."""
    seen: dict[tuple[int, int, str], int] = {}
    busy: set[tuple[int, int]] = set()
    for t in sched.ticks:
        key = (t.stage, t.microbatch, t.phase)
        if key in seen:
            raise ScheduleError(f"duplicate tick {key}")
        seen[key] = t.slot
        cell = (t.stage, t.slot)
        if cell in busy:
            raise ScheduleError(
                f"stage {t.stage} runs two ticks in slot {t.slot}")
        busy.add(cell)
    expect = 2 * sched.n_stages * sched.num_microbatches
    if len(sched.ticks) != expect:
        raise ScheduleError(
            f"{len(sched.ticks)} ticks scheduled, expected {expect}")

    def slot_of(stage: int, j: int, phase: str) -> int:
        slot = seen.get((stage, j, phase))
        if slot is None:
            raise ScheduleError(
                f"missing tick ({stage}, mb={j}, {phase})")
        return slot

    for (stage, j, phase), slot in seen.items():
        if phase == "fwd":
            if stage > 0 and slot_of(stage - 1, j, "fwd") >= slot:
                raise ScheduleError(
                    f"fwd(mb={j}) at stage {stage} precedes stage "
                    f"{stage - 1}")
        else:
            if stage < sched.n_stages - 1 and \
                    slot_of(stage + 1, j, "bwd") >= slot:
                raise ScheduleError(
                    f"bwd(mb={j}) at stage {stage} precedes stage "
                    f"{stage + 1}")
            if slot_of(stage, j, "fwd") >= slot:
                raise ScheduleError(
                    f"bwd(mb={j}) at stage {stage} precedes its fwd")


# ---------------------------------------------------------------------------
# microbatch roles over a graph
# ---------------------------------------------------------------------------

def microbatch_roles(graph: Graph, batch_dim: int = 0) -> dict[str, int]:
    """Tensor name -> microbatch role (``op_semantics`` vocabulary):
    placeholders are Split along ``batch_dim``, parameters Duplicate,
    everything else propagates through ``op_semantics.microbatch_role``
    (reshape's split dim is remapped here, where shapes are known)."""
    roles: dict[str, int] = {}
    for op in graph.ops:
        out = op.outputs[0] if op.outputs else None
        if op.kind == "placeholder":
            if len(out.shape) <= batch_dim:
                raise MicrobatchError(
                    f"placeholder {out.name!r} has no batch dim "
                    f"{batch_dim} to split")
            roles[out.name] = batch_dim
            continue
        if op.kind == "parameter":
            roles[out.name] = MB_DUP
            continue
        if op.kind == "comm":
            roles[out.name] = roles[op.inputs[0].name]
            continue
        in_roles = [roles[t.name] for t in op.inputs]
        try:
            role = op_semantics.microbatch_role(
                op.kind, in_roles, op.attrs,
                [len(t.shape) for t in op.inputs])
        except MicrobatchError as e:
            raise MicrobatchError(f"{out.name!r}: {e}") from None
        if op.kind == "reshape" and role >= 0:
            role = _map_reshape_dim(role, op.inputs[0].shape,
                                    op.attrs["new_shape"], out.name)
        roles[out.name] = role
    return roles


def _map_reshape_dim(d: int, old_shape, new_shape, name: str) -> int:
    """The batch dim survives a reshape iff the leading-dims product is
    preserved (the same rule annotation deduction uses)."""
    import math
    before = math.prod(old_shape[:d])
    acc = 1
    for nd, size in enumerate(new_shape):
        if acc == before:
            return nd
        acc *= size
    raise MicrobatchError(
        f"{name!r}: reshape moves the microbatch (batch) dim {d}")


def microbatch_graph(graph: Graph, num_microbatches: int,
                     roles: dict[str, int] | None = None,
                     shape_env: dict[str, int] | None = None) -> Graph:
    """A deep copy of ``graph`` with every Split-role shape scaled down
    to one microbatch (reshape targets rewritten alongside; symbolic
    dims are bound through ``shape_env`` first).  The copy keeps the
    installed annotations, so it compiles through the normal
    specialization path."""
    import copy

    from .symbolic import bind_shape, free_symbols

    roles = roles if roles is not None else microbatch_roles(graph)
    m = num_microbatches
    micro = copy.deepcopy(graph)
    env = dict(shape_env or {})
    for name, t in micro.tensors.items():
        if free_symbols(t.shape) <= set(env):
            t.shape = bind_shape(t.shape, env)
        d = roles[name]
        if d < 0:
            continue
        size = t.shape[d]
        if not isinstance(size, int):
            raise MicrobatchError(
                f"{name!r}: symbolic batch dim {size!r}; pass shape_env "
                f"to bind it before microbatching")
        if size % m != 0:
            raise MicrobatchError(
                f"{name!r}: batch dim {size} not divisible by "
                f"{m} microbatches")
        t.shape = t.shape[:d] + (size // m,) + t.shape[d + 1:]
    for op in micro.ops:
        if op.kind == "reshape" and roles[op.outputs[0].name] >= 0:
            op.attrs["new_shape"] = tuple(op.outputs[0].shape)
    return micro


# ---------------------------------------------------------------------------
# op -> stage assignment + output combination
# ---------------------------------------------------------------------------

def assign_stages(graph: Graph, strategy: int,
                  pipelines: list[Pipeline]) -> dict[int, int]:
    """Map ``id(op) -> stage index``.  A device's stage is its position
    in its pipeline; an op runs at the deepest stage any of its tensors
    touches (stage-boundary CommOps thereby land on the *receiving*
    stage — the activation send completes the hop)."""
    dev_stage: dict[int, int] = {}
    for p in pipelines:
        for d in p.devices():
            s = p.stage_of(d)
            dev_stage[d] = max(dev_stage.get(d, 0), s)
    out: dict[int, int] = {}
    for op in graph.ops:
        stages = [dev_stage.get(d, 0)
                  for t in op.inputs + op.outputs
                  for d in t.annots[strategy].devices]
        out[id(op)] = max(stages, default=0)
    return out


def combine_outputs(per_mb: list[dict], roles: dict[str, int],
                    full_shapes: dict[str, tuple[int, ...]],
                    full_annots: dict[str, object]) -> dict:
    """Reduce per-microbatch fetches to full-batch ShardedTensors.

    Partial -> sequential per-shard sum in microbatch order (both
    executors' per-microbatch shards are bit-exact, so the combined
    shards are too); Duplicate -> microbatch 0's shards; Split(d) ->
    gather each microbatch globally, concatenate along ``d`` and
    re-scatter under the full-batch annotation.
    """
    from .simulator import ShardedTensor, gather, scatter

    out: dict[str, ShardedTensor] = {}
    for name in per_mb[0]:
        role = roles[name]
        shards = [r[name] for r in per_mb]
        annot = full_annots[name]
        if role == MB_PARTIAL:
            parts = {d: a.copy() for d, a in shards[0].parts.items()}
            for st in shards[1:]:
                for d in parts:
                    parts[d] = parts[d] + st.parts[d]
            out[name] = ShardedTensor(full_shapes[name], annot, parts)
        elif role == MB_DUP:
            out[name] = ShardedTensor(full_shapes[name], annot,
                                      dict(shards[0].parts))
        else:
            if annot.has_partial:
                raise MicrobatchError(
                    f"cannot reconstruct Split-role fetch {name!r} under "
                    f"a Partial annotation; fetch the reduced value "
                    f"instead")
            full = np.concatenate([gather(s) for s in shards], axis=role)
            out[name] = scatter(full, annot)
    return out


__all__ = [
    "PipelineSchedule", "ScheduleError", "ScheduleStats", "Tick",
    "SCHEDULES", "assign_stages", "build_schedule", "combine_outputs",
    "microbatch_graph", "microbatch_roles", "validate",
]

# re-exported for callers reasoning about roles without op_semantics
assert (MB_DUP, MB_PARTIAL) == (DUP, PARTIAL)
