"""Dynamic graph switching (paper §6, Fig 12).

A tensor bound to multiple annotations yields one annotated graph per
parallel strategy (§6.1).  Switching strategies = re-sharding every weight
from its source annotation to its destination annotation, modeled as one
**fused BSR** task over all tensors (§6.2): a single global BSR table,
heuristics + per-pair message fusion, load-balanced across the whole
transition.

``switch`` also executes the plan on the virtual-device simulator so the
weight migration is verified numerically, and reports the statistics the
paper uses in Fig 18 / Table 2 (per-rank volume over fast/slow links,
message counts, estimated transition time).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .annotations import HSPMD
from .bsr import BsrPlan, plan_bsr_naive, plan_fused_bsr, plan_unfused_bsr
from .graph import Graph
from .plan import CommPlan
from .simulator import ShardedTensor, apply_plan
from .topology import Topology, UniformTopology


@dataclass
class SwitchReport:
    plan: BsrPlan
    planning_seconds: float
    est_transfer_seconds: float
    total_bytes: int
    message_count: int
    per_sender: dict[int, tuple[int, int]] = field(default_factory=dict)

    def summary(self) -> str:
        return (f"{self.message_count} msgs, {self.total_bytes / 1e6:.1f} MB, "
                f"plan {self.planning_seconds * 1e3:.1f} ms, "
                f"est transfer {self.est_transfer_seconds * 1e3:.1f} ms")


def plan_switch(graph: Graph, src_strategy: int, dst_strategy: int,
                shape_env: dict[str, int] | None = None,
                topology: Topology | None = None,
                mode: str = "fused") -> SwitchReport:
    """Plan the weight migration between two annotated strategies."""
    from .symbolic import bind_shape
    topology = topology or UniformTopology()
    tensors = []
    for p in graph.parameters():
        shape = bind_shape(p.shape, shape_env or {})
        tensors.append((p.name, p.annots[src_strategy],
                        p.annots[dst_strategy], shape, 2))
    t0 = time.perf_counter()
    if mode == "fused":
        plan = plan_fused_bsr(tensors, topology)
    elif mode == "unfused":
        plan = plan_unfused_bsr(tensors, topology)
    elif mode == "naive":
        assignments = []
        for name, s, d, shape, isz in tensors:
            assignments.extend(plan_bsr_naive(s, d, shape, name, isz).assignments)
        plan = BsrPlan(assignments, fused=False)
    else:
        raise ValueError(mode)
    dt = time.perf_counter() - t0
    return SwitchReport(
        plan=plan,
        planning_seconds=dt,
        est_transfer_seconds=plan.est_time(topology),
        total_bytes=plan.total_bytes(),
        message_count=plan.message_count(),
        per_sender=plan.per_sender_bytes(topology),
    )


def execute_switch(weights: dict[str, ShardedTensor],
                   graph: Graph, src_strategy: int, dst_strategy: int,
                   shape_env: dict[str, int] | None = None,
                   topology: Topology | None = None, *,
                   backend: str = "sim", mesh=None,
                   reduction: str = "exact") -> dict[str, ShardedTensor]:
    """Migrate weight shards to the destination strategy.

    Per-tensor plans share the fused global planning state; execution is
    per tensor either on the virtual-device simulator (``backend="sim"``,
    numerically exact) or on real JAX devices through the shard_map
    execution backend (``backend="jax"`` — the fused-BSR messages become
    actual collective-permutes; see ``repro.runtime``)."""
    from .symbolic import bind_shape
    if backend not in ("sim", "jax"):
        raise ValueError(f"unknown switch backend {backend!r}")
    report = plan_switch(graph, src_strategy, dst_strategy, shape_env,
                         topology, mode="fused")
    by_tensor: dict[str, list] = {}
    for a in report.plan.assignments:
        by_tensor.setdefault(a.tensor, []).append(a)

    out: dict[str, ShardedTensor] = {}
    for p in graph.parameters():
        src = p.annots[src_strategy]
        dst = p.annots[dst_strategy]
        shape = bind_shape(p.shape, shape_env or {})
        sub = BsrPlan(by_tensor.get(p.name, []), fused=True)
        cp = CommPlan(src=src, dst=dst, kind="switch:BSR")
        cp.add(sub.to_step(), dst)
        if backend == "jax":
            from repro.runtime import execute_sharded
            out[p.name] = execute_sharded(weights[p.name], cp, mesh,
                                          reduction=reduction)
        else:
            out[p.name] = apply_plan(weights[p.name], cp)
    return out
