"""Dynamic graph switching (paper §6, Fig 12).

A tensor bound to multiple annotations yields one annotated graph per
parallel strategy (§6.1).  Switching strategies = re-sharding every weight
from its source annotation to its destination annotation, modeled as one
**fused BSR** task over all tensors (§6.2): a single global BSR table,
heuristics + per-pair message fusion, load-balanced across the whole
transition.

``switch`` also executes the plan on the virtual-device simulator so the
weight migration is verified numerically, and reports the statistics the
paper uses in Fig 18 / Table 2 (per-rank volume over fast/slow links,
message counts, estimated transition time).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .annotations import HSPMD
from .bsr import BsrPlan, plan_bsr_naive, plan_fused_bsr, plan_unfused_bsr
from .graph import Graph
from .plan import CommPlan
from .simulator import ShardedTensor, apply_plan
from .topology import Topology, UniformTopology


@dataclass
class SwitchReport:
    plan: BsrPlan
    planning_seconds: float
    est_transfer_seconds: float
    total_bytes: int
    message_count: int
    per_sender: dict[int, tuple[int, int]] = field(default_factory=dict)
    # stamped by Session.switch for consumers that track live transitions
    # (the elastic trace driver): measured end-to-end wall seconds of the
    # whole switch (plan + execute + recompile) and the strategy names
    wall_seconds: float = 0.0
    src_name: str = ""
    dst_name: str = ""

    def summary(self) -> str:
        arrow = (f"{self.src_name} -> {self.dst_name}: "
                 if self.src_name or self.dst_name else "")
        return (f"{arrow}{self.message_count} msgs, "
                f"{self.total_bytes / 1e6:.1f} MB, "
                f"plan {self.planning_seconds * 1e3:.1f} ms, "
                f"est transfer {self.est_transfer_seconds * 1e3:.1f} ms")


def plan_tensor_switch(tensors, topology: Topology | None = None,
                       mode: str = "fused") -> SwitchReport:
    """Plan one global BSR migration over ``(name, src_annot, dst_annot,
    shape, itemsize)`` tuples — the shared core of graph switching and the
    scenario cost models (elastic / mixed-length)."""
    topology = topology or UniformTopology()
    t0 = time.perf_counter()
    if mode == "fused":
        plan = plan_fused_bsr(tensors, topology)
    elif mode == "unfused":
        plan = plan_unfused_bsr(tensors, topology)
    elif mode == "naive":
        assignments = []
        for name, s, d, shape, isz in tensors:
            assignments.extend(plan_bsr_naive(s, d, shape, name, isz).assignments)
        plan = BsrPlan(assignments, fused=False)
    else:
        raise ValueError(mode)
    dt = time.perf_counter() - t0
    return SwitchReport(
        plan=plan,
        planning_seconds=dt,
        est_transfer_seconds=plan.est_time(topology),
        total_bytes=plan.total_bytes(),
        message_count=plan.message_count(),
        per_sender=plan.per_sender_bytes(topology),
    )


def plan_switch(graph: Graph, src_strategy: int, dst_strategy: int,
                shape_env: dict[str, int] | None = None,
                topology: Topology | None = None,
                mode: str = "fused", itemsize=2) -> SwitchReport:
    """Plan the weight migration between two annotated strategies.

    ``itemsize`` prices the byte/time statistics: an int (default 2 =
    bf16, the paper's training dtype) or a per-tensor ``name -> int``
    callable (``switch`` below passes the live weights' itemsizes).
    """
    from .symbolic import bind_shape
    isz = itemsize if callable(itemsize) else (lambda name: itemsize)
    tensors = []
    for p in graph.parameters():
        shape = bind_shape(p.shape, shape_env or {})
        tensors.append((p.name, p.annots[src_strategy],
                        p.annots[dst_strategy], shape, isz(p.name)))
    return plan_tensor_switch(tensors, topology, mode)


def execute_switch(weights: dict[str, ShardedTensor],
                   graph: Graph, src_strategy: int, dst_strategy: int,
                   shape_env: dict[str, int] | None = None,
                   topology: Topology | None = None, *,
                   backend: str = "sim", mesh=None,
                   reduction: str = "exact",
                   report: SwitchReport | None = None
                   ) -> dict[str, ShardedTensor]:
    """Migrate weight shards to the destination strategy.

    Per-tensor plans share the fused global planning state; execution is
    per tensor either on the virtual-device simulator (``backend="sim"``,
    numerically exact) or on real JAX devices through the shard_map
    execution backend (``backend="jax"`` — the fused-BSR messages become
    actual collective-permutes; see ``repro.runtime``)."""
    from .symbolic import bind_shape
    if backend not in ("sim", "jax"):
        raise ValueError(f"unknown switch backend {backend!r}")
    if report is None:
        report = plan_switch(graph, src_strategy, dst_strategy, shape_env,
                             topology, mode="fused")
    by_tensor: dict[str, list] = {}
    for a in report.plan.assignments:
        by_tensor.setdefault(a.tensor, []).append(a)

    out: dict[str, ShardedTensor] = {}
    for p in graph.parameters():
        src = p.annots[src_strategy]
        dst = p.annots[dst_strategy]
        shape = bind_shape(p.shape, shape_env or {})
        sub = BsrPlan(by_tensor.get(p.name, []), fused=True)
        cp = CommPlan(src=src, dst=dst, kind="switch:BSR")
        cp.add(sub.to_step(), dst)
        if backend == "jax":
            from repro.runtime import execute_sharded
            out[p.name] = execute_sharded(weights[p.name], cp, mesh,
                                          reduction=reduction)
        else:
            out[p.name] = apply_plan(weights[p.name], cp)
    return out


@dataclass
class SwitchOutcome:
    """Stable result of a planned-and-executed strategy switch."""

    weights: dict[str, ShardedTensor]
    report: SwitchReport
    src_strategy: int
    dst_strategy: int


def switch(weights: dict[str, ShardedTensor],
           graph: Graph, src_strategy: int, dst_strategy: int,
           shape_env: dict[str, int] | None = None,
           topology: Topology | None = None, *,
           backend: str = "sim", mesh=None,
           reduction: str = "exact") -> SwitchOutcome:
    """Plan + execute the fused-BSR strategy switch, returning both the
    migrated weights and the planning/transfer report (paper §6.2) —
    what ``repro.api.Session.switch`` composes.  Report statistics are
    priced at each live weight's actual itemsize."""

    def isz(name: str) -> int:
        st = weights.get(name)
        if st is None:
            return 2
        return np.asarray(next(iter(st.parts.values()))).dtype.itemsize

    report = plan_switch(graph, src_strategy, dst_strategy, shape_env,
                         topology, mode="fused", itemsize=isz)
    new = execute_switch(weights, graph, src_strategy, dst_strategy,
                         shape_env, topology, backend=backend, mesh=mesh,
                         reduction=reduction, report=report)
    return SwitchOutcome(new, report, src_strategy, dst_strategy)
