"""Specialization-class lowering IR (between deduction and execution).

Progressive specialization (``core.specialize``) instantiates one
executable graph *per device*.  Executing that literally — one dispatch
per (op, device) — is what made the lowered jax program a forest of
``n_mesh``-way ``lax.switch``es and the simulator a per-device python
loop, even though in the common SPMD case every participating device
runs the *identical* local computation (same local input shapes, same
local output shape, same kernel implementation, same attrs).

This module computes the quotient: for each compute op under a strategy,
the **equivalence classes of devices that share the local computation**,
and groups maximal runs of compute ops between comm ops into
:class:`Segment`\\ s with a joint class partition (devices equivalent for
*every* op of the run).  Both executors lower onto it:

* ``runtime.program.LoweredGraph`` emits ONE branch per class per
  segment — the homogeneous case (one class, every device) becomes
  straight-line unpadded code with zero switches; the hetero / pipeline
  case gets a small switch over classes, not devices,
* ``api.executors.SimulatorExecutor`` applies one vectorized numpy
  kernel over a class's stacked shards instead of dispatching per
  device.

The per-device :class:`~repro.core.specialize.ExecItem` lists remain the
ground truth: :func:`check_against_exec_items` asserts that devices
placed in one class really do carry identical compute item sequences
over the segment (GSPMD's shared-program-for-symmetric-shards insight,
with the asymmetric classes kept first-class as HAP motivates).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Sequence

from .graph import Graph, Op
from .symbolic import bind_shape

#: impl tag for ops executed through the shared local semantics
#: (``core.op_semantics.local_apply``) rather than a dedicated kernel
SHARED_IMPL = ""

ImplOf = Callable[[Op, int], str]


@dataclass(frozen=True)
class OpSpec:
    """What one device of a class executes for one op: the static
    device-local geometry plus the kernel implementation tag."""

    in_shapes: tuple[tuple[int, ...], ...]
    out_shape: tuple[int, ...]
    impl: str = SHARED_IMPL


@dataclass(frozen=True)
class SegmentClass:
    """One specialization class: the devices sharing an identical local
    program over a segment (``specs[i] is None`` where the class does
    not run ``ops[i]`` — partial participation is just another class)."""

    devices: tuple[int, ...]
    specs: tuple["OpSpec | None", ...]

    @property
    def n_devices(self) -> int:
        return len(self.devices)


@dataclass
class Segment:
    """A maximal run of compute ops between comm ops, with the joint
    class partition of the participating devices."""

    ops: list[Op]
    classes: list[SegmentClass]
    idle_devices: tuple[int, ...]

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    def class_of(self, dev: int) -> int | None:
        """Index of ``dev``'s class, or ``None`` if it idles through the
        whole segment."""
        for i, cls in enumerate(self.classes):
            if dev in cls.devices:
                return i
        return None

    def is_homogeneous(self) -> bool:
        """One class, no idle devices: every device runs the identical
        local program — the straight-line (zero-switch) case."""
        return len(self.classes) == 1 and not self.idle_devices

    def describe(self) -> str:
        kinds = "+".join(op.kind for op in self.ops)
        sizes = "/".join(str(c.n_devices) for c in self.classes)
        idle = f" idle={len(self.idle_devices)}" if self.idle_devices \
            else ""
        return f"[{kinds}] classes={self.n_classes} ({sizes}){idle}"


@dataclass
class CommSlot:
    """A CommOp in execution order — a segment boundary."""

    op: Op


@dataclass
class LoweredIR:
    """The segment sequence of one (graph, strategy): alternating
    compute :class:`Segment`\\ s and :class:`CommSlot`\\ s, in op order."""

    strategy: int
    devices: tuple[int, ...]
    entries: list["Segment | CommSlot"]

    @property
    def segments(self) -> list[Segment]:
        return [e for e in self.entries if isinstance(e, Segment)]

    @property
    def comm_slots(self) -> list[CommSlot]:
        return [e for e in self.entries if isinstance(e, CommSlot)]

    def class_counts(self) -> list[int]:
        return [s.n_classes for s in self.segments]

    def total_classes(self) -> int:
        return sum(self.class_counts())

    def describe(self) -> str:
        lines = [f"strategy {self.strategy}: {len(self.segments)} "
                 f"segment(s), {len(self.comm_slots)} comm op(s), "
                 f"{len(self.devices)} device(s)"]
        for e in self.entries:
            lines.append("  " + (e.describe() if isinstance(e, Segment)
                                 else f"comm {e.op.outputs[0].name}"))
        return "\n".join(lines)


def op_participants(op: Op, strategy: int) -> tuple[int, ...]:
    """The devices that execute ``op`` — exactly progressive
    specialization's rule: compute ops run where their OUTPUT lives
    (``core.specialize.specialize``)."""
    if not op.outputs:
        return ()
    return op.outputs[0].annots[strategy].devices


def op_spec(op: Op, dev: int, strategy: int,
            shapes: dict[str, tuple[int, ...]],
            impl_of: ImplOf | None = None) -> OpSpec:
    """The static local-execution record of ``op`` on ``dev``."""
    out_t = op.outputs[0]
    in_shapes = tuple(
        tuple(t.annots[strategy].device_shape(dev, shapes[t.name]))
        for t in op.inputs)
    out_shape = tuple(
        out_t.annots[strategy].device_shape(dev, shapes[out_t.name]))
    impl = impl_of(op, dev) if impl_of is not None else SHARED_IMPL
    return OpSpec(in_shapes, out_shape, impl)


def _partition_segment(ops: list[Op], devices: Sequence[int],
                       strategy: int,
                       shapes: dict[str, tuple[int, ...]],
                       impl_of: ImplOf | None) -> Segment:
    """Joint class partition of one compute run: devices are equivalent
    iff their per-op specs agree for EVERY op of the run.  Classes are
    ordered by first device appearance in ``devices`` order, so the
    partition *structure* (class sizes, specs) is invariant under device
    renumbering."""
    sigs: dict[int, tuple] = {}
    for dev in devices:
        sig = []
        for op in ops:
            if dev in op_participants(op, strategy):
                sig.append(op_spec(op, dev, strategy, shapes, impl_of))
            else:
                sig.append(None)
        sigs[dev] = tuple(sig)
    classes: list[SegmentClass] = []
    by_sig: dict[tuple, list[int]] = {}
    order: list[tuple] = []
    for dev in devices:
        sig = sigs[dev]
        if sig not in by_sig:
            by_sig[sig] = []
            order.append(sig)
        by_sig[sig].append(dev)
    idle: tuple[int, ...] = ()
    for sig in order:
        members = tuple(by_sig[sig])
        if all(s is None for s in sig):
            idle = members
        else:
            classes.append(SegmentClass(members, sig))
    return Segment(list(ops), classes, idle)


def partition_graph(graph: Graph, strategy: int = 0, *,
                    shapes: dict[str, tuple[int, ...]] | None = None,
                    shape_env: dict[str, int] | None = None,
                    impl_of: ImplOf | None = None,
                    devices: Iterable[int] | None = None,
                    ops: Iterable[Op] | None = None) -> LoweredIR:
    """Compute the specialization-class IR of a deduced graph under one
    strategy.

    ``impl_of(op, dev)`` optionally refines the partition by kernel
    implementation (the attention ref↔Pallas seam): devices whose local
    shard shapes agree but whose kernel dispatch differs land in
    different classes.  ``shapes`` (or ``shape_env`` for symbolic
    graphs) binds tensor shapes; ``devices`` defaults to the union of
    all annotated devices.

    ``ops`` restricts the walk to a subset of ``graph.ops`` (kept in
    graph order by the caller) — the per-stage MPMD lowering partitions
    each (virtual stage, phase) bucket separately, since a whole-graph
    segment may span a stage/phase boundary that has no comm op on it
    (e.g. the last stage's loss: fwd flows into bwd with no comm
    between).
    """
    if shapes is None:
        env = shape_env or {}
        shapes = {name: bind_shape(t.shape, env)
                  for name, t in graph.tensors.items()}
    if devices is None:
        devs: set[int] = set()
        for t in graph.tensors.values():
            if t.annots:
                devs |= set(t.annots[strategy].devices)
        devices = tuple(sorted(devs))
    else:
        devices = tuple(devices)

    entries: list[Segment | CommSlot] = []
    run: list[Op] = []

    def flush():
        if run:
            entries.append(_partition_segment(
                run, devices, strategy, shapes, impl_of))
            run.clear()

    for op in (graph.ops if ops is None else ops):
        if op.kind in ("placeholder", "parameter"):
            continue
        if op.kind == "comm":
            flush()
            entries.append(CommSlot(op))
        else:
            run.append(op)
    flush()
    return LoweredIR(strategy, devices, entries)


def check_against_exec_items(ir: LoweredIR, specialization) -> None:
    """Assert the class partition against progressive specialization's
    per-device ExecItems (the ground truth): two devices share a class
    iff their compute-item sequences over the segment's ops are
    identical.  Raises ``AssertionError`` on any divergence."""
    for seg in ir.segments:
        names = [op.outputs[0].name for op in seg.ops]
        item_sig: dict[int, tuple] = {}
        for dev in ir.devices:
            if dev not in specialization.exec_graphs:
                item_sig[dev] = ()
                continue
            mine = {i.name: i.kind
                    for i in specialization.items(dev)
                    if i.role == "compute"}
            item_sig[dev] = tuple(
                (n, mine[n]) for n in names if n in mine)
        for cls in seg.classes:
            sig0 = item_sig[cls.devices[0]]
            for dev in cls.devices[1:]:
                if item_sig[dev] != sig0:
                    raise AssertionError(
                        f"devices {cls.devices[0]} and {dev} share a "
                        f"class but their ExecItems differ over "
                        f"segment {seg.describe()}")
        for dev in seg.idle_devices:
            if item_sig[dev]:
                raise AssertionError(
                    f"device {dev} is idle in {seg.describe()} but has "
                    f"compute ExecItems {item_sig[dev]}")
