"""Virtual-device shard simulator.

Executes :class:`~repro.core.plan.CommPlan` stages on a plain
``dict[device_id, np.ndarray]`` so the entire hierarchical communication
resolution layer (paper §4) can be validated *numerically* on CPU — for any
number of virtual devices, including the paper's 48-rank cases.

Semantics:

* *Split*/*Duplicate* shards hold the exact sub-box of the global value.
* *Partial* shards hold random summands that add up to the global value
  (random decomposition makes silent drop/double-count bugs visible).
* ``apply_plan`` executes each stage: contributed slice-groups are reduced
  or copied and delivered; any region of a device's next-annotation box not
  covered by a delivery is filled from the device's own previous shard
  (the paper's "local copy" path), and full coverage is asserted.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .annotations import DUP, PARTIAL, HSPMD
from .plan import (Box, CommPlan, box_contains, box_intersect, box_shape,
                   rel_slices)


@dataclasses.dataclass
class ShardedTensor:
    shape: tuple[int, ...]
    annot: HSPMD
    parts: dict[int, np.ndarray]

    @property
    def dtype(self):
        return next(iter(self.parts.values())).dtype


def _decompose(value: np.ndarray, k: int, rng: np.random.Generator) -> list[np.ndarray]:
    """Random summand decomposition: k arrays that sum to ``value``."""
    if k == 1:
        return [value]
    pieces = [rng.normal(size=value.shape).astype(value.dtype) for _ in range(k - 1)]
    pieces.append(value - sum(pieces))
    return pieces


def scatter(value: np.ndarray, annot: HSPMD,
            rng: np.random.Generator | None = None,
            decompose=None) -> ShardedTensor:
    """Shard a global array according to ``annot``.

    ``decompose(value, k, rng) -> [summands]`` overrides the random
    Partial decomposition (e.g. integer summands make reductions
    order-insensitive for differential tests against fast collectives).
    """
    rng = rng or np.random.default_rng(0)
    decompose = decompose or _decompose
    shape = tuple(value.shape)

    # top tier: one slab (or summand) per subgroup
    if annot.hdim == PARTIAL:
        slabs = decompose(value, annot.hsize, rng)
        slab_boxes = [tuple((0, s) for s in shape)] * annot.hsize
    else:
        slabs, slab_boxes = [], []
        for g in range(annot.hsize):
            if annot.hdim >= 0:
                lo, hi = annot._hdim_bounds(shape[annot.hdim])[g]
                idx = tuple(slice(lo, hi) if d == annot.hdim else slice(None)
                            for d in range(len(shape)))
                box = tuple((lo, hi) if d == annot.hdim else (0, s)
                            for d, s in enumerate(shape))
            else:
                idx = tuple(slice(None) for _ in shape)
                box = tuple((0, s) for s in shape)
            slabs.append(value[idx])
            slab_boxes.append(box)

    parts: dict[int, np.ndarray] = {}
    for g, (dg, ds) in enumerate(zip(annot.dgs, annot.dss)):
        slab = slabs[g]
        kp = ds.get(PARTIAL)
        summands = decompose(slab, kp, rng)
        for pos, dev in enumerate(dg):
            c = ds.coords(pos)
            piece = summands[c.get(PARTIAL, 0)]
            box = ds.local_box(pos, slab.shape)
            parts[dev] = piece[tuple(slice(lo, hi) for lo, hi in box)].copy()
    return ShardedTensor(shape, annot, parts)


def gather(st: ShardedTensor, check_dups: bool = True,
           atol: float = 1e-6) -> np.ndarray:
    """Reconstruct the global array; asserts duplicate copies agree."""
    annot, shape = st.annot, st.shape
    slabs = []
    for g, (dg, ds) in enumerate(zip(annot.dgs, annot.dss)):
        slab_shape = annot.subgroup_shape(g, shape)
        kp = ds.get(PARTIAL)
        acc = np.zeros(slab_shape, dtype=np.float64)
        seen: dict[tuple, np.ndarray] = {}
        for pos, dev in enumerate(dg):
            c = ds.coords(pos)
            box = ds.local_box(pos, slab_shape)
            key = (box, c.get(PARTIAL, 0))
            arr = st.parts[dev]
            if key in seen:
                if check_dups:
                    np.testing.assert_allclose(arr, seen[key], atol=atol,
                                               err_msg=f"dup mismatch dev {dev}")
                continue
            seen[key] = arr
            acc[tuple(slice(lo, hi) for lo, hi in box)] += arr
        slabs.append(acc)

    if annot.hdim == PARTIAL:
        return sum(slabs)
    if annot.hdim == DUP:
        if check_dups:
            for s in slabs[1:]:
                np.testing.assert_allclose(s, slabs[0], atol=atol,
                                           err_msg="subgroup replica mismatch")
        return slabs[0]
    # hdim split: concatenate slabs in subgroup order
    return np.concatenate(slabs, axis=annot.hdim)


def apply_plan(st: ShardedTensor, plan: CommPlan,
               strict: bool = True) -> ShardedTensor:
    """Execute a communication plan stage by stage."""
    shape = st.shape
    state = dict(st.parts)
    annot = st.annot
    for stage in plan.stages:
        next_annot = stage.annot_after
        delivered: dict[int, list[tuple[Box, np.ndarray]]] = {}
        for step in stage.steps:
            for g in step.groups:
                contribs = []
                for s in g.srcs:
                    sbox = annot.device_box(s, shape)
                    if not box_contains(sbox, g.box):
                        raise AssertionError(
                            f"src dev {s} box {sbox} does not contain group box {g.box}")
                    contribs.append(state[s][rel_slices(sbox, g.box)])
                piece = sum(np.asarray(c, dtype=np.float64) for c in contribs) \
                    if g.reduce else contribs[0]
                for d in g.dsts:
                    delivered.setdefault(d, []).append((g.box, np.asarray(piece)))

        new_state: dict[int, np.ndarray] = {}
        for dev in next_annot.devices:
            box = next_annot.device_box(dev, shape)
            arr = np.zeros(box_shape(box), dtype=st.dtype)
            covered = np.zeros(box_shape(box), dtype=bool)
            # local retention first (identity / local-copy path) ...
            if dev in annot.devices:
                pbox = annot.device_box(dev, shape)
                inter = box_intersect(pbox, box)
                if inter is not None:
                    arr[rel_slices(box, inter)] = state[dev][rel_slices(pbox, inter)]
                    covered[rel_slices(box, inter)] = True
            # ... then deliveries override
            for dbox, piece in delivered.get(dev, ()):
                inter = box_intersect(dbox, box)
                if inter is None:
                    continue
                arr[rel_slices(box, inter)] = piece[rel_slices(dbox, inter)]
                covered[rel_slices(box, inter)] = True
            if strict and not covered.all():
                kinds = "+".join(st_.kind for st_ in stage.steps)
                raise AssertionError(
                    f"dev {dev}: {int((~covered).sum())} uncovered elements "
                    f"after stage [{kinds}]")
            new_state[dev] = arr.astype(st.dtype)
        state, annot = new_state, next_annot
    return ShardedTensor(shape, annot, state)


def roundtrip_check(value: np.ndarray, src: HSPMD, dst: HSPMD, plan: CommPlan,
                    rng: np.random.Generator | None = None,
                    atol: float = 1e-5) -> None:
    """scatter by src -> apply plan -> gather must reproduce ``value``
    under the dst annotation (the canonical property test)."""
    st = scatter(value, src, rng=rng)
    out = apply_plan(st, plan)
    assert out.annot is plan.annots[-1] or out.annot == plan.annots[-1]
    # every device must hold exactly its dst shard
    recon = gather(out, atol=atol)
    np.testing.assert_allclose(recon, value, atol=atol)
    for dev in dst.devices:
        box = dst.device_box(dev, value.shape)
        want = value[tuple(slice(lo, hi) for lo, hi in box)]
        deg = dst.partial_degree(dev)
        if deg == 1:
            np.testing.assert_allclose(out.parts[dev], want, atol=atol,
                                       err_msg=f"dev {dev} shard mismatch")
