"""HSPMD sharding annotations (paper §3).

Two-tier annotation structure:

* Bottom tier — classical SPMD ``DS`` (*Distributed States*): an ordered
  mapping ``dim -> #shards`` over a ``DG`` (*Device Group*, an ordered list
  of device ids).  Dim semantics follow the paper:

    - ``d >= 0``  — *Split*: tensor split uniformly along physical dim d,
    - ``d == DUP (-1)`` — *Duplicate*: full replica,
    - ``d == PARTIAL (-2)`` — *Partial*: device holds a summand.

* Top tier — ``HSPMD``: a union of ``HSize`` (DG, DS) pairs ("sharding
  subgroups"), related along a heterogeneous dimension ``HDim``:

    - ``hdim >= 0`` — tensor split along that dim *across* subgroups
      (optionally non-uniformly via ``hsplits``),
    - ``hdim == DUP`` — replicated across subgroups,
    - ``hdim == PARTIAL`` — subgroups hold summands (appears only as a
      deduction intermediate, e.g. contraction split across subgroups).

Device -> shard mapping: a device's position ``p`` in its DG is decomposed
row-major over the DS entries *in order* (first entry is the slowest-varying
coordinate), mirroring the paper's ordered-dict semantics.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

DUP = -1
PARTIAL = -2


def _norm_entries(entries: Iterable[tuple[int, int]]) -> tuple[tuple[int, int], ...]:
    out = []
    seen = set()
    for d, n in entries:
        d = int(d)
        n = int(n)
        if d < PARTIAL:
            raise ValueError(f"invalid dim {d}")
        if n <= 0:
            raise ValueError(f"invalid shard count {n} for dim {d}")
        if n == 1:
            continue  # trivial; canonical form omits it
        if d in seen:
            # duplicate DUP/PARTIAL entries are just as inconsistent as
            # duplicate splits: get() would see only the first while
            # num_devices multiplies both, silently corrupting the
            # device -> shard decomposition
            name = {DUP: "Duplicate", PARTIAL: "Partial"}.get(d, f"dim {d}")
            raise ValueError(f"{name} annotated twice in DS entries")
        seen.add(d)
        out.append((d, n))
    return tuple(out)


@dataclass(frozen=True)
class DS:
    """Bottom-tier distributed states: ordered (dim, nshards) entries."""

    entries: tuple[tuple[int, int], ...] = ()

    def __init__(self, entries: Iterable[tuple[int, int]] | Mapping[int, int] = ()):
        if isinstance(entries, Mapping):
            entries = entries.items()
        object.__setattr__(self, "entries", _norm_entries(entries))

    # -- basic queries ----------------------------------------------------
    @property
    def num_devices(self) -> int:
        return math.prod(n for _, n in self.entries) if self.entries else 1

    def get(self, dim: int) -> int:
        for d, n in self.entries:
            if d == dim:
                return n
        return 1

    @property
    def split_dims(self) -> tuple[int, ...]:
        return tuple(d for d, _ in self.entries if d >= 0)

    @property
    def has_partial(self) -> bool:
        return self.get(PARTIAL) > 1

    def same_sharding(self, other: "DS") -> bool:
        """True if the dim->n maps agree (ignoring entry order)."""
        return dict(self.entries) == dict(other.entries)

    # -- device coordinate decomposition ----------------------------------
    def coords(self, pos: int) -> dict[int, int]:
        """Decompose device position (row-major over entries) into a
        per-dim shard coordinate map."""
        if not (0 <= pos < self.num_devices):
            raise ValueError(f"device position {pos} out of range")
        out: dict[int, int] = {}
        rem = pos
        for d, n in reversed(self.entries):
            out[d] = rem % n
            rem //= n
        return out

    def positions_varying(self, dim: int) -> list[list[int]]:
        """Group device positions into lists that differ only in ``dim``'s
        coordinate (i.e. the communication groups for a collective over
        ``dim``), each ordered by that coordinate."""
        groups: dict[tuple, list[int]] = {}
        for p in range(self.num_devices):
            c = self.coords(p)
            key = tuple(sorted((d, i) for d, i in c.items() if d != dim))
            groups.setdefault(key, []).append(p)
        res = []
        for key, ps in sorted(groups.items()):
            ps.sort(key=lambda p: self.coords(p).get(dim, 0))
            res.append(ps)
        return res

    # -- shard geometry ----------------------------------------------------
    def local_box(self, pos: int, shape: Sequence[int]) -> tuple[tuple[int, int], ...]:
        """Global-coordinate box (start, stop) per tensor dim held by the
        device at ``pos`` (Partial/Dup do not affect geometry)."""
        c = self.coords(pos)
        box = []
        for dim, size in enumerate(shape):
            n = self.get(dim)
            if size % n != 0:
                raise ValueError(f"dim {dim} of size {size} not divisible by {n}")
            step = size // n
            i = c.get(dim, 0)
            box.append((i * step, (i + 1) * step))
        return tuple(box)

    def local_shape(self, shape: Sequence[int]) -> tuple[int, ...]:
        return tuple(s // self.get(d) for d, s in enumerate(shape))

    def replace(self, **dim_to_n: int) -> "DS":
        """Functional update by dim (kw form: use d0=, dm1=, dm2= helpers)."""
        raise NotImplementedError("use DS(dict) construction instead")

    def with_dim(self, dim: int, n: int) -> "DS":
        m = dict(self.entries)
        if n == 1:
            m.pop(dim, None)
        else:
            m[dim] = n
        # preserve original entry order where possible; new dims appended
        order = [d for d, _ in self.entries if d in m]
        order += [d for d in m if d not in order]
        return DS([(d, m[d]) for d in order])

    def __repr__(self) -> str:
        if not self.entries:
            return "DS{}"
        parts = []
        for d, n in self.entries:
            name = {DUP: "dup", PARTIAL: "partial"}.get(d, f"s{d}")
            parts.append(f"{name}:{n}")
        return "DS{" + ",".join(parts) + "}"


@dataclass(frozen=True)
class DG:
    """Ordered device group."""

    devices: tuple[int, ...]

    def __init__(self, devices: Iterable[int]):
        devs = tuple(int(d) for d in devices)
        if len(set(devs)) != len(devs):
            raise ValueError("duplicate devices in DG")
        object.__setattr__(self, "devices", devs)

    def __len__(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __getitem__(self, i: int) -> int:
        return self.devices[i]

    def index(self, dev: int) -> int:
        return self.devices.index(dev)

    def __repr__(self) -> str:
        return f"DG{list(self.devices)}"


@dataclass(frozen=True)
class HSPMD:
    """Top-tier annotation: DG Union + DS Union + (HDim, optional HSplits)."""

    dgs: tuple[DG, ...]
    dss: tuple[DS, ...]
    hdim: int = DUP
    hsplits: tuple[int, ...] | None = None  # non-uniform split numerators

    def __init__(
        self,
        dgs: Sequence[DG | Sequence[int]],
        dss: Sequence[DS | Mapping[int, int]],
        hdim: int = DUP,
        hsplits: Sequence[int] | None = None,
    ):
        dgs = tuple(dg if isinstance(dg, DG) else DG(dg) for dg in dgs)
        dss = tuple(ds if isinstance(ds, DS) else DS(ds) for ds in dss)
        if len(dgs) != len(dss):
            raise ValueError("DG Union and DS Union must have equal HSize")
        if not dgs:
            raise ValueError("empty union")
        seen: set[int] = set()
        for dg, ds in zip(dgs, dss):
            if len(dg) != ds.num_devices:
                raise ValueError(
                    f"DG size {len(dg)} != DS device count {ds.num_devices}")
            if seen & set(dg.devices):
                raise ValueError("sharding subgroups must be disjoint")
            seen |= set(dg.devices)
        hdim = int(hdim)
        if hdim < PARTIAL:
            raise ValueError(f"invalid hdim {hdim}")
        if len(dgs) == 1 and hsplits is None:
            hdim = DUP  # top tier is trivial for a single subgroup
        if hsplits is not None:
            hsplits = tuple(int(x) for x in hsplits)
            if len(hsplits) != len(dgs):
                raise ValueError("hsplits length must equal HSize")
            if hdim < 0:
                raise ValueError("hsplits requires a split hdim >= 0")
        object.__setattr__(self, "dgs", dgs)
        object.__setattr__(self, "dss", dss)
        object.__setattr__(self, "hdim", hdim)
        object.__setattr__(self, "hsplits", hsplits)

    # -- queries -----------------------------------------------------------
    @property
    def hsize(self) -> int:
        return len(self.dgs)

    @property
    def devices(self) -> tuple[int, ...]:
        return tuple(d for dg in self.dgs for d in dg)

    @property
    def has_partial(self) -> bool:
        return self.hdim == PARTIAL or any(ds.has_partial for ds in self.dss)

    def subgroup_of(self, dev: int) -> int:
        for i, dg in enumerate(self.dgs):
            if dev in dg.devices:
                return i
        raise KeyError(dev)

    def same_dg_union(self, other: "HSPMD") -> bool:
        return self.hsize == other.hsize and all(
            a.devices == b.devices for a, b in zip(self.dgs, other.dgs))

    def same_ds_union(self, other: "HSPMD") -> bool:
        return self.hsize == other.hsize and all(
            a.same_sharding(b) for a, b in zip(self.dss, other.dss))

    # -- geometry ----------------------------------------------------------
    def _hdim_bounds(self, size: int) -> list[tuple[int, int]]:
        """Start/stop of every subgroup's slab along hdim."""
        if self.hdim < 0:
            return [(0, size)] * self.hsize
        if self.hsplits is not None:
            tot = sum(self.hsplits)
            if size % tot != 0:
                raise ValueError(f"hdim size {size} not divisible by hsplits sum {tot}")
            unit = size // tot
            bounds, acc = [], 0
            for w in self.hsplits:
                bounds.append((acc * unit, (acc + w) * unit))
                acc += w
            return bounds
        if size % self.hsize != 0:
            raise ValueError(f"hdim size {size} not divisible by HSize {self.hsize}")
        step = size // self.hsize
        return [(i * step, (i + 1) * step) for i in range(self.hsize)]

    def subgroup_shape(self, g: int, shape: Sequence[int]) -> tuple[int, ...]:
        """The slab-of-global shape that subgroup ``g`` shards internally."""
        shape = list(shape)
        if self.hdim >= 0:
            lo, hi = self._hdim_bounds(shape[self.hdim])[g]
            shape[self.hdim] = hi - lo
        return tuple(shape)

    def device_box(self, dev: int, shape: Sequence[int]) -> tuple[tuple[int, int], ...]:
        """Global box held by ``dev`` (Partial treated geometrically as the
        full covered box; summand semantics live in the simulator)."""
        g = self.subgroup_of(dev)
        sub_shape = self.subgroup_shape(g, shape)
        pos = self.dgs[g].index(dev)
        box = list(self.dss[g].local_box(pos, sub_shape))
        if self.hdim >= 0:
            lo, _ = self._hdim_bounds(shape[self.hdim])[g]
            b = box[self.hdim]
            box[self.hdim] = (b[0] + lo, b[1] + lo)
        return tuple(box)

    def device_shape(self, dev: int, shape: Sequence[int]) -> tuple[int, ...]:
        return tuple(hi - lo for lo, hi in self.device_box(dev, shape))

    def partial_degree(self, dev: int) -> int:
        """Number of summands that must be reduced to realize the value of
        this device's box (bottom partial x top partial)."""
        g = self.subgroup_of(dev)
        deg = self.dss[g].get(PARTIAL)
        if self.hdim == PARTIAL:
            deg *= self.hsize
        return deg

    def __repr__(self) -> str:
        hname = {DUP: "dup", PARTIAL: "partial"}.get(self.hdim, f"s{self.hdim}")
        body = ", ".join(f"{dg}:{ds}" for dg, ds in zip(self.dgs, self.dss))
        extra = f", hsplits={list(self.hsplits)}" if self.hsplits else ""
        return f"HSPMD[hdim={hname}{extra} | {body}]"


# ---------------------------------------------------------------------------
# convenience constructors
# ---------------------------------------------------------------------------

def spmd(devices: Sequence[int], ds: DS | Mapping[int, int]) -> HSPMD:
    """Classical single-group SPMD annotation (HSize == 1)."""
    return HSPMD([DG(devices)], [ds if isinstance(ds, DS) else DS(ds)])


def replicated(devices: Sequence[int]) -> HSPMD:
    return spmd(devices, DS({DUP: len(devices)}))
