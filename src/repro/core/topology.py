"""Cluster topology / bandwidth models for BSR planning (paper §4.3).

The paper's BSR heuristic 2 prefers the highest-bandwidth link between an
owner and a receiver; heuristic 3 balances cumulative send load.  Both need
a topology oracle.  We provide:

* :class:`NvlinkIbTopology` — the paper's own cluster shape (Appendix A.1):
  nodes of ``gpus_per_node`` GPUs joined by NVLink, nodes joined by
  InfiniBand.  Used to reproduce Table 2 / Fig 18.

* :class:`TpuTorusTopology` — the TPU-native adaptation: a 2D ICI torus per
  pod (wraparound links, ~50 GB/s per link) with DCN across pods.  Distance
  is ICI hop count; bandwidth decays with hops (store-and-forward shares
  links), and cross-pod traffic rides the much slower DCN.

* :class:`UniformTopology` — equal bandwidth everywhere (degenerate case;
  makes heuristic 2 a no-op so heuristic 3 dominates — used in tests).
"""

from __future__ import annotations

from dataclasses import dataclass, field


class Topology:
    def bandwidth(self, src: int, dst: int) -> float:  # GB/s
        raise NotImplementedError

    def time_for(self, src: int, dst: int, nbytes: int) -> float:
        if src == dst:
            return 0.0
        return nbytes / (self.bandwidth(src, dst) * 1e9)


@dataclass(frozen=True)
class UniformTopology(Topology):
    gbps: float = 100.0

    def bandwidth(self, src: int, dst: int) -> float:
        return self.gbps


@dataclass(frozen=True)
class NvlinkIbTopology(Topology):
    """Paper Appendix A.1-style cluster: NVLink within a node, IB across."""

    gpus_per_node: int = 8
    nvlink_gbps: float = 400.0  # H800 NVLink from Table 3
    ib_gbps: float = 25.0       # typical 200 Gb/s HCA per GPU
    # optional per-node NVLink override (e.g. H20 nodes have 900 GB/s)
    node_nvlink_gbps: dict[int, float] = field(default_factory=dict)

    def node_of(self, dev: int) -> int:
        return dev // self.gpus_per_node

    def bandwidth(self, src: int, dst: int) -> float:
        if self.node_of(src) == self.node_of(dst):
            return self.node_nvlink_gbps.get(self.node_of(src), self.nvlink_gbps)
        return self.ib_gbps


@dataclass(frozen=True)
class TpuTorusTopology(Topology):
    """TPU pod: chips on an X x Y torus (per pod), pods joined by DCN.

    ``bandwidth(src, dst)`` models effective point-to-point throughput as
    link_gbps / hops (a message consumes every link on its minimal path),
    which preserves the *ordering* the BSR heuristics need: neighbors beat
    far chips beat cross-pod.
    """

    torus_x: int = 16
    torus_y: int = 16
    link_gbps: float = 50.0   # per ICI link
    dcn_gbps: float = 6.25    # per-chip share of cross-pod DCN

    @property
    def chips_per_pod(self) -> int:
        return self.torus_x * self.torus_y

    def pod_of(self, dev: int) -> int:
        return dev // self.chips_per_pod

    def coords(self, dev: int) -> tuple[int, int]:
        local = dev % self.chips_per_pod
        return local // self.torus_y, local % self.torus_y

    def hops(self, src: int, dst: int) -> int:
        (x0, y0), (x1, y1) = self.coords(src), self.coords(dst)
        dx = abs(x0 - x1)
        dy = abs(y0 - y1)
        return min(dx, self.torus_x - dx) + min(dy, self.torus_y - dy)

    def bandwidth(self, src: int, dst: int) -> float:
        if self.pod_of(src) != self.pod_of(dst):
            return self.dcn_gbps
        h = self.hops(src, dst)
        return self.link_gbps if h <= 1 else self.link_gbps / h
