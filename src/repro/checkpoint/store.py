"""Sharding-agnostic checkpointing.

Saves the parameter/optimizer pytree as flat full arrays (npz) plus a JSON
manifest; restore re-shards onto whatever mesh/strategy is active — so a
checkpoint written under one parallel strategy loads under any other (the
checkpoint-and-restart baseline of the paper's elastic scenario, §7.2).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def jnp_asarray(a, skeleton_leaf):
    want = getattr(skeleton_leaf, "dtype", None)
    if want is not None and str(want) != str(getattr(a, "dtype", "")):
        return jnp.asarray(a, dtype=want)
    return a


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any], skeleton):
    def rec(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, list):
            return [rec(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        if isinstance(tree, tuple):
            return tuple(rec(v, f"{prefix}{i}/") for i, v in enumerate(tree))
        return flat[prefix[:-1]]
    return rec(skeleton)


def save(path: str, tree, step: int = 0, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":   # npz cannot store ml_dtypes
            a = a.astype(np.float32)
        arrays[k] = a
    np.savez(os.path.join(path, "arrays.npz"),
             **{k.replace("/", "|"): v for k, v in arrays.items()})
    manifest = {
        "step": step,
        "meta": meta or {},
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in arrays.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, skeleton, shardings=None):
    """Restore into the structure of ``skeleton``; if ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, arrays are placed
    sharded — re-sharding is free at load time."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {k.replace("|", "/"): data[k] for k in data.files}
    tree = _unflatten(flat, skeleton)
    # restore original dtypes (bf16 was widened for npz)
    tree = jax.tree.map(
        lambda a, sk: jnp_asarray(a, sk), tree, skeleton)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]
