"""Sharding-agnostic checkpointing.

Saves the parameter/optimizer pytree as flat full arrays (npz) plus a JSON
manifest; restore re-shards onto whatever mesh/strategy is active — so a
checkpoint written under one parallel strategy loads under any other (the
checkpoint-and-restart baseline of the paper's elastic scenario, §7.2).

Durability contract (the elastic driver's fault injector leans on it):

* :func:`save` is **atomic at the directory level** — arrays + manifest
  are staged into a hidden temp directory next to ``path`` and renamed
  into place, so a fault at ANY point mid-save leaves either the old
  complete checkpoint or no checkpoint, never a half-written one.
* :func:`restore` **validates before it deserializes** — a missing /
  corrupted ``arrays.npz``, a manifest↔npz key drift, or a skeleton that
  does not match the stored keys all raise a structured
  :class:`CheckpointError` instead of a deep ``KeyError`` or silently
  restoring garbage.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint is missing, incomplete, corrupted, or does not match
    the skeleton it is being restored into."""


def jnp_asarray(a, skeleton_leaf):
    want = getattr(skeleton_leaf, "dtype", None)
    if want is not None and str(want) != str(getattr(a, "dtype", "")):
        return jnp.asarray(a, dtype=want)
    return a


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict[str, Any], skeleton):
    def rec(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, list):
            return [rec(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
        if isinstance(tree, tuple):
            return tuple(rec(v, f"{prefix}{i}/") for i, v in enumerate(tree))
        return flat[prefix[:-1]]
    return rec(skeleton)


def save(path: str, tree, step: int = 0, meta: dict | None = None) -> None:
    """Write ``tree`` under ``path`` atomically: stage into a temp dir in
    the same parent, then rename into place (replacing any previous
    checkpoint at ``path`` only after the new one is complete)."""
    path = os.path.abspath(path)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":   # npz cannot store ml_dtypes
            a = a.astype(np.float32)
        arrays[k] = a
    manifest = {
        "step": step,
        "meta": meta or {},
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in arrays.items()},
    }
    tmp = tempfile.mkdtemp(dir=parent, prefix=".ck-tmp-")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "|"): v for k, v in arrays.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.lexists(path):
            old = tempfile.mkdtemp(dir=parent, prefix=".ck-old-")
            # two renames: the previous checkpoint stays complete (just
            # relocated) until the new one is in place
            os.rename(path, os.path.join(old, "ck"))
            os.rename(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def peek(path: str) -> dict:
    """Load and return just the manifest (step, meta, keys) — validates
    that ``path`` holds a complete, parseable checkpoint header."""
    mf = os.path.join(path, "manifest.json")
    if not os.path.isfile(mf):
        raise CheckpointError(
            f"no manifest.json under {path!r} — not a checkpoint "
            f"(or an interrupted save)")
    try:
        with open(mf) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(
            f"unreadable manifest.json under {path!r}: {e}") from e
    if not isinstance(manifest, dict) or "keys" not in manifest:
        raise CheckpointError(
            f"malformed manifest under {path!r}: missing 'keys'")
    return manifest


def _load_arrays(path: str, manifest: dict) -> dict[str, np.ndarray]:
    npz = os.path.join(path, "arrays.npz")
    if not os.path.isfile(npz):
        raise CheckpointError(
            f"no arrays.npz under {path!r} — incomplete checkpoint")
    try:
        with np.load(npz) as data:
            # force every member through the zip CRC so truncation /
            # corruption surfaces here, not as garbage values later
            flat = {k.replace("|", "/"): np.asarray(data[k])
                    for k in data.files}
    except CheckpointError:
        raise
    except Exception as e:  # BadZipFile, zlib error, pickle refusals, ...
        raise CheckpointError(
            f"corrupted arrays.npz under {path!r}: {e}") from e
    mkeys = set(manifest["keys"])
    if set(flat) != mkeys:
        missing = sorted(mkeys - set(flat))
        extra = sorted(set(flat) - mkeys)
        raise CheckpointError(
            f"manifest/arrays key drift under {path!r}: "
            f"missing from npz {missing}, not in manifest {extra}")
    for k, info in manifest["keys"].items():
        if list(flat[k].shape) != list(info["shape"]):
            raise CheckpointError(
                f"checkpoint {path!r} key {k!r}: stored shape "
                f"{list(flat[k].shape)} != manifest shape {info['shape']}")
    return flat


def restore(path: str, skeleton, shardings=None):
    """Restore into the structure of ``skeleton``; if ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, arrays are placed
    sharded — re-sharding is free at load time.

    Raises :class:`CheckpointError` (never a bare ``KeyError``) when the
    checkpoint is incomplete/corrupted or its keys do not match the
    skeleton's structure."""
    manifest = peek(path)
    flat = _load_arrays(path, manifest)
    skel_keys = set(_flatten(skeleton))
    if skel_keys != set(flat):
        missing = sorted(skel_keys - set(flat))
        extra = sorted(set(flat) - skel_keys)
        raise CheckpointError(
            f"checkpoint {path!r} does not match the restore skeleton: "
            f"skeleton keys absent from checkpoint {missing}, "
            f"checkpoint keys absent from skeleton {extra}")
    tree = _unflatten(flat, skeleton)
    # restore original dtypes (bf16 was widened for npz)
    tree = jax.tree.map(
        lambda a, sk: jnp_asarray(a, sk), tree, skeleton)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]
