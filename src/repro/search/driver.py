"""The search driver: enumerate -> prune -> rank -> (optionally)
execution-validate, behind one restart-free entry point.

A :class:`Searcher` holds ONLY model-and-grid configuration — never
cluster state — so the elastic driver (ROADMAP item 3) can call
``searcher.search(new_cluster)`` after every topology change without
rebuilding anything; the measured fwd-fraction proxy is memoized at
module level (it is a property of the op mix, not the cluster).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.costmodel import ClusterSpec, ModelSpec

from .prune import PruneReport, SearchError, prune
from .rank import RankedCandidate, rank, resolve_fwd_fraction
from .space import Candidate, enumerate_candidates
from .validate import ValidationReport, validate


@dataclass
class SearchResult:
    ranked: list[RankedCandidate]
    prune_report: PruneReport
    validation: ValidationReport | None = None

    @property
    def best(self) -> RankedCandidate:
        return self.ranked[0]

    def summary(self) -> str:
        lines = [self.prune_report.summary()]
        lines += ["  " + rc.describe() for rc in self.ranked[:5]]
        if len(self.ranked) > 5:
            lines.append(f"  ... {len(self.ranked) - 5} more")
        if self.validation is not None:
            lines.append(self.validation.summary())
        return "\n".join(lines)


@dataclass
class Selection:
    """Outcome of one mid-run re-selection (:meth:`Searcher.
    select_candidate`): the winning cost-model strategy, its predicted
    step time, and where it came from — a searched candidate, or one of
    the caller's pre-built ``extras``."""

    strategy: object                 # repro.core.costmodel.Strategy
    predicted_step_s: float
    candidate: Candidate | None = None   # set when a searched one won
    extra_index: int | None = None       # set when an extras entry won
    searched: int = 0                    # ranked candidates considered

    @property
    def source(self) -> str:
        return "search" if self.candidate is not None else "extra"


@dataclass
class Searcher:
    """Reusable search configuration for one model.

    ``search(cluster)`` may be called with a DIFFERENT ``ClusterSpec``
    every time (elastic topology changes): nothing cluster-specific is
    cached on the instance.
    """

    model: ModelSpec
    global_batch: int
    seq_len: int = 4096
    tp_options: tuple = (1, 2, 4, 8)
    pp_options: tuple = (1, 2, 4, 8)
    virtual_options: tuple = (1, 2)
    micro_bs_options: tuple = (1,)
    pipeline_options: tuple = (1, 2, 4)
    include_uniform: bool = True
    include_hetero: bool = True
    fwd_fraction: float | str | None = "measured"
    mem_fraction: float = 0.85

    def candidates(self, cluster: ClusterSpec,
                   ranks: list[int] | None = None) -> list[Candidate]:
        return enumerate_candidates(
            cluster, self.model, ranks, global_batch=self.global_batch,
            tp_options=self.tp_options, pp_options=self.pp_options,
            virtual_options=self.virtual_options,
            micro_bs_options=self.micro_bs_options,
            pipeline_options=self.pipeline_options,
            include_uniform=self.include_uniform,
            include_hetero=self.include_hetero)

    def search(self, cluster: ClusterSpec,
               ranks: list[int] | None = None, *,
               validate_top: int = 0, executors=("sim",), mesh=None,
               repeats: int = 3, what: str = "strategy",
               **validate_kw) -> SearchResult:
        """Enumerate + prune + rank; with ``validate_top=k > 0`` also
        execute the top-k (``validate.validate``).  Raises
        :class:`SearchError` when every candidate is pruned."""
        report = prune(cluster, self.model, self.candidates(cluster,
                                                            ranks),
                       mem_fraction=self.mem_fraction)
        if not report.survivors:
            raise SearchError(report, what)
        ranked = rank(cluster, self.model, report.survivors,
                      self.seq_len, fwd_fraction=self.fwd_fraction)
        validation = None
        if validate_top > 0:
            validation = validate(cluster, ranked, top_k=validate_top,
                                  executors=executors, mesh=mesh,
                                  repeats=repeats, **validate_kw)
        return SearchResult(ranked, report, validation)

    def select_candidate(self, cluster: ClusterSpec,
                         ranks: list[int] | None = None, *,
                         extras=()) -> Selection:
        """Best cost-model :class:`Strategy` among the searched
        candidates AND any ``extras`` (pre-built strategies, e.g. the
        elastic scenario's hand-written fixture) — the mid-run
        re-selection hook, with provenance (what won and why) for the
        elastic trace driver's transition records."""
        from repro.core.costmodel import step_time

        frac = resolve_fwd_fraction(self.fwd_fraction)
        sel: Selection | None = None
        searched = 0
        try:
            result = self.search(cluster, ranks)
            searched = len(result.ranked)
            sel = Selection(result.best.candidate.strategy,
                            result.best.predicted_step_s,
                            candidate=result.best.candidate,
                            searched=searched)
        except SearchError:
            pass
        for i, strat in enumerate(extras):
            t = step_time(cluster, self.model, strat, self.seq_len,
                          fwd_fraction=frac)
            if sel is None or t < sel.predicted_step_s:
                sel = Selection(strat, t, extra_index=i,
                                searched=searched)
        if sel is None:
            raise RuntimeError("select(): no searched candidate and no "
                               "feasible extras")
        return sel

    def select(self, cluster: ClusterSpec,
               ranks: list[int] | None = None, *,
               extras=()) -> "object":
        """:meth:`select_candidate` without the provenance — just the
        winning cost-model strategy."""
        return self.select_candidate(cluster, ranks, extras=extras).strategy


def search(cluster: ClusterSpec, model: ModelSpec, *,
           global_batch: int, seq_len: int = 4096,
           validate_top: int = 0, executors=("sim",), mesh=None,
           **searcher_kw) -> SearchResult:
    """One-shot convenience: ``search.driver.search(cluster, model,
    global_batch=..., validate_top=3)``."""
    extra_validate = {}
    for key in ("repeats", "batch", "n_pairs", "d", "f", "max_micro",
                "speed_project", "seed"):
        if key in searcher_kw:
            extra_validate[key] = searcher_kw.pop(key)
    searcher = Searcher(model, global_batch=global_batch,
                        seq_len=seq_len, **searcher_kw)
    return searcher.search(cluster, validate_top=validate_top,
                           executors=executors, mesh=mesh,
                           **extra_validate)
