"""Rank surviving candidates with the priced pipeline cost model.

Each survivor is scored by ``costmodel.step_time`` — the slowest
pipeline's priced timetable (``pipeline_time`` builds the actual
1F1B/interleaved tick table and re-times it) plus cross-pipeline grad
sync.  The fwd/bwd tick split defaults to a MEASURED fraction
(``fwd_fraction="measured"``): a tiny differentiated proxy program is
compiled once and its :meth:`CompiledPlan.fwd_fraction` replaces the
analytic 1/3 assumption, module-memoized so ranking stays cheap.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.costmodel import (ClusterSpec, ModelSpec, dp_sync_time,
                                  pipeline_time)

from .space import Candidate

# measured fwd fraction of the differentiated proxy, computed once per
# process (the ratio is a property of the op mix, not of the cluster)
_PROXY_FRACTION: list[float] = []


def proxy_fwd_fraction() -> float:
    """The fwd share of a differentiated relu-MLP step, measured from a
    single-device ``compile_train`` proxy plan (memoized)."""
    if not _PROXY_FRACTION:
        from repro import api
        g = api.Graph()
        g.placeholder("X", (4, 8))
        g.parameter("W", (8, 8))
        y = g.relu(g.dot(g.tensors["X"], g.tensors["W"], name="H"),
                   name="Y")
        g.sum(g.sum(y, 1, name="L1"), 0, name="L")
        strat = api.Strategy("proxy", {
            "X": api.spmd([0], api.DS({})),
            "W": api.spmd([0], api.DS({})),
        })
        plan = api.Program(g, [strat]).compile_train("proxy")
        _PROXY_FRACTION.append(plan.fwd_fraction())
    return _PROXY_FRACTION[0]


def resolve_fwd_fraction(spec: float | str | None) -> float | None:
    """``None`` -> analytic 1/3; ``"measured"`` -> proxy-measured;
    a float passes through."""
    if spec is None:
        return None
    if spec == "measured":
        return proxy_fwd_fraction()
    return float(spec)


@dataclass(frozen=True)
class RankedCandidate:
    candidate: Candidate
    predicted_step_s: float
    pipeline_s: float
    sync_s: float
    fwd_fraction: float | None      # None = analytic split

    @property
    def name(self) -> str:
        return self.candidate.name

    def describe(self) -> str:
        return (f"{self.name}: {self.predicted_step_s * 1e3:.3f} ms "
                f"(pipeline {self.pipeline_s * 1e3:.3f} + "
                f"sync {self.sync_s * 1e3:.3f})")


def predict_step_time(cluster: ClusterSpec, model: ModelSpec,
                      cand: Candidate, seq_len: int, *,
                      fwd_fraction: float | None = None,
                      overlap: bool = False) -> RankedCandidate:
    strat = cand.strategy
    assert strat is not None, f"cannot price rejected {cand.name}"
    kind = "interleaved" if cand.v > 1 else cand.schedule
    t_pipe = max(pipeline_time(
        cluster, model, p, seq_len, kind=kind,
        virtual_stages_per_device=cand.v, fwd_fraction=fwd_fraction,
        overlap=overlap)
        for p in strat.pipelines)
    t_sync = dp_sync_time(cluster, model, strat)
    return RankedCandidate(cand, t_pipe + t_sync, t_pipe, t_sync,
                           fwd_fraction)


def rank(cluster: ClusterSpec, model: ModelSpec,
         candidates: list[Candidate] | tuple[Candidate, ...],
         seq_len: int, *,
         fwd_fraction: float | str | None = "measured",
         overlap: bool = False) -> list[RankedCandidate]:
    """Survivors sorted fastest-first (name breaks exact ties, keeping
    the order deterministic).  ``overlap=True`` scores candidates for
    the async executor: boundary transfers are priced ``max(compute,
    comm)`` per tick instead of serialized after compute — pipelines
    whose boundaries the async runtime can hide rank accordingly."""
    frac = resolve_fwd_fraction(fwd_fraction)
    ranked = [predict_step_time(cluster, model, c, seq_len,
                                fwd_fraction=frac, overlap=overlap)
              for c in candidates]
    ranked.sort(key=lambda rc: (rc.predicted_step_s, rc.name))
    return ranked
