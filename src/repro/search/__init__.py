"""Automated HSPMD strategy search: enumerate, prune, rank with the
priced cost model, and validate winners by executing them.

    from repro.search import Searcher, search
    result = search(cluster, model, global_batch=64, validate_top=3)
    result.best.candidate.strategy      # cost-model Strategy
    result.summary()

Pipeline: :mod:`space` (candidate grids over TP x DP x PP x virtual
stages x asymmetric per-group sharding), :mod:`prune` (memory /
divisibility / layer-count feasibility with per-rule rejection counts),
:mod:`rank` (measured-fraction priced pipeline cost model),
:mod:`validate` (top-k executed via ``compile_train`` +
``Session.train_step`` on forced CPU meshes, sim↔jax bit-exact),
:mod:`driver` (the restart-free entry point the elastic driver calls).
"""

from .driver import Searcher, SearchResult, search
from .prune import (PruneReport, Rejection, RULES, SearchError,
                    check_candidate, prune)
from .rank import RankedCandidate, proxy_fwd_fraction, rank
from .space import (CPU_A, CPU_B, Candidate, balanced_stages,
                    cpu_cluster, cpu_hetero_cluster,
                    enumerate_candidates, proportional_split, tiny_spec)
from .validate import (ExecutedCandidate, ProxyError, ValidationReport,
                       executable_microbatches, proxy_program, validate)

__all__ = [
    "CPU_A", "CPU_B", "Candidate", "ExecutedCandidate", "ProxyError",
    "PruneReport", "RULES", "RankedCandidate", "Rejection",
    "SearchError", "SearchResult", "Searcher", "ValidationReport",
    "balanced_stages", "check_candidate", "cpu_cluster",
    "cpu_hetero_cluster", "enumerate_candidates",
    "executable_microbatches", "proportional_split", "proxy_program",
    "proxy_fwd_fraction", "prune", "rank", "search", "tiny_spec",
    "validate",
]
