"""Strategy-space enumeration (the search subsystem's candidate grid).

The paper selects hetero strategies from "pre-profiled results combined
with a cost model" (§7.2); HAP (PAPERS.md) shows the strategy program
itself can be synthesized.  This module enumerates the candidate space a
``ClusterSpec`` + ``ModelSpec`` admits:

* **uniform** candidates — TP x DP x PP x virtual-stage x micro-batch
  grids over the rank list (the DeepSpeed/Megatron axes), and
* **hetero** candidates — per-device-type TP degrees with layer counts
  assigned proportionally to stage compute power (the paper's Table 5
  shape: asymmetric per-group sharding, slower device classes feeding
  the early stages with fewer layers).

Every grid point becomes a :class:`Candidate` — including infeasible
ones, which carry a ``defect`` (rule, reason) instead of a cost-model
``Strategy`` so the pruner can report per-rule rejection counts instead
of silently skipping.  Enumeration order is DETERMINISTIC (sorted
grids), which the driver's memoization and the tests rely on.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.costmodel import (ClusterSpec, DeviceType, ModelSpec,
                                  PipelineSpec, Stage, Strategy)

# -- CPU fixtures for execution validation ----------------------------------
# The validator executes candidates on forced CPU meshes; these device
# classes keep the cost model's compute term dominant (tiny tflops) and
# the comm terms small (one fat intra-node link).  ``cpuB`` is a second
# CLASS (its own name -> the hetero enumeration applies) at half speed
# and smaller memory.
CPU_A = DeviceType("cpuA", 2e-4, 64.0, 64.0)
CPU_B = DeviceType("cpuB", 1e-4, 48.0, 64.0)


def cpu_cluster(n: int) -> ClusterSpec:
    """Homogeneous n-rank CPU fixture."""
    return ClusterSpec((CPU_A,) * n)


def cpu_hetero_cluster(n_fast: int, n_slow: int,
                       slow_tflops: float | None = None) -> ClusterSpec:
    """Two-class CPU fixture: ``n_fast`` cpuA ranks then ``n_slow``
    cpuB ranks (half speed by default; pass ``slow_tflops`` to change
    the ratio — e.g. ``CPU_A.tflops`` for classes that differ only in
    memory, which execution validation on an equal-speed CPU mesh can
    rank without speed projection)."""
    slow = CPU_B if slow_tflops is None else DeviceType(
        "cpuB", slow_tflops, CPU_B.mem_gb, CPU_B.nvlink_gbps)
    return ClusterSpec((CPU_A,) * n_fast + (slow,) * n_slow)


def tiny_spec(n_layers: int = 8) -> ModelSpec:
    """A model small enough that CPU-fixture searches stay feasible."""
    return ModelSpec("cpu-tiny", n_layers, 64, 256, vocab=512)


# -- proportional layer assignment ------------------------------------------

def proportional_split(weights: list[float], total: int) -> list[int]:
    """``len(weights)`` counts, each >= 1, summing to ``total``,
    proportional to ``weights``.  Allocates against the REMAINING budget
    so no stage can be starved to zero (the bug the old
    ``scenarios.search._balanced_stages`` had when the group count
    approached the layer count)."""
    n = len(weights)
    if n > total:
        raise ValueError(f"cannot split {total} layers into {n} "
                         f"groups of >= 1 layer each")
    out: list[int] = []
    rem_w = float(sum(weights))
    rem_t = total
    for i, w in enumerate(weights):
        trailing = n - i - 1
        if trailing == 0:
            c = rem_t
        else:
            want = round(rem_t * w / rem_w) if rem_w > 0 else 1
            # leave >= 1 for every remaining group
            c = max(1, min(want, rem_t - trailing))
        out.append(c)
        rem_t -= c
        rem_w -= w
    return out


def balanced_stages(groups: list[tuple[tuple[int, ...], float]],
                    n_layers: int) -> list[Stage]:
    """Assign layer ranges to TP groups proportionally to throughput;
    every stage gets at least one layer (raises ``ValueError`` when
    there are more groups than layers)."""
    counts = proportional_split([p for _, p in groups], n_layers)
    stages, lo = [], 0
    for (ranks, _), c in zip(groups, counts):
        stages.append(Stage(tuple(ranks), (lo, lo + c)))
        lo += c
    return stages


# -- candidates --------------------------------------------------------------

@dataclass(frozen=True)
class Candidate:
    """One point of the search space.

    ``strategy`` is the cost-model :class:`Strategy` (None when the grid
    point cannot even be built — then ``defect`` names the pruning rule
    and reason).  ``dp`` counts pipelines (DP replicas for uniform
    candidates, hetero subgroups for hetero ones), ``pp`` physical
    stages per pipeline, ``v`` Megatron virtual stages per device,
    ``group_tps`` the per-device-type TP degrees of hetero candidates.
    """

    name: str
    kind: str                       # "uniform" | "hetero"
    dp: int
    tp: int                         # 0 for hetero (see group_tps)
    pp: int
    v: int
    micro_bs: int
    n_micro: int
    schedule: str                   # "1f1b" | "interleaved"
    strategy: Strategy | None
    group_tps: tuple[tuple[str, int], ...] = ()
    defect: tuple[str, str] | None = None

    @property
    def n_devices(self) -> int:
        return self.strategy.device_count() if self.strategy else 0

    def describe(self) -> str:
        if self.defect:
            return f"{self.name}: REJECTED[{self.defect[0]}] {self.defect[1]}"
        extra = "".join(f" {t}:tp{k}" for t, k in self.group_tps)
        return (f"{self.name}: {self.kind} dp{self.dp} pp{self.pp} "
                f"v{self.v} m{self.n_micro}x{self.micro_bs}{extra}")


def _defect(name: str, kind: str, rule: str, reason: str,
            **dims) -> Candidate:
    base = dict(dp=0, tp=0, pp=0, v=1, micro_bs=1, n_micro=0,
                schedule="1f1b")
    base.update(dims)
    return Candidate(name=name, kind=kind, strategy=None,
                     defect=(rule, reason), **base)


def _uniform_candidate(ranks: list[int], model: ModelSpec, tp: int, pp: int,
                       v: int, mbs: int, global_batch: int) -> Candidate:
    n = len(ranks)
    sched = "interleaved" if v > 1 else "1f1b"
    vtag = f".v{v}" if v > 1 else ""
    mtag = f".mbs{mbs}" if mbs > 1 else ""
    if n % (tp * pp):
        return _defect(f"tp{tp}.pp{pp}{vtag}{mtag}", "uniform",
                       "divisibility",
                       f"tp*pp={tp * pp} does not divide {n} ranks",
                       tp=tp, pp=pp, v=v, micro_bs=mbs, schedule=sched)
    dp = n // (tp * pp)
    name = f"dp{dp}.tp{tp}.pp{pp}{vtag}{mtag}"
    if global_batch % (dp * mbs):
        return _defect(name, "uniform", "divisibility",
                       f"global batch {global_batch} not divisible by "
                       f"dp*micro_bs={dp * mbs}",
                       dp=dp, tp=tp, pp=pp, v=v, micro_bs=mbs,
                       schedule=sched)
    n_micro = global_batch // (dp * mbs)
    if pp * v > model.n_layers:
        return _defect(name, "uniform", "layer-count",
                       f"{pp}x{v} virtual stages exceed "
                       f"{model.n_layers} layers",
                       dp=dp, tp=tp, pp=pp, v=v, micro_bs=mbs,
                       n_micro=n_micro, schedule=sched)
    if v > 1 and n_micro % pp and n_micro > pp:
        return _defect(name, "uniform", "divisibility",
                       f"interleaved needs m % pp == 0 or m <= pp "
                       f"(m={n_micro}, pp={pp})",
                       dp=dp, tp=tp, pp=pp, v=v, micro_bs=mbs,
                       n_micro=n_micro, schedule=sched)
    counts = proportional_split([1.0] * pp, model.n_layers)
    pipelines, idx = [], 0
    for _ in range(dp):
        stages, lo = [], 0
        for s in range(pp):
            grp = tuple(ranks[idx:idx + tp])
            idx += tp
            stages.append(Stage(grp, (lo, lo + counts[s])))
            lo += counts[s]
        pipelines.append(PipelineSpec(tuple(stages), n_micro, mbs))
    strat = Strategy(tuple(pipelines))
    return Candidate(name=name, kind="uniform", dp=dp, tp=tp, pp=pp, v=v,
                     micro_bs=mbs, n_micro=n_micro, schedule=sched,
                     strategy=strat)


def _hetero_candidates(cluster: ClusterSpec, model: ModelSpec,
                       ranks: list[int], global_batch: int,
                       pipeline_options, tp_options,
                       micro_bs_options) -> list[Candidate]:
    by_type: dict[str, list[int]] = {}
    for r in ranks:
        by_type.setdefault(cluster.ranks[r].name, []).append(r)
    types = sorted(by_type)
    out: list[Candidate] = []
    for n_pipes in sorted(pipeline_options):
        if any(len(v) % n_pipes for v in by_type.values()):
            out.append(_defect(
                f"het{n_pipes}", "hetero", "divisibility",
                f"{n_pipes} pipelines do not divide the per-type rank "
                f"counts {[len(by_type[t]) for t in types]}",
                dp=n_pipes))
            continue
        per_pipe = {t: [v[i::n_pipes] for i in range(n_pipes)]
                    for t, v in by_type.items()}
        for tps in itertools.product(sorted(tp_options),
                                     repeat=len(types)):
            tag = "het{}x".format(n_pipes) + "-".join(
                f"{t}.tp{k}" for t, k in zip(types, tps))
            group_tps = tuple(zip(types, tps))
            bad = next((t for t, k in zip(types, tps)
                        if len(per_pipe[t][0]) % k), None)
            if bad is not None:
                out.append(_defect(
                    tag, "hetero", "divisibility",
                    f"tp={dict(group_tps)[bad]} does not divide the "
                    f"{len(per_pipe[bad][0])} {bad} ranks per pipeline",
                    dp=n_pipes, group_tps=group_tps))
                continue
            pipes, n_groups = [], 0
            for pi in range(n_pipes):
                groups = []
                for t, tp in zip(types, tps):
                    chunk = per_pipe[t][pi]
                    power = cluster.ranks[chunk[0]].tflops * tp
                    for gidx in range(len(chunk) // tp):
                        groups.append(
                            (tuple(chunk[gidx * tp:(gidx + 1) * tp]),
                             power))
                # slower device classes feed the early stages (paper
                # Table 5 places the H20 stages first); rank id breaks
                # power ties deterministically
                groups.sort(key=lambda g: (g[1], g[0]))
                n_groups = len(groups)
                if n_groups > model.n_layers:
                    break
                stages = balanced_stages(groups, model.n_layers)
                pipes.append(stages)
            if n_groups > model.n_layers:
                out.append(_defect(
                    tag, "hetero", "layer-count",
                    f"{n_groups} stages per pipeline exceed "
                    f"{model.n_layers} layers",
                    dp=n_pipes, pp=n_groups, group_tps=group_tps))
                continue
            for mbs in sorted(micro_bs_options):
                mtag = f".mbs{mbs}" if mbs > 1 else ""
                if global_batch % (n_pipes * mbs):
                    out.append(_defect(
                        tag + mtag, "hetero", "divisibility",
                        f"global batch {global_batch} not divisible by "
                        f"pipelines*micro_bs={n_pipes * mbs}",
                        dp=n_pipes, pp=n_groups, micro_bs=mbs,
                        group_tps=group_tps))
                    continue
                n_micro = global_batch // (n_pipes * mbs)
                strat = Strategy(tuple(
                    PipelineSpec(tuple(stages), n_micro, mbs)
                    for stages in pipes))
                out.append(Candidate(
                    name=tag + mtag, kind="hetero", dp=n_pipes, tp=0,
                    pp=n_groups, v=1, micro_bs=mbs, n_micro=n_micro,
                    schedule="1f1b", strategy=strat,
                    group_tps=group_tps))
    return out


def enumerate_candidates(cluster: ClusterSpec, model: ModelSpec,
                         ranks: list[int] | None = None, *,
                         global_batch: int,
                         tp_options=(1, 2, 4, 8),
                         pp_options=(1, 2, 4, 8),
                         virtual_options=(1, 2),
                         micro_bs_options=(1,),
                         pipeline_options=(1, 2, 4),
                         include_uniform: bool = True,
                         include_hetero: bool = True) -> list[Candidate]:
    """The full candidate list (deterministic order; includes defect
    candidates so pruning can count per-rule rejections).

    Uniform candidates sweep TP x PP x v x micro-bs grids (DP is
    implied by the rank count); hetero candidates sweep pipeline counts
    x per-device-type TP degrees with power-proportional layer ranges.
    Interleaved (v > 1) sweeps are uniform-only — hetero candidates
    already break symmetry through their stage shapes.
    """
    ranks = sorted(ranks if ranks is not None else
                   range(len(cluster.ranks)))
    if not ranks:
        raise ValueError("enumerate_candidates needs at least one rank")
    out: list[Candidate] = []
    if include_uniform:
        for tp in sorted(tp_options):
            for pp in sorted(pp_options):
                for v in sorted(virtual_options):
                    if v > 1 and pp == 1:
                        continue    # interleaving needs a real pipeline
                    for mbs in sorted(micro_bs_options):
                        out.append(_uniform_candidate(
                            ranks, model, tp, pp, v, mbs, global_batch))
    if include_hetero:
        out.extend(_hetero_candidates(
            cluster, model, ranks, global_batch, pipeline_options,
            tp_options, micro_bs_options))
    return out
