"""Feasibility pruning with per-candidate rejection reasons.

Every candidate either survives or is rejected under exactly one of
:data:`RULES`; the :class:`PruneReport` keeps per-rule counts so an
infeasible search raises a debuggable :class:`SearchError` ("12
rejected — divisibility: 9, memory: 3") instead of the old bare
``RuntimeError``.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.costmodel import ClusterSpec, ModelSpec, memory_per_rank

from .space import Candidate

RULES = ("divisibility", "layer-count", "memory")


def check_candidate(cluster: ClusterSpec, model: ModelSpec,
                    cand: Candidate, *,
                    mem_fraction: float = 0.85
                    ) -> tuple[str, str] | None:
    """``(rule, reason)`` when infeasible, ``None`` when the candidate
    survives.  Enumeration-time defects (divisibility, layer-count) are
    carried through; memory is checked here against the cluster."""
    if cand.defect is not None:
        return cand.defect
    strat = cand.strategy
    assert strat is not None
    for p in strat.pipelines:
        for st in p.stages:
            if st.n_layers < cand.v:
                return ("layer-count",
                        f"stage {st.ranks} holds {st.n_layers} layers "
                        f"< {cand.v} virtual stages")
    worst_r, worst_frac = -1, 0.0
    for r, gb in memory_per_rank(model, strat).items():
        frac = gb / cluster.ranks[r].mem_gb
        if frac > worst_frac:
            worst_r, worst_frac = r, frac
    if worst_frac > mem_fraction:
        return ("memory",
                f"rank {worst_r} needs {worst_frac:.2f}x of its "
                f"{cluster.ranks[worst_r].mem_gb:.0f} GB "
                f"(limit {mem_fraction:.2f}x)")
    return None


@dataclass(frozen=True)
class Rejection:
    candidate: Candidate
    rule: str
    reason: str


@dataclass(frozen=True)
class PruneReport:
    n_candidates: int
    survivors: tuple[Candidate, ...]
    rejections: tuple[Rejection, ...]

    def counts(self) -> dict[str, int]:
        out = {rule: 0 for rule in RULES}
        for rej in self.rejections:
            out[rej.rule] = out.get(rej.rule, 0) + 1
        return out

    def summary(self) -> str:
        counts = self.counts()
        per_rule = ", ".join(f"{rule}: {counts[rule]}" for rule in RULES
                             if counts.get(rule))
        return (f"{self.n_candidates} candidates -> "
                f"{len(self.survivors)} feasible, "
                f"{len(self.rejections)} rejected"
                + (f" ({per_rule})" if per_rule else ""))


class SearchError(RuntimeError):
    """No feasible strategy; ``.report`` holds the full prune trail."""

    def __init__(self, report: PruneReport,
                 what: str = "strategy") -> None:
        self.report = report
        counts = report.counts()
        per_rule = ", ".join(f"{rule}: {counts[rule]}" for rule in RULES)
        super().__init__(
            f"no feasible {what} found: {len(report.rejections)} "
            f"candidates rejected ({per_rule})")


def prune(cluster: ClusterSpec, model: ModelSpec,
          candidates: list[Candidate], *,
          mem_fraction: float = 0.85) -> PruneReport:
    survivors: list[Candidate] = []
    rejections: list[Rejection] = []
    for cand in candidates:
        verdict = check_candidate(cluster, model, cand,
                                  mem_fraction=mem_fraction)
        if verdict is None:
            survivors.append(cand)
        else:
            rejections.append(Rejection(cand, *verdict))
    return PruneReport(len(candidates), tuple(survivors),
                       tuple(rejections))
