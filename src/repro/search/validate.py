"""Execution validation: run the top-k candidates for real.

The cost model ranks candidates analytically; this module checks that
ranking against ACTUAL execution.  Each candidate is turned into a
small proxy training program whose HSPMD annotations realize exactly
the candidate's parallel shape — TP groups column/row-splitting weight
pairs, pipeline stages owning layer-proportional slices of the pair
chain (comm ops at every owner change), DP/hetero pipelines as hsize>1
subgroups with batch slabs (``hdim=0``) and hetero-duplicated weights
whose gradients come back ``hdim=Partial`` (the SplitAR grad path PR 6
made executable) — then trained end to end via
``Program.compile_train`` + ``Session.train_step`` on forced CPU
meshes, on both executors.

Measuring is subtle: the SimulatorExecutor serializes every device onto
one CPU, so raw wall time is nearly invariant across dp/pp splits (the
total op work is constant).  Instead the executor records per-tick
PER-DEVICE wall times (``record_ticks=True``) and the validator
re-prices the executed timetable with max-over-devices tick durations
(``price_schedule``) — the parallel makespan a real cluster would see.
For heterogeneous fixtures, each device's time is first scaled by
``ref_tflops / its_tflops`` (the CPU mesh has equal-speed devices; the
projection reintroduces the speed ratio the candidate was priced
under).

Proxy numerics are exact: inputs are small integers and every weight is
a signed selection matrix (one ±1 per column), so activations never
grow, float32 arithmetic stays integer-exact, and sim↔jax losses and
gradients can be compared BITWISE.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.costmodel import ClusterSpec
from repro.core.schedule import price_schedule

from .rank import RankedCandidate
from .space import Candidate, proportional_split


class ProxyError(ValueError):
    """The candidate's shape cannot be realized as a proxy program."""


def _selection_matrix(rng, rows: int, cols: int, stride: int,
                      offset: int) -> np.ndarray:
    """A (rows, cols) matrix with exactly one ±1 per column: applying it
    permutes/negates columns, so activation magnitudes never grow and
    every product stays exactly representable in float32."""
    w = np.zeros((rows, cols), np.float32)
    for j in range(cols):
        w[(j * stride + offset) % rows, j] = float(
            rng.integers(0, 2) * 2 - 1)
    return w


@dataclass
class ProxyCase:
    """A candidate realized as an executable training program."""

    program: object                     # api.Program
    feeds: dict[str, np.ndarray]
    weights: dict[str, np.ndarray]
    n_devices: int
    rank_of_device: dict[int, int]      # device id -> cluster rank


def proxy_program(cand: Candidate, *, n_pairs: int = 8, d: int = 16,
                  f: int = 32, batch: int = 16,
                  seed: int = 0) -> ProxyCase:
    """Build the candidate-shaped proxy: a chain of ``n_pairs`` relu-MLP
    weight pairs standing in for the model's layers, annotated with the
    candidate's exact TP x PP x DP/hetero shape."""
    from repro import api

    strat = cand.strategy
    if strat is None:
        raise ProxyError(f"{cand.name} was rejected; nothing to execute")
    pipes = strat.pipelines
    n_pipes = len(pipes)
    all_ranks = sorted(r for p in pipes for st in p.stages
                      for r in st.ranks)
    if len(set(all_ranks)) != len(all_ranks):
        raise ProxyError(f"{cand.name}: pipelines share ranks")
    dev_of = {r: i for i, r in enumerate(all_ranks)}
    n_stages = len(pipes[0].stages)
    if any(len(p.stages) != n_stages for p in pipes):
        raise ProxyError(f"{cand.name}: ragged pipeline depths")
    if batch % n_pipes:
        raise ProxyError(f"batch {batch} not divisible by "
                         f"{n_pipes} pipelines")
    # owner physical stage of each weight pair: layer-proportional for
    # v=1 (asymmetric hetero splits show up in the executed shape), the
    # Megatron wrap-around chunk layout for v>1
    if cand.v == 1:
        counts = proportional_split(
            [st.n_layers for st in pipes[0].stages], n_pairs)
        owner = [s for s, c in enumerate(counts) for _ in range(c)]
    else:
        chunks = n_stages * cand.v
        if n_pairs < chunks:
            raise ProxyError(f"{n_pairs} pairs < {chunks} virtual "
                             f"stages")
        owner = [(i * chunks // n_pairs) % n_stages
                 for i in range(n_pairs)]
    grp = [[tuple(dev_of[r] for r in st.ranks) for st in p.stages]
           for p in pipes]
    for s in range(n_stages):
        for p in range(n_pipes):
            tp = len(grp[p][s])
            if f % tp or d % tp:
                raise ProxyError(
                    f"stage tp={tp} does not divide proxy dims "
                    f"d={d}, f={f}")

    def act_annot(s: int):
        groups = [list(grp[p][s]) for p in range(n_pipes)]
        dss = [api.DS({api.DUP: len(g)}) if len(g) > 1 else api.DS({})
               for g in groups]
        if n_pipes == 1:
            return api.spmd(groups[0], dss[0])
        return api.HSPMD(groups, dss, hdim=0)

    def w_annot(s: int, dim: int):
        groups = [list(grp[p][s]) for p in range(n_pipes)]
        dss = [api.DS({dim: len(g)}) if len(g) > 1 else api.DS({})
               for g in groups]
        if n_pipes == 1:
            return api.spmd(groups[0], dss[0])
        return api.HSPMD(groups, dss)       # hdim=DUP: grads -> SplitAR

    rng = np.random.default_rng(seed)
    g = api.Graph()
    x = g.placeholder("X", (batch, d))
    annots = {"X": act_annot(owner[0])}
    feeds = {"X": rng.integers(-3, 4, (batch, d)).astype(np.float32)}
    weights: dict[str, np.ndarray] = {}
    prev = owner[0]
    for i in range(n_pairs):
        s = owner[i]
        if s != prev:                        # stage boundary -> P2P comm
            x = g.comm(x, name=f"T{i}")
            annots[f"T{i}"] = act_annot(s)
            prev = s
        wu = g.parameter(f"Wu{i}", (d, f))
        wd = g.parameter(f"Wd{i}", (f, d))
        annots[f"Wu{i}"] = w_annot(s, 1)     # column-parallel
        annots[f"Wd{i}"] = w_annot(s, 0)     # row-parallel
        weights[f"Wu{i}"] = _selection_matrix(rng, d, f, 3, i)
        weights[f"Wd{i}"] = _selection_matrix(rng, f, d, 5, 2 * i + 1)
        h = g.relu(g.dot(x, wu, name=f"H{i}"), name=f"R{i}")
        y = g.dot(h, wd, name=f"Y{i}")
        tp = len(grp[0][s])
        if tp > 1:                           # resolve the TP Partial
            x = g.comm(y, name=f"A{i}")
            annots[f"A{i}"] = act_annot(s)
        else:
            x = y
    g.sum(g.sum(x, 1, name="L1"), 0, name="L")
    program = api.Program(g, [api.Strategy(cand.name, annots)])
    return ProxyCase(program, feeds, weights, len(all_ranks),
                     {i: r for r, i in dev_of.items()})


def executable_microbatches(cand: Candidate, batch: int,
                            cap: int = 8) -> int:
    """The largest microbatch count <= min(candidate, cap) the proxy can
    actually run: the batch must split into m microbatches AND each
    microbatch must still split across the candidate's pipelines;
    interleaved schedules additionally need m % stages == 0 (or
    m <= stages)."""
    n_pipes = cand.dp if cand.dp else 1
    for m in range(min(max(cand.n_micro, 1), cap), 0, -1):
        if batch % m:
            continue
        if (batch // m) % n_pipes:
            continue
        if cand.v > 1 and m % cand.pp and m > cand.pp:
            continue
        return m
    return 1


# -- measurement -------------------------------------------------------------

def _tick_durations(ticks: dict, scale: dict[int, float] | None
                    ) -> dict[tuple[int, str], float]:
    """(stage, phase) -> the tick's parallel cost, noise-rejected at OP
    granularity: every occurrence of a (stage, phase) key executes the
    same per-device op sequence (same shapes, different microbatch), so
    each op's true cost is the element-wise MIN across the pooled
    microbatch x repeat samples — timing noise is strictly additive and
    per-op spans give it the fewest places to hide.  A device's tick
    cost is the sum of its op minima (speed-scaled for hetero
    projection); the tick's cost is the max over devices: what the
    serialized simulator work would cost running in parallel."""
    out: dict[tuple[int, str], float] = {}
    for key, occurrences in ticks.items():
        mins: dict[int, list[float]] = {}
        for devops in occurrences:
            for dev, samples in devops.items():
                best = mins.get(dev)
                if best is None:
                    mins[dev] = list(samples)
                else:
                    for i in range(min(len(best), len(samples))):
                        if samples[i] < best[i]:
                            best[i] = samples[i]
        out[key] = max(
            sum(ops) * (scale.get(dev, 1.0) if scale else 1.0)
            for dev, ops in mins.items())
    return out


@dataclass
class ExecutedCandidate:
    """One candidate's execution-validation outcome."""

    ranked: RankedCandidate
    m: int = 1
    schedule: str = "1f1b"
    measured_wall_s: float | None = None       # serialized wall clock
    measured_makespan_s: float | None = None   # re-priced parallel time
    projected_makespan_s: float | None = None  # speed-scaled (hetero)
    proxy_predicted_s: float | None = None     # plan's own timetable
    loss: float | None = None
    bit_exact: bool | None = None              # sim vs jax (None: sim only)
    error: str | None = None

    @property
    def name(self) -> str:
        return self.ranked.name

    @property
    def candidate(self) -> Candidate:
        return self.ranked.candidate

    @property
    def predicted_s(self) -> float:
        return self.ranked.predicted_step_s

    def describe(self) -> str:
        if self.error:
            return f"{self.name}: SKIPPED ({self.error})"
        mk = self.projected_makespan_s or self.measured_makespan_s
        bits = "" if self.bit_exact is None else \
            (" bit-exact" if self.bit_exact else " MISMATCH")
        return (f"{self.name}: predicted {self.predicted_s * 1e3:.3f} ms,"
                f" measured makespan "
                f"{(mk or 0.0) * 1e3:.3f} ms (m={self.m}){bits}")


@dataclass
class ValidationReport:
    executed: tuple[ExecutedCandidate, ...]
    speed_projected: bool

    def _comparable(self) -> list[ExecutedCandidate]:
        return [e for e in self.executed if e.error is None
                and (e.projected_makespan_s if self.speed_projected
                     else e.measured_makespan_s) is not None]

    def agreement(self, tol: float = 0.05) -> float | None:
        """Pairwise concordance of predicted vs measured ordering over
        the validated candidates (1.0 = identical order).  Pairs whose
        predicted OR measured times are within ``tol`` relative
        difference count as concordant — near-ties carry no ordering
        information either way."""
        items = [(e.predicted_s,
                  e.projected_makespan_s if self.speed_projected
                  else e.measured_makespan_s)
                 for e in self._comparable()]
        n = len(items)
        if n < 2:
            return None
        good = total = 0
        for i in range(n):
            for j in range(i + 1, n):
                (pi, mi), (pj, mj) = items[i], items[j]
                total += 1
                close_pred = abs(pi - pj) <= tol * max(pi, pj)
                close_meas = abs(mi - mj) <= tol * max(mi, mj)
                if close_pred or close_meas or \
                        ((pi < pj) == (mi < mj)):
                    good += 1
        return good / total

    def summary(self) -> str:
        ag = self.agreement()
        lines = [f"validated {len(self._comparable())}/"
                 f"{len(self.executed)} candidate(s); ordering "
                 f"agreement {'n/a' if ag is None else f'{ag:.2f}'}"
                 + (" (speed-projected)" if self.speed_projected
                    else "")]
        lines += ["  " + e.describe() for e in self.executed]
        return "\n".join(lines)


def validate(cluster: ClusterSpec, ranked: list[RankedCandidate], *,
             top_k: int = 3, executors=("sim",), mesh=None,
             repeats: int = 3, batch: int = 16, n_pairs: int = 8,
             d: int = 16, f: int = 32, max_micro: int = 8,
             speed_project: bool | None = None,
             seed: int = 0) -> ValidationReport:
    """Execute the top-k ranked candidates as proxy training programs
    and compare cost-model ordering against measured makespans.

    ``executors=("sim", "jax")`` additionally runs each candidate on the
    JaxExecutor (pass the forced-CPU ``mesh``) and checks the first
    step's loss and every weight gradient BITWISE against the
    simulator.
    """
    from repro import api

    import statistics

    if speed_project is None:
        speed_project = len({dt.tflops for dt in cluster.ranks}) > 1
    ref = max(dt.tflops for dt in cluster.ranks)

    # phase 1: realize every candidate as a proxy session
    out: list[ExecutedCandidate] = []
    runners: list[dict] = []
    for rc in ranked[:top_k]:
        cand = rc.candidate
        try:
            proxy = proxy_program(cand, n_pairs=n_pairs, d=d, f=f,
                                  batch=batch, seed=seed)
        except (ProxyError, ValueError) as e:
            out.append(ExecutedCandidate(rc, error=f"proxy: {e}"))
            continue
        m = executable_microbatches(cand, batch, cap=max_micro)
        kind = "interleaved" if cand.v > 1 else "1f1b"
        entry = ExecutedCandidate(rc, m=m, schedule=kind)
        out.append(entry)
        sess = api.Session(proxy.program, 0,
                           executor=api.SimulatorExecutor(
                               record_ticks=True))
        sess.load(proxy.weights)
        runners.append(dict(entry=entry, proxy=proxy, sess=sess, m=m,
                            kind=kind, walls=[], ticks={}, sched=None))

    # phase 2: measured steps ROUND-ROBIN across candidates (+1 warmup
    # round), so a load spike on the shared CPU hits every candidate's
    # sample pool instead of biasing whichever was measured then
    for rep in range(1 + repeats):
        for run in list(runners):
            entry = run["entry"]
            try:
                t0 = time.perf_counter()
                r = run["sess"].train_step(
                    run["proxy"].feeds, num_microbatches=run["m"],
                    schedule=run["kind"])
                dt = time.perf_counter() - t0
            except Exception as e:  # noqa: BLE001 - isolate candidates
                entry.error = f"{type(e).__name__}: {e}"
                runners.remove(run)
                continue
            if rep == 0:            # warmup: numpy caches, compiles
                entry.loss = r.loss
                run["sched"] = r.schedule
                continue
            run["walls"].append(dt)
            rec = run["sess"].executor.last_tick_device_seconds
            for key, occurrences in rec.items():
                run["ticks"].setdefault(key, []).extend(occurrences)

    # phase 3: re-price each candidate's executed timetable
    calibration: float | None = None
    for run in runners:
        entry, proxy = run["entry"], run["proxy"]
        entry.measured_wall_s = statistics.median(run["walls"])
        if run["ticks"] and run["sched"] is not None:
            raw = _tick_durations(run["ticks"], None)
            entry.measured_makespan_s = price_schedule(
                run["sched"], lambda s, ph: raw.get((s, ph), 0.0)
            ).makespan
            if speed_project:
                scale = {dev: ref / cluster.ranks[r].tflops
                         for dev, r in proxy.rank_of_device.items()}
                proj = _tick_durations(run["ticks"], scale)
                entry.projected_makespan_s = price_schedule(
                    run["sched"], lambda s, ph: proj.get((s, ph), 0.0)
                ).makespan
        else:
            # m=1 runs have no timetable: approximate the parallel
            # makespan as serialized wall time over the device count
            entry.measured_makespan_s = \
                entry.measured_wall_s / max(proxy.n_devices, 1)
        try:
            tplan = proxy.program.compile_train(0,
                                                num_microbatches=run["m"])
            base = tplan.predicted_step_seconds(run["m"], run["kind"])
            if calibration is None and entry.measured_makespan_s:
                calibration = base / entry.measured_makespan_s
            if calibration:
                entry.proxy_predicted_s = base / calibration
            if "jax" in executors:
                if mesh is None:
                    entry.error = "jax requested but no mesh given"
                else:
                    entry.bit_exact = _bit_exact(
                        api, proxy, mesh, run["m"], run["kind"])
        except Exception as e:  # noqa: BLE001 - isolate candidates
            entry.error = f"{type(e).__name__}: {e}"
    return ValidationReport(tuple(out), speed_project)


def _bit_exact(api, proxy: ProxyCase, mesh, m: int, kind: str) -> bool:
    """One fresh train step on each executor; loss and every gradient
    must match BITWISE (the proxy arithmetic is integer-exact)."""
    results = []
    for executor in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
        sess = api.Session(proxy.program, 0, executor=executor)
        sess.load(proxy.weights)
        results.append(sess.train_step(proxy.feeds, num_microbatches=m,
                                       schedule=kind))
    a, b = results
    if a.loss != b.loss:
        return False
    return all(np.array_equal(a.grad_value(p), b.grad_value(p))
               for p in a.grads)
