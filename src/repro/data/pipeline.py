"""Data pipeline: synthetic corpora with realistic length distributions,
sequence packing, and the length-bucket machinery the mixed-length
scenarios (paper §7.3) need.

Two synthetic corpora mirror the paper's evaluation sets:
  * ``commoncrawl`` — lognormal lengths, median ~600 tokens, heavy tail
    (97% of sequences under 8K at 32K context, matching Fig 16's remark);
  * ``github``      — flatter lognormal with a longer tail.

``pack_batch`` packs variable-length sequences into fixed context windows
with loss masks (the DeepSpeed/Megatron baseline treatment); bucketing +
per-step max-length stats feed HotSPa-style (Hetu-A) and heterogeneous
(Hetu-B) strategy selection.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CorpusConfig:
    name: str = "commoncrawl"
    vocab: int = 32000
    seed: int = 0
    max_len: int = 32768


_DISTS = {
    # (log-mean, log-std) of token counts
    "commoncrawl": (6.4, 1.1),    # median ~600, 97% < 8K
    "github": (7.0, 1.3),         # median ~1100, longer tail
}


class SyntheticCorpus:
    """Deterministic stream of (tokens, length) samples."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        if cfg.name not in _DISTS:
            raise KeyError(f"unknown corpus {cfg.name!r}")
        self._rng = np.random.default_rng(cfg.seed)

    def sample_lengths(self, n: int) -> np.ndarray:
        mu, sigma = _DISTS[self.cfg.name]
        ln = self._rng.lognormal(mu, sigma, size=n)
        return np.clip(ln.astype(np.int64), 8, self.cfg.max_len)

    def sample_sequences(self, n: int) -> list[np.ndarray]:
        lens = self.sample_lengths(n)
        return [self._rng.integers(0, self.cfg.vocab, size=int(l),
                                   dtype=np.int32) for l in lens]


def pack_batch(seqs: list[np.ndarray], batch: int, context: int,
               pad_id: int = 0):
    """Greedy first-fit packing into (batch, context) windows.

    Returns dict(tokens, labels, loss_mask, positions) — positions reset
    at every packed-sequence boundary so RoPE does not leak across
    documents.  Sequences longer than ``context`` are truncated (the
    baseline systems' behaviour in §7.3)."""
    tokens = np.full((batch, context), pad_id, np.int32)
    positions = np.zeros((batch, context), np.int32)
    mask = np.zeros((batch, context), np.float32)
    row, col = 0, 0
    for seq in seqs:
        seq = seq[:context]
        while len(seq) and row < batch:
            space = context - col
            take = min(space, len(seq))
            tokens[row, col:col + take] = seq[:take]
            positions[row, col:col + take] = np.arange(take)
            mask[row, col:col + take] = 1.0
            col += take
            seq = seq[take:]
            if col >= context:
                row, col = row + 1, 0
        if row >= batch:
            break
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = pad_id
    return {"tokens": tokens, "labels": labels, "loss_mask": mask,
            "positions": positions}


# ---------------------------------------------------------------------------
# mixed-length bucketing (paper §7.3)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Bucket:
    lo: int
    hi: int

    def holds(self, n: int) -> bool:
        return self.lo < n <= self.hi


DEFAULT_BUCKETS_32K = (Bucket(0, 4096), Bucket(4096, 16384),
                       Bucket(16384, 32768))
DEFAULT_BUCKETS_16K = (Bucket(0, 4096), Bucket(4096, 16384))


def bucketize(seqs: list[np.ndarray], buckets) -> dict[Bucket, list]:
    out = {b: [] for b in buckets}
    for s in seqs:
        for b in buckets:
            if b.holds(len(s)):
                out[b].append(s)
                break
        else:
            out[buckets[-1]].append(s[:buckets[-1].hi])
    return out


def step_stream(corpus: SyntheticCorpus, tokens_per_step: int,
                n_steps: int):
    """Yields per-step sequence lists totalling ~tokens_per_step tokens
    (the paper uses 200K tokens/step)."""
    for _ in range(n_steps):
        seqs: list[np.ndarray] = []
        total = 0
        while total < tokens_per_step:
            (s,) = corpus.sample_sequences(1)
            seqs.append(s)
            total += len(s)
        yield seqs
