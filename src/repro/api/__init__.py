"""`repro.api` — the front door to the HSPMD pipeline.

One coherent compile-and-run surface over the paper's abstractions::

    from repro import api

    g = api.Graph()                       # single-device view (§5.1)
    x = g.placeholder("X", (8, 16))
    w = g.parameter("W", (16, 4))
    y = g.dot(x, g.comm(w, name="W'"), name="Y")

    tp = api.Strategy("tp", {...})        # named annotation bundles (§3)
    dp = api.Strategy("dp", {...})
    prog = api.Program(g, [tp, dp])       # deduction per strategy (§6.1)

    plan = prog.compile("tp")             # §4 comm resolution + §5.3-5.4
    plan.exec_items(device)               #   per-device executable graph
    plan.cost.summary()                   #   analytic cost / roofline

    sess = api.Session(prog, "tp", executor=api.JaxExecutor())
    sess.load({"W": w_value})
    out = sess.run({"X": x_value})        # one shard_map program (§5.3)
    out = sess.run({"X": x_value},        # microbatched 1F1B pipeline
                   num_microbatches=4,    #   over plan.pipelines (§5.4)
                   schedule="1f1b")
    report = sess.switch("dp")            # fused-BSR, restart-free (§6.2)

Executors are pluggable (:class:`Executor`): ``SimulatorExecutor`` runs
the virtual-device numpy spec, ``JaxExecutor`` the real-device shard_map
backend — bit-exact against each other (``runtime.selftest``).

The pre-API entry points (``core.specialize.specialize``,
``core.comm_resolve.resolve``, ``runtime.execute_plan`` …) remain
importable as shims; see README "Migrating to repro.api".
"""

from repro.core.annotations import (DG, DS, DUP, PARTIAL, HSPMD, replicated,
                                    spmd)
from repro.core.comm_resolve import resolve
from repro.core.graph import (DeductionError, DeductionReport, GradError,
                              Graph, VJP_RULES, cotangent_annot)
from repro.core.op_semantics import MicrobatchError
from repro.core.plan import CommPlan
from repro.core.schedule import (PipelineSchedule, PricedSchedule,
                                 ScheduleError, ScheduleStats, Tick,
                                 build_schedule, price_schedule)
from repro.core.simulator import ShardedTensor, gather, scatter
from repro.core.specialize import (ExecItem, ExecutableGraph, Pipeline,
                                   SpecializationResult)
from repro.core.switching import (SwitchOutcome, SwitchReport,
                                  plan_tensor_switch)
from repro.core.topology import (NvlinkIbTopology, Topology,
                                 UniformTopology)

from repro.runtime.async_program import AsyncExecutor

from .executors import (Executor, JaxExecutor, SimulatorExecutor,
                        get_executor)
from .program import CompiledPlan, CompileError, CostEstimate, Program
from .session import RunResult, Session, TrainResult
from .strategy import (Strategy, StrategyError, data_parallel_strategy,
                       weights_graph)

# deprecation-friendly alias: the scenarios' old hand-rolled
# "build tensors + plan_fused_bsr + est_time" dance, as one call
estimate_switch = plan_tensor_switch

__all__ = [
    "DG", "DS", "DUP", "PARTIAL", "HSPMD", "replicated", "spmd",
    "AsyncExecutor",
    "CommPlan", "CompileError", "CompiledPlan", "CostEstimate",
    "DeductionError", "DeductionReport", "ExecItem", "ExecutableGraph",
    "Executor", "GradError", "Graph", "JaxExecutor", "MicrobatchError",
    "NvlinkIbTopology", "Pipeline", "PipelineSchedule", "PricedSchedule",
    "Program", "RunResult", "ScheduleError", "ScheduleStats", "Session",
    "ShardedTensor", "SimulatorExecutor", "SpecializationResult",
    "Strategy", "StrategyError", "SwitchOutcome", "SwitchReport", "Tick",
    "Topology", "TrainResult", "UniformTopology", "VJP_RULES",
    "build_schedule", "cotangent_annot", "data_parallel_strategy",
    "estimate_switch", "gather", "get_executor", "plan_tensor_switch",
    "price_schedule", "resolve", "scatter", "weights_graph",
]
