"""Named, validated bundles of HSPMD annotations (the API's `Strategy`).

A :class:`Strategy` is everything one parallelization choice needs: a
name, one HSPMD annotation per *annotation point* of the single-device
graph (leaves and CommOp outputs — the paper §6.1 binding sites), and
optionally the cluster topology the cost model should price it on.
``Program`` installs N strategies onto one graph and deduction runs per
strategy index — the paper's "one user graph, one annotated graph per
parallel strategy".
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

from repro.core.annotations import DS, HSPMD, spmd
from repro.core.graph import Graph
from repro.core.topology import Topology


class StrategyError(ValueError):
    """Invalid strategy bundle (bad name, missing/non-HSPMD annotations)."""


@dataclass(frozen=True)
class Strategy:
    """A named bundle: tensor name -> HSPMD annotation (+ topology)."""

    name: str
    annots: Mapping[str, HSPMD]
    topology: Topology | None = field(default=None, compare=False)

    def __post_init__(self):
        if not isinstance(self.name, str) or not self.name:
            raise StrategyError("strategy name must be a non-empty string")
        if not self.annots:
            raise StrategyError(
                f"strategy {self.name!r}: empty annotation bundle")
        for tname, annot in self.annots.items():
            if not isinstance(annot, HSPMD):
                raise StrategyError(
                    f"strategy {self.name!r}: annotation for {tname!r} is "
                    f"{type(annot).__name__}, expected HSPMD")
        if self.topology is not None and not isinstance(self.topology,
                                                        Topology):
            raise StrategyError(
                f"strategy {self.name!r}: topology must be a Topology")
        object.__setattr__(self, "annots", dict(self.annots))

    @property
    def devices(self) -> tuple[int, ...]:
        devs: set[int] = set()
        for annot in self.annots.values():
            devs |= set(annot.devices)
        return tuple(sorted(devs))

    def validate_against(self, graph: Graph) -> None:
        """Check this bundle covers exactly the graph's annotation points
        (leaves + CommOp outputs) — typos and gaps fail loudly."""
        points = [t.name for t in graph.annotation_points()]
        missing = [n for n in points if n not in self.annots]
        if missing:
            raise StrategyError(
                f"strategy {self.name!r} misses annotations for "
                f"{missing}; annotation points are {points}")
        extra = [n for n in self.annots if n not in points]
        if extra:
            raise StrategyError(
                f"strategy {self.name!r} annotates unknown tensors {extra}; "
                f"annotation points are {points}")


# ---------------------------------------------------------------------------
# convenience constructors
# ---------------------------------------------------------------------------

def weights_graph(shapes: Mapping[str, Sequence[int]]) -> Graph:
    """A parameters-only graph — the weight-migration view that elastic
    training and serving reshard (paper §6.2)."""
    g = Graph()
    for name, shape in shapes.items():
        g.parameter(name, tuple(shape))
    return g


def data_parallel_strategy(name: str, devices: Sequence[int],
                           shapes: Mapping[str, Sequence[int]],
                           shard_dim: int = 0,
                           topology: Topology | None = None) -> Strategy:
    """FSDP-style placement: each tensor split along ``shard_dim`` over
    the largest trailing subset of ``devices`` that divides it (falling
    back to a single-device replica) — the elastic-training layout."""
    devices = list(devices)
    n = len(devices)
    if n == 0:
        raise StrategyError(f"strategy {name!r}: empty device list")
    annots = {}
    for tname, shape in shapes.items():
        if len(shape) <= shard_dim:
            annots[tname] = spmd(devices[:1], DS({}))
            continue
        size = int(shape[shard_dim])
        for k in (n, n - n % 2, 4, 2, 1):
            if k and k <= n and size % k == 0:
                # survivors with the highest ids host the shards, so a
                # shrinking cluster actually moves data (SR/BSR paths)
                annots[tname] = spmd(devices[-k:], DS({shard_dim: k}))
                break
        else:
            annots[tname] = spmd(devices[:1], DS({}))
    return Strategy(name, annots, topology)
