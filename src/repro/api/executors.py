"""Pluggable executors: run a CompiledPlan's per-device ExecItems.

The :class:`Executor` protocol is the seam between planning and
execution.  Two implementations ship:

* :class:`SimulatorExecutor` — interprets the specialized per-device
  programs with numpy over the virtual-device simulator
  (``core.simulator``): compute ops apply the shared local semantics
  (``core.op_semantics``) shard-by-shard, CommOps run ``apply_plan``.
  Works for any device count, no accelerator needed — the executable
  specification.
* :class:`JaxExecutor` — lowers the whole graph (compute AND comm) into
  one ``jax.shard_map`` program on real devices
  (``runtime.program.LoweredGraph``) and caches the compiled program per
  (strategy, fetches).  Bit-exactness against the SimulatorExecutor is
  what ``runtime.selftest`` checks on 2/4/8 forced CPU devices.

Both take and return ``{name: ShardedTensor}`` — per-device shards under
the strategy's deduced annotations — so results are comparable
shard-by-shard, bitwise.  Output dtypes follow one shared rule
(``op_semantics.result_dtype``); bitwise parity is guaranteed for
exactly-representable computations (the differential tests' integer-
valued shards through dot/add/relu and all comm), while transcendental
kernels (gelu) may differ in the final ulp between numpy and XLA.

Microbatched pipeline execution (``Session.run(num_microbatches=m)``)
goes through :meth:`run_schedule`: the SimulatorExecutor *interprets the
1F1B / GPipe / interleaved timetable tick by tick* — each forward tick
executes exactly the ops progressive specialization assigned to that
(virtual) pipeline stage, for that microbatch, so an unexecutable
schedule fails loudly — while the JaxExecutor lowers all microbatches
into ONE shard_map program (``lax.scan`` over the microbatch axis; XLA's
dependence order realizes the same pipeline, and a device holding ``v``
interleaved chunks simply has all its chunks' ops in its ``lax.switch``
branch).  Both return *per-microbatch* outputs; the Session combines
them with one shared reduction rule.
"""

from __future__ import annotations

import time
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.op_semantics import local_apply, result_dtype, stacked_apply
from repro.core.schedule import (SCHEDULES, PipelineSchedule, ScheduleError,
                                 assign_stages)
from repro.core.simulator import ShardedTensor, apply_plan

from .program import CompiledPlan


@runtime_checkable
class Executor(Protocol):
    """Anything that can run a CompiledPlan over sharded state."""

    name: str

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        """Execute; ``state`` maps every leaf tensor (placeholders and
        parameters) to its ShardedTensor.  Returns the fetched tensors
        (default: graph sinks) as ShardedTensors."""
        ...

    def run_schedule(self, compiled: CompiledPlan,
                     schedule: PipelineSchedule,
                     states: Sequence[dict[str, ShardedTensor]],
                     fetches: Sequence[str] | None = None
                     ) -> list[dict[str, ShardedTensor]]:
        """Execute a microbatched pipeline schedule over the MICRO plan
        (``Program.compile_micro``); ``states[j]`` holds microbatch
        ``j``'s leaves.  Returns per-microbatch fetches, in order."""
        ...


def _check_fetches(compiled: CompiledPlan, fetches) -> list[str]:
    graph = compiled.graph
    fetches = list(fetches or [t.name for t in graph.sinks()])
    for f in fetches:  # fail up front, like LoweredGraph does
        if f not in graph.tensors:
            raise ValueError(f"unknown fetch tensor {f!r}")
    return fetches


class SimulatorExecutor:
    """Numpy interpretation of the specialized per-device programs.

    Per-op dispatch is CLASS-vectorized (the simulator mirror of the
    specialization-class lowering, ``core.lowered_ir``): devices whose
    local input/output shard shapes agree are stacked and run through
    ONE ``op_semantics.stacked_apply`` call instead of a per-device
    python loop — bit-identical per shard, since the adapters only
    re-index axes.  Kinds without a vectorized form (and singleton
    classes) fall back to the per-device ``local_apply`` path.

    ``record_ticks=True`` makes :meth:`run_schedule` keep COMPUTE
    wall-clock timings per (virtual stage, phase) tick, split BY
    DEVICE — the simulator serializes all devices onto one CPU, so its
    total wall time is pipeline-shape-blind; the per-tick max over
    devices is the parallel makespan contribution the search validator
    re-prices a timetable with (``last_tick_device_seconds``).  A
    vectorized class is timed once and the elapsed time attributed as
    ``dt / n_devices`` per device — the stacked call does each device's
    work in one batched kernel, so the per-device share is the honest
    parallel-cost proxy (this is what makes TP≥2 candidates measure
    sanely instead of paying n× python dispatch).  Comm ops are
    excluded: their simulator cost is python shard-shuffling, not
    network time."""

    name = "sim"
    #: schedule kinds run_schedule accepts (Session validates against
    #: this before building a timetable)
    supported_schedules = SCHEDULES

    def __init__(self, record_ticks: bool = False):
        self.record_ticks = record_ticks
        # (stage, phase) -> one {device: [per-op seconds]} dict per
        # executed tick; a device's op order within a (stage, phase) is
        # deterministic, so samples from different microbatches/repeats
        # align element-wise (the validator min-reduces per op)
        self.last_tick_device_seconds: dict[
            tuple[int, str], list[dict[int, list[float]]]] = {}

    def _exec_op(self, op, env: dict[str, ShardedTensor],
                 compiled: CompiledPlan, plans: dict,
                 dev_acc: dict[int, list[float]] | None = None) -> None:
        out_t = op.outputs[0]
        if op.kind == "comm":
            # never timed into dev_acc: the simulator's comm cost is
            # python shard-shuffling overhead, not network time — the
            # recorded makespan is COMPUTE-only (comm is priced
            # analytically by the cost model)
            env[out_t.name] = apply_plan(env[op.inputs[0].name],
                                         plans[id(op)])
            return
        k = compiled.strategy_index
        annot = out_t.annots[k]
        out_shape = compiled.shapes[out_t.name]
        dtype = result_dtype(op.kind,
                             [env[t.name].dtype for t in op.inputs])
        in_parts = [env[t.name].parts for t in op.inputs]
        # specialization classes, computed from the shards themselves:
        # devices with identical local input/output geometry share one
        # vectorized application (core.lowered_ir's partition would give
        # the same grouping — here the concrete shapes are already in
        # hand, so group on those)
        groups: dict[tuple, list[int]] = {}
        for dev in annot.devices:
            out_local = tuple(annot.device_shape(dev, out_shape))
            key = (tuple(tuple(p[dev].shape) for p in in_parts),
                   out_local)
            groups.setdefault(key, []).append(dev)
        parts: dict[int, np.ndarray] = {}
        for (_, out_local), devs in groups.items():
            stacked = None
            if len(devs) > 1:
                t0 = time.perf_counter() if dev_acc is not None else 0.0
                ins = [np.stack([p[d] for d in devs]) for p in in_parts]
                stacked = stacked_apply(op.kind, np, ins, op.attrs,
                                        out_local, len(devs))
                if stacked is not None:
                    stacked = np.asarray(stacked).astype(
                        dtype, copy=False)
                    dt = (time.perf_counter() - t0) / len(devs) \
                        if dev_acc is not None else 0.0
                    for j, dev in enumerate(devs):
                        parts[dev] = stacked[j].copy()
                        if dev_acc is not None:
                            dev_acc.setdefault(dev, []).append(dt)
            if stacked is None:   # singleton class or no vectorized form
                for dev in devs:
                    t0 = time.perf_counter() \
                        if dev_acc is not None else 0.0
                    locs = [p[dev] for p in in_parts]
                    parts[dev] = np.asarray(local_apply(
                        op.kind, np, locs, op.attrs, out_local)).astype(
                        dtype, copy=False)
                    if dev_acc is not None:
                        dev_acc.setdefault(dev, []).append(
                            time.perf_counter() - t0)
        env[out_t.name] = ShardedTensor(out_shape, annot, parts)

    def _leaf_env(self, compiled: CompiledPlan,
                  state: dict[str, ShardedTensor]
                  ) -> dict[str, ShardedTensor]:
        env: dict[str, ShardedTensor] = {}
        for op in compiled.graph.ops:
            if op.kind in ("placeholder", "parameter"):
                name = op.outputs[0].name
                if name not in state:
                    raise ValueError(f"missing leaf tensor {name!r}")
                env[name] = state[name]
        return env

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        fetches = _check_fetches(compiled, fetches)
        plans = {id(rc.op): rc.plan for rc in
                 compiled.specialization.resolved}
        env = self._leaf_env(compiled, state)
        for op in compiled.graph.ops:
            if op.kind not in ("placeholder", "parameter"):
                self._exec_op(op, env, compiled, plans)
        return {f: env[f] for f in fetches}

    def run_schedule(self, compiled: CompiledPlan,
                     schedule: PipelineSchedule,
                     states: Sequence[dict[str, ShardedTensor]],
                     fetches: Sequence[str] | None = None
                     ) -> list[dict[str, ShardedTensor]]:
        """Interpret the timetable: each tick runs exactly the ops of
        its (virtual) pipeline stage AND its phase for its microbatch —
        forward ticks run the forward ops, backward ticks run the
        autodiff backward ops anchored at that stage (gradient compute
        plus activation-grad / grad-reduce comm; forward-only graphs
        simply have empty bwd ticks).  Interleaved schedules index ops
        by virtual stage: chunk ``tick.stage // S`` on device
        ``tick.stage % S``.  A schedule that violates dataflow (a stage
        ticking before its producer stage) fails on the missing
        input."""
        if len(states) != schedule.num_microbatches:
            raise ScheduleError(
                f"{len(states)} microbatch states for a "
                f"{schedule.num_microbatches}-microbatch schedule")
        if schedule.n_stages != compiled.n_stages:
            raise ScheduleError(
                f"schedule has {schedule.n_stages} stage(s) but the plan "
                f"has {compiled.n_stages}")
        fetches = _check_fetches(compiled, fetches)
        graph, k = compiled.graph, compiled.strategy_index
        plans = {id(rc.op): rc.plan for rc in
                 compiled.specialization.resolved}
        # raises if the graph's chunk count exceeds the schedule's v —
        # a v>1 plan handed a plain 1F1B/GPipe table fails here loudly
        stage_of = assign_stages(
            graph, k, compiled.specialization.pipelines,
            virtual_stages_per_device=schedule.virtual_per_stage)
        ops_by_phase: dict[tuple[int, str], list] = {}
        for op in graph.ops:
            if op.kind in ("placeholder", "parameter"):
                continue
            phase = "bwd" if op.attrs.get("phase") == "bwd" else "fwd"
            ops_by_phase.setdefault(
                (stage_of[id(op)], phase), []).append(op)
        envs = [self._leaf_env(compiled, st) for st in states]
        ran = [0] * len(states)
        if self.record_ticks:
            self.last_tick_device_seconds = {}
        for tick in schedule.ticks:          # already (slot, stage) sorted
            env = envs[tick.microbatch]
            ops = ops_by_phase.get((tick.stage, tick.phase), ())
            dev_acc: dict[int, list[float]] | None = \
                {} if (self.record_ticks and ops) else None
            for op in ops:
                try:
                    self._exec_op(op, env, compiled, plans, dev_acc)
                except KeyError as e:
                    raise ScheduleError(
                        f"stage {tick.stage} ({tick.phase}) ran before "
                        f"its input {e} was produced (invalid "
                        f"schedule)") from None
                ran[tick.microbatch] += 1
            if dev_acc is not None:
                self.last_tick_device_seconds.setdefault(
                    (tick.stage, tick.phase), []).append(dev_acc)
        n_ops = sum(len(v) for v in ops_by_phase.values())
        if any(r != n_ops for r in ran):
            raise ScheduleError(
                f"schedule executed {ran} of {n_ops} ops per microbatch")
        return [{f: env[f] for f in fetches} for env in envs]


class JaxExecutor:
    """Real-device execution: one shard_map program per compiled plan."""

    name = "jax"
    supported_schedules = SCHEDULES

    def __init__(self, mesh=None, *, reduction: str = "exact"):
        import weakref
        self.mesh = mesh
        self.reduction = reduction
        # keyed by the CompiledPlan object itself (weakly, so dropped
        # plans evict their traced programs and dead ids can't alias)
        self._cache: "weakref.WeakKeyDictionary[CompiledPlan, dict]" = \
            weakref.WeakKeyDictionary()

    def lowered(self, compiled: CompiledPlan,
                fetches: Sequence[str] | None = None,
                num_microbatches: int = 1):
        """The (cached) LoweredGraph for this plan + fetch list."""
        from repro.runtime.program import lower_graph
        per_plan = self._cache.get(compiled)
        if per_plan is None:
            per_plan = self._cache[compiled] = {}
        key = (tuple(fetches) if fetches else None, num_microbatches)
        lw = per_plan.get(key)
        if lw is None:
            lw = lower_graph(compiled.graph, compiled.strategy_index,
                             shape_env=compiled.shape_env, mesh=self.mesh,
                             topology=compiled.topology,
                             reduction=self.reduction,
                             fetches=list(fetches) if fetches else None,
                             num_microbatches=num_microbatches)
            per_plan[key] = lw
        return lw

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        return self.lowered(compiled, fetches).run(state)

    def run_schedule(self, compiled: CompiledPlan,
                     schedule: PipelineSchedule,
                     states: Sequence[dict[str, ShardedTensor]],
                     fetches: Sequence[str] | None = None
                     ) -> list[dict[str, ShardedTensor]]:
        """All microbatches in ONE shard_map program: the body scans over
        the stacked microbatch axis, keeping the per-device ``lax.switch``
        branches of the unpipelined path.  The explicit timetable is the
        simulator's contract; on real devices XLA's dependence order
        realizes the same pipeline — including interleaved virtual
        stages, where a device's branch contains the ops of ALL its
        chunks and the cross-chunk comm lowerings route activations
        around the ring ``v`` times — so the schedule only sizes the
        program here."""
        if len(states) != schedule.num_microbatches:
            raise ScheduleError(
                f"{len(states)} microbatch states for a "
                f"{schedule.num_microbatches}-microbatch schedule")
        lw = self.lowered(compiled, fetches,
                          num_microbatches=len(states))
        return lw.run_microbatches(list(states))


def _executor_registry() -> dict:
    # AsyncExecutor lives in runtime/ (it is a lowering, like
    # LoweredGraph); imported lazily to keep api importable without
    # pulling the runtime package at module load
    from repro.runtime.async_program import AsyncExecutor
    return {"sim": SimulatorExecutor, "jax": JaxExecutor,
            "async": AsyncExecutor}


def get_executor(name: str, **kwargs) -> Executor:
    """Executor registry: ``"sim"``, ``"jax"`` or ``"async"``
    (deprecation-friendly string form used by CLI flags and old call
    sites).  Unknown names raise ``ValueError`` listing the valid
    options; unknown options raise ``TypeError`` instead of vanishing
    silently."""
    registry = _executor_registry()
    cls = registry.get(name)
    if cls is None:
        raise ValueError(
            f"unknown executor {name!r} "
            f"(have: {', '.join(sorted(registry))})")
    return cls(**kwargs)  # unknown kwargs raise TypeError
