"""Pluggable executors: run a CompiledPlan's per-device ExecItems.

The :class:`Executor` protocol is the seam between planning and
execution.  Two implementations ship:

* :class:`SimulatorExecutor` — interprets the specialized per-device
  programs with numpy over the virtual-device simulator
  (``core.simulator``): compute ops apply the shared local semantics
  (``core.op_semantics``) shard-by-shard, CommOps run ``apply_plan``.
  Works for any device count, no accelerator needed — the executable
  specification.
* :class:`JaxExecutor` — lowers the whole graph (compute AND comm) into
  one ``jax.shard_map`` program on real devices
  (``runtime.program.LoweredGraph``) and caches the compiled program per
  (strategy, fetches).  Bit-exactness against the SimulatorExecutor is
  what ``runtime.selftest`` checks on 2/4/8 forced CPU devices.

Both take and return ``{name: ShardedTensor}`` — per-device shards under
the strategy's deduced annotations — so results are comparable
shard-by-shard, bitwise.  Output dtypes follow one shared rule
(``op_semantics.result_dtype``); bitwise parity is guaranteed for
exactly-representable computations (the differential tests' integer-
valued shards through dot/add/relu and all comm), while transcendental
kernels (gelu) may differ in the final ulp between numpy and XLA.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.op_semantics import local_apply, result_dtype
from repro.core.simulator import ShardedTensor, apply_plan

from .program import CompiledPlan


@runtime_checkable
class Executor(Protocol):
    """Anything that can run a CompiledPlan over sharded state."""

    name: str

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        """Execute; ``state`` maps every leaf tensor (placeholders and
        parameters) to its ShardedTensor.  Returns the fetched tensors
        (default: graph sinks) as ShardedTensors."""
        ...


class SimulatorExecutor:
    """Numpy interpretation of the specialized per-device programs."""

    name = "sim"

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        graph, k = compiled.graph, compiled.strategy_index
        shapes = compiled.shapes
        plans = {id(rc.op): rc.plan for rc in
                 compiled.specialization.resolved}
        fetches = list(fetches or [t.name for t in graph.sinks()])
        for f in fetches:  # fail up front, like LoweredGraph does
            if f not in graph.tensors:
                raise ValueError(f"unknown fetch tensor {f!r}")
        env: dict[str, ShardedTensor] = {}
        for op in graph.ops:
            out_t = op.outputs[0] if op.outputs else None
            if op.kind in ("placeholder", "parameter"):
                if out_t.name not in state:
                    raise ValueError(f"missing leaf tensor {out_t.name!r}")
                env[out_t.name] = state[out_t.name]
                continue
            if op.kind == "comm":
                env[out_t.name] = apply_plan(env[op.inputs[0].name],
                                             plans[id(op)])
                continue
            annot = out_t.annots[k]
            out_shape = shapes[out_t.name]
            dtype = result_dtype(op.kind,
                                 [env[t.name].dtype for t in op.inputs])
            parts: dict[int, np.ndarray] = {}
            for dev in annot.devices:
                locs = [env[t.name].parts[dev] for t in op.inputs]
                out_local = tuple(annot.device_shape(dev, out_shape))
                parts[dev] = np.asarray(local_apply(
                    op.kind, np, locs, op.attrs, out_local)).astype(
                    dtype, copy=False)
            env[out_t.name] = ShardedTensor(out_shape, annot, parts)
        return {f: env[f] for f in fetches}


class JaxExecutor:
    """Real-device execution: one shard_map program per compiled plan."""

    name = "jax"

    def __init__(self, mesh=None, *, reduction: str = "exact"):
        import weakref
        self.mesh = mesh
        self.reduction = reduction
        # keyed by the CompiledPlan object itself (weakly, so dropped
        # plans evict their traced programs and dead ids can't alias)
        self._cache: "weakref.WeakKeyDictionary[CompiledPlan, dict]" = \
            weakref.WeakKeyDictionary()

    def lowered(self, compiled: CompiledPlan,
                fetches: Sequence[str] | None = None):
        """The (cached) LoweredGraph for this plan + fetch list."""
        from repro.runtime.program import lower_graph
        per_plan = self._cache.get(compiled)
        if per_plan is None:
            per_plan = self._cache[compiled] = {}
        key = tuple(fetches) if fetches else None
        lw = per_plan.get(key)
        if lw is None:
            lw = lower_graph(compiled.graph, compiled.strategy_index,
                             shape_env=compiled.shape_env, mesh=self.mesh,
                             topology=compiled.topology,
                             reduction=self.reduction,
                             fetches=list(fetches) if fetches else None)
            per_plan[key] = lw
        return lw

    def run(self, compiled: CompiledPlan,
            state: dict[str, ShardedTensor],
            fetches: Sequence[str] | None = None
            ) -> dict[str, ShardedTensor]:
        return self.lowered(compiled, fetches).run(state)


def get_executor(name: str, **kwargs) -> Executor:
    """Executor registry: ``"sim"`` or ``"jax"`` (deprecation-friendly
    string form used by CLI flags and old call sites)."""
    if name == "sim":
        return SimulatorExecutor()
    if name == "jax":
        return JaxExecutor(**kwargs)
    raise ValueError(f"unknown executor {name!r} (have: sim, jax)")
