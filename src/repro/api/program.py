"""`Program`: one single-device graph + N strategies, compiled per strategy.

``program.compile(strategy)`` runs the paper's full front half —
annotation deduction (§5.2), hierarchical communication resolution (§4),
progressive per-device specialization and pipeline construction
(§5.3-5.4) — and returns a :class:`CompiledPlan`: per-device ExecItems,
resolved comm plans, pipelines, and an analytic cost/roofline estimate.
A CompiledPlan is inert data; executing it is an
:class:`~repro.api.executors.Executor`'s job.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from repro.core import op_semantics
from repro.core.graph import DeductionReport, GradError, Graph
from repro.core.plan import CommPlan
from repro.core.schedule import (PipelineSchedule, build_schedule,
                                 infer_virtual_stages, microbatch_graph,
                                 microbatch_roles)
from repro.core.specialize import (ExecItem, ExecutableGraph,
                                   SpecializationResult, specialize_all)
from repro.core.symbolic import bind_shape, free_symbols
from repro.core.topology import Topology, UniformTopology

from .strategy import Strategy, StrategyError


class CompileError(ValueError):
    pass


# stable default so memoized compiles keyed on topology identity can hit
_DEFAULT_TOPOLOGY = UniformTopology()


@dataclass(frozen=True)
class CostEstimate:
    """Analytic cost terms of one compiled strategy (roofline inputs)."""

    flops: int                      # global compute work
    comm_bytes: int                 # bytes crossing device boundaries
    comm_messages: int              # collective / p2p launches
    est_comm_seconds: float         # priced on the strategy topology
    per_kind_bytes: dict[str, int] = field(default_factory=dict)

    def roofline_seconds(self, peak_flops: float) -> float:
        """max(compute, comm) completion-time proxy at ``peak_flops``."""
        return max(self.flops / max(peak_flops, 1.0),
                   self.est_comm_seconds)

    def summary(self) -> str:
        kinds = ",".join(f"{k}:{v / 1e6:.2f}MB"
                         for k, v in sorted(self.per_kind_bytes.items()))
        return (f"{self.flops / 1e6:.2f} MFLOP, "
                f"{self.comm_bytes / 1e6:.2f} MB comm in "
                f"{self.comm_messages} msgs "
                f"(~{self.est_comm_seconds * 1e3:.2f} ms) [{kinds}]")


@dataclass(eq=False)  # identity semantics: executors cache per plan object
class CompiledPlan:
    """Result of ``Program.compile``: everything an Executor needs."""

    graph: Graph
    strategy: Strategy
    strategy_index: int
    shapes: dict[str, tuple[int, ...]]
    shape_env: dict[str, int]
    topology: Topology
    specialization: SpecializationResult
    cost: CostEstimate
    # set on micro-plans (Program.compile_micro): how many microbatches the
    # shapes were scaled down by, and each tensor's microbatch role
    num_microbatches: int = 1
    mb_roles: dict[str, int] | None = None
    # set on TRAIN plans (Program.compile_train): autodiff provenance —
    # forward tensor name -> gradient tensor name, and the loss tensor
    grad_map: dict[str, str] | None = None
    loss_name: str | None = None
    _schedules: dict = field(default_factory=dict, repr=False)
    _n_virtual: int | None = field(default=None, repr=False)

    @property
    def devices(self) -> tuple[int, ...]:
        return self.specialization.devices

    @property
    def n_stages(self) -> int:
        """PHYSICAL pipeline depth of this strategy (1 when nothing is
        staged); with interleaving each physical stage holds
        ``virtual_stages_per_device`` model chunks."""
        return max((len(p.stages)
                    for p in self.specialization.pipelines), default=1)

    @property
    def virtual_stages_per_device(self) -> int:
        """Megatron's ``v``: how many model chunks this graph's dataflow
        places on each physical stage (1 unless the strategy routes the
        graph around the device ring more than once — such plans can
        only be scheduled with ``schedule="interleaved"``)."""
        if self._n_virtual is None:
            self._n_virtual = infer_virtual_stages(
                self.graph, self.strategy_index,
                self.specialization.pipelines)
        return self._n_virtual

    def schedule(self, num_microbatches: int, kind: str = "1f1b",
                 virtual_stages_per_device: int | None = None
                 ) -> PipelineSchedule:
        """The explicit (slot, stage, microbatch, phase) timetable this
        plan's pipelines follow for ``num_microbatches`` (memoized).
        ``kind="interleaved"`` defaults ``virtual_stages_per_device`` to
        the plan's deduced chunk count; other kinds require v=1."""
        v = virtual_stages_per_device
        if v is None:
            v = self.virtual_stages_per_device if kind == "interleaved" \
                else 1
        key = (num_microbatches, kind, v)
        cached = self._schedules.get(key)
        if cached is None:
            cached = self._schedules[key] = build_schedule(
                self.n_stages, num_microbatches, kind,
                virtual_stages_per_device=v)
        return cached

    def tick_durations(self, flops_per_second: float = 1e12,
                       virtual_stages_per_device: int | None = None
                       ) -> dict[tuple[int, str], float]:
        """MEASURED per-(virtual stage, phase) tick durations from this
        plan's own graph: each (chunk, phase) slot is priced by the real
        FLOPs of the ops assigned to it (autodiff backward ops fill the
        ``bwd`` slots of a train plan; forward-only plans price bwd as
        0).  Feed to ``schedule.stats(durations)`` /
        ``core.schedule.price_schedule`` to re-time a timetable — the
        measured replacement for the cost model's fwd:bwd = 1:2
        assumption."""
        from repro.core.costmodel import graph_tick_durations
        v = virtual_stages_per_device or self.virtual_stages_per_device
        return graph_tick_durations(
            self.graph, self.strategy_index,
            self.specialization.pipelines, v, self.shapes,
            flops_per_second)

    def fwd_fraction(self) -> float:
        """The fwd share of this plan's compute FLOPs
        (:func:`~repro.core.costmodel.measured_fwd_fraction`; the
        analytic 1/3 for forward-only plans)."""
        from repro.core.costmodel import measured_fwd_fraction
        return measured_fwd_fraction(
            self.graph, self.strategy_index,
            self.specialization.pipelines,
            self.virtual_stages_per_device, self.shapes)

    def predicted_step_seconds(self, num_microbatches: int,
                               kind: str = "1f1b", *,
                               flops_per_second: float = 1e12,
                               virtual_stages_per_device: int | None = None
                               ) -> float:
        """Makespan of this plan's own timetable under its MEASURED
        per-tick durations: ``schedule(m).stats(tick_durations())`` — the
        plan-level prediction the search subsystem compares against
        executed step times (scale-free up to ``flops_per_second``)."""
        v = virtual_stages_per_device
        if v is None:
            v = self.virtual_stages_per_device if kind == "interleaved" \
                else 1
        sched = self.schedule(num_microbatches, kind,
                              virtual_stages_per_device=v)
        durations = self.tick_durations(flops_per_second,
                                        virtual_stages_per_device=v)
        return sched.stats(durations).makespan

    @property
    def comm_plans(self) -> list[CommPlan]:
        return [rc.plan for rc in self.specialization.resolved]

    def exec_items(self, device: int) -> list[ExecItem]:
        """This device's executable graph (paper Fig 9)."""
        return self.specialization.exec_graphs[device].items

    def exec_graph(self, device: int) -> ExecutableGraph:
        return self.specialization.exec_graphs[device]

    def describe(self) -> str:
        lines = [f"CompiledPlan[{self.strategy.name}] over "
                 f"{len(self.devices)} device(s), "
                 f"{len(self.specialization.pipelines)} pipeline(s)"]
        for p in self.specialization.pipelines:
            lines.append("  pipeline: " + " -> ".join(
                str(sorted(s)) for s in p.stages))
        for rc in self.specialization.resolved:
            lines.append(f"  comm {rc.op.outputs[0].name}: {rc.plan.kind}")
        lines.append("  cost: " + self.cost.summary())
        return "\n".join(lines)


def _estimate_cost(graph: Graph, shapes, resolved,
                   topology: Topology) -> CostEstimate:
    flops = 0
    for op in graph.ops:
        if op.kind in ("placeholder", "parameter", "comm"):
            continue
        flops += op_semantics.flops(
            op.kind, [shapes[t.name] for t in op.inputs],
            shapes[op.outputs[0].name], op.attrs)
    comm_bytes = 0
    messages = 0
    est_s = 0.0
    per_kind: dict[str, int] = {}
    for rc in resolved:
        plan = rc.plan
        comm_bytes += plan.nbytes_moved()
        messages += plan.message_count()
        for step in plan.steps:
            nb = step.nbytes_moved()
            per_kind[step.kind] = per_kind.get(step.kind, 0) + nb
            for g in step.groups:
                worst = max((topology.time_for(s, d, nb)
                             for s in g.srcs for d in g.dsts if s != d),
                            default=0.0)
                est_s += worst / max(len(step.groups), 1)
    return CostEstimate(flops, comm_bytes, messages, est_s, per_kind)


class Program:
    """A single-device graph bound to N named strategies."""

    def __init__(self, graph: Graph, strategies: Sequence[Strategy]):
        import copy
        if not strategies:
            raise StrategyError("Program needs at least one strategy")
        names = [s.name for s in strategies]
        if len(set(names)) != len(names):
            raise StrategyError(f"duplicate strategy names in {names}")
        for s in strategies:
            s.validate_against(graph)
        # own a private copy: installing annotations must not corrupt a
        # graph another Program (and its live Sessions) already wraps
        self.graph = copy.deepcopy(graph)
        self.strategies = list(strategies)
        points = set()
        for t in self.graph.annotation_points():
            t.annots = [s.annots[t.name] for s in strategies]
            points.add(id(t))
        for t in self.graph.tensors.values():
            if id(t) not in points:
                # stale deduced annots from a prior deduce() would skew
                # deduce's strategy count; they are recomputed anyway
                t.annots = []
        self.report: DeductionReport = self.graph.deduction_report()
        self._compile_cache: dict[tuple, CompiledPlan] = {}
        self._joint_cache: dict[str, Graph] = {}

    @classmethod
    def from_annotated(cls, graph: Graph,
                       names: Sequence[str] | None = None) -> "Program":
        """Wrap a graph whose leaves already carry (multi-)annotations —
        the pre-API construction style, kept importable as a shim."""
        import copy
        graph = copy.deepcopy(graph)
        report = graph.deduction_report()  # deduces (once)
        points = graph.annotation_points()
        n = report.n_strategies
        names = list(names or (f"s{i}" for i in range(n)))
        if len(names) != n:
            raise StrategyError(
                f"{len(names)} names for {n} annotation strategies")
        if len(set(names)) != len(names):
            raise StrategyError(f"duplicate strategy names in {names}")
        strategies = [
            Strategy(names[k], {t.name: t.annots[k] for t in points})
            for k in range(n)]
        prog = cls.__new__(cls)
        prog.graph = graph
        prog.strategies = strategies
        prog.report = report
        prog._compile_cache = {}
        prog._joint_cache = {}
        return prog

    # -- lookup ------------------------------------------------------------
    @property
    def names(self) -> list[str]:
        return [s.name for s in self.strategies]

    def index(self, strategy: "Strategy | str | int") -> int:
        if isinstance(strategy, int):
            if not 0 <= strategy < len(self.strategies):
                raise StrategyError(f"strategy index {strategy} out of "
                                    f"range; have {self.names}")
            return strategy
        name = strategy.name if isinstance(strategy, Strategy) else strategy
        for i, s in enumerate(self.strategies):
            if s.name == name:
                return i
        raise StrategyError(f"unknown strategy {name!r}; have {self.names}")

    def strategy(self, strategy: "Strategy | str | int") -> Strategy:
        return self.strategies[self.index(strategy)]

    def add_strategy(self, strategy: Strategy) -> int:
        """Register a strategy discovered AFTER construction (the elastic
        driver's mid-run re-selection path) and return its index.

        A same-name strategy with identical annotations is a no-op (its
        existing index is returned — compiled plans stay memoized); a
        same-name strategy with DIFFERENT annotations is rejected, since
        strategies compare by name and silently rebinding one would
        poison every cache keyed on its index.  Appending re-runs
        deduction over all strategies — deterministic, so previously
        compiled plans and indices remain valid — and invalidates only
        the joint fwd+bwd graphs (their backward comm ops carry
        per-strategy annotations that cannot be extended in place)."""
        if strategy.name in self.names:
            k = self.index(strategy.name)
            if self.strategies[k].annots == strategy.annots:
                return k
            raise StrategyError(
                f"strategy {strategy.name!r} already registered with "
                f"different annotations; pick a fresh name")
        strategy.validate_against(self.graph)
        self.strategies.append(strategy)
        points = set()
        for t in self.graph.annotation_points():
            t.annots.append(strategy.annots[t.name])
            points.add(id(t))
        for t in self.graph.tensors.values():
            if id(t) not in points:
                t.annots = []
        self.report = self.graph.deduction_report()
        # train plans cache joint graphs whose backward ops were comm-
        # resolved per strategy at build time; rebuild them on demand
        self._joint_cache.clear()
        self._compile_cache = {
            key: plan for key, plan in self._compile_cache.items()
            if key[0] != "train"}
        return len(self.strategies) - 1

    # -- compile -----------------------------------------------------------
    def compile(self, strategy: "Strategy | str | int", *,
                shape_env: dict[str, int] | None = None,
                topology: Topology | None = None) -> CompiledPlan:
        """Deduction -> comm resolution -> progressive specialization.

        Memoized per (strategy, shape_env, topology): switching back to
        an already-compiled strategy returns the SAME CompiledPlan object,
        so executors keep their traced programs (JaxExecutor's cache is
        keyed by plan identity — strategy flapping doesn't retrace).
        """
        k = self.index(strategy)
        strat = self.strategies[k]
        env = dict(shape_env or {})
        topology = topology or strat.topology or _DEFAULT_TOPOLOGY
        # id() is stable here: the cached plan keeps the topology alive
        key = (k, tuple(sorted(env.items())), id(topology))
        cached = self._compile_cache.get(key)
        if cached is not None:
            return cached
        plan = self._compile_graph(self.graph, k, env, topology)
        self._compile_cache[key] = plan
        return plan

    def compile_micro(self, strategy: "Strategy | str | int",
                      num_microbatches: int, *,
                      shape_env: dict[str, int] | None = None,
                      topology: Topology | None = None) -> CompiledPlan:
        """Compile the ONE-MICROBATCH plan: every Split-role shape scaled
        by ``1/num_microbatches`` (``core.schedule.microbatch_graph``),
        re-specialized so comm plans and exec items carry microbatch
        geometry.  Memoized like :meth:`compile`; ``num_microbatches=1``
        is exactly the full plan."""
        k = self.index(strategy)
        if num_microbatches < 1:
            raise CompileError(
                f"num_microbatches must be >= 1 (got {num_microbatches})")
        if num_microbatches == 1:
            return self.compile(strategy, shape_env=shape_env,
                                topology=topology)
        strat = self.strategies[k]
        env = dict(shape_env or {})
        topology = topology or strat.topology or _DEFAULT_TOPOLOGY
        key = (k, tuple(sorted(env.items())), id(topology),
               num_microbatches)
        cached = self._compile_cache.get(key)
        if cached is not None:
            return cached
        roles = microbatch_roles(self.graph)
        micro = microbatch_graph(self.graph, num_microbatches, roles,
                                 shape_env=env)
        plan = self._compile_graph(micro, k, env, topology)
        plan.num_microbatches = num_microbatches
        plan.mb_roles = roles
        self._compile_cache[key] = plan
        return plan

    def _resolve_loss(self, loss: str | None) -> str:
        """The loss tensor's NAME (default: the single scalar sink) —
        resolved before any cache lookup so ``loss=None`` and
        ``loss="L"`` share one joint graph and one train-plan line."""
        if loss is not None:
            if loss not in self.graph.tensors:
                raise CompileError(f"unknown loss tensor {loss!r}")
            return loss
        scalars = [t for t in self.graph.sinks() if tuple(t.shape) == ()]
        if len(scalars) != 1:
            raise CompileError(
                f"graph has {len(scalars)} scalar sink(s); pass loss= "
                f"to pick the tensor to differentiate")
        return scalars[0].name

    def _joint_graph(self, loss: str) -> Graph:
        """The fwd+bwd training graph: a private copy of the deduced
        graph extended with its reverse-mode backward pass
        (``core.graph.Graph.backward``), memoized per loss tensor and
        shared by every strategy (annotations are per-strategy lists)."""
        import copy
        cached = self._joint_cache.get(loss)
        if cached is None:
            joint = copy.deepcopy(self.graph)
            try:
                joint.backward(loss)
            except GradError as e:
                raise CompileError(f"cannot build the training graph: "
                                   f"{e}") from None
            cached = self._joint_cache[loss] = joint
        return cached

    def compile_train(self, strategy: "Strategy | str | int", *,
                      loss: str | None = None,
                      num_microbatches: int = 1,
                      shape_env: dict[str, int] | None = None,
                      topology: Topology | None = None) -> CompiledPlan:
        """Compile the JOINT fwd+bwd plan for one training step.

        The forward graph is extended with real backward ops (per-op
        VJPs, gradient comm resolved by §4 like any CommOp), then
        compiled through the normal specialization path — so the
        returned plan's ExecItems carry a ``bwd`` phase, its pipelines
        are the forward pipelines, and its timetables' ``bwd`` ticks
        finally execute gradient compute + grad-reduce comm.  With
        ``num_microbatches=m > 1`` the joint graph is microbatched
        (gradients carry the Partial role: they accumulate across
        microbatches).  ``plan.grad_map`` / ``plan.loss_name`` expose
        the autodiff provenance; memoized like :meth:`compile`.
        """
        k = self.index(strategy)
        if num_microbatches < 1:
            raise CompileError(
                f"num_microbatches must be >= 1 (got {num_microbatches})")
        strat = self.strategies[k]
        env = dict(shape_env or {})
        topology = topology or strat.topology or _DEFAULT_TOPOLOGY
        loss = self._resolve_loss(loss)
        key = ("train", k, tuple(sorted(env.items())), id(topology),
               num_microbatches, loss)
        cached = self._compile_cache.get(key)
        if cached is not None:
            return cached
        joint = self._joint_graph(loss)
        if num_microbatches == 1:
            plan = self._compile_graph(joint, k, env, topology)
        else:
            roles = microbatch_roles(joint)
            micro = microbatch_graph(joint, num_microbatches, roles,
                                     shape_env=env)
            plan = self._compile_graph(micro, k, env, topology)
            plan.num_microbatches = num_microbatches
            plan.mb_roles = roles
        plan.grad_map = dict(joint.grad_map)
        plan.loss_name = joint.loss_name
        self._compile_cache[key] = plan
        return plan

    def _compile_graph(self, graph: Graph, k: int, env: dict[str, int],
                       topology: Topology) -> CompiledPlan:
        shapes: dict[str, tuple[int, ...]] = {}
        for name, t in graph.tensors.items():
            syms = free_symbols(t.shape)
            if syms - set(env):
                raise CompileError(
                    f"tensor {name!r} has unbound symbolic dims "
                    f"{sorted(syms - set(env))}; pass shape_env")
            shapes[name] = bind_shape(t.shape, env)
        spec = specialize_all(graph, k, topology, env)
        cost = _estimate_cost(graph, shapes, spec.resolved, topology)
        return CompiledPlan(graph, self.strategies[k], k, shapes, env,
                            topology, spec, cost)
