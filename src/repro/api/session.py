"""`Session`: live sharded state + execution + dynamic strategy switching.

A Session owns the sharded weights of a Program under one active
strategy, executes steps through a pluggable
:class:`~repro.api.executors.Executor`, and — the paper's §6 headline —
switches strategies *without restart*: ``session.switch(new_strategy)``
re-shards every parameter through the fused-BSR migration plan and
returns the :class:`~repro.core.switching.SwitchReport` (message counts,
bytes over fast/slow links, planning + estimated transfer time).
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.schedule import (SCHEDULES, PipelineSchedule,
                                 ScheduleError, ScheduleStats,
                                 combine_outputs)
from repro.core.simulator import ShardedTensor, gather, scatter
from repro.core.switching import SwitchReport
from repro.core.switching import switch as core_switch
from repro.core.topology import Topology

from .executors import Executor, JaxExecutor, SimulatorExecutor
from .program import CompiledPlan, Program
from .strategy import Strategy


@dataclass
class TrainResult:
    """One training step's outcome: the (global) loss, the gradient
    shards that produced the update, optimizer metrics (grad_norm, lr),
    and — for microbatched steps — the executed pipeline timetable."""

    loss: float
    grads: dict[str, ShardedTensor]
    metrics: dict[str, float]
    schedule: PipelineSchedule | None = None
    outputs: dict[str, ShardedTensor] | None = None  # extra fetches

    @property
    def stats(self) -> "ScheduleStats | None":
        return self.schedule.stats() if self.schedule else None

    def grad_value(self, name: str) -> np.ndarray:
        """Reconstruct a parameter's global gradient."""
        return gather(self.grads[name])


@dataclass
class RunResult:
    """One step's fetched tensors, sharded per the active strategy.

    Microbatched runs also carry the pipeline ``schedule`` that was
    executed.  ``stats`` summarizes it as a
    :class:`~repro.core.schedule.ScheduleStats`: tick / bubble / p2p
    counts plus the *priced* ``makespan`` and ``bubble_fraction``
    (uniform tick durations here, so makespan == slot count; re-price
    with real per-(stage, phase) costs via
    ``result.schedule.stats(durations)`` or
    ``core.schedule.price_schedule``)."""

    outputs: dict[str, ShardedTensor]
    schedule: PipelineSchedule | None = None

    @property
    def stats(self) -> "ScheduleStats | None":
        return self.schedule.stats() if self.schedule else None

    def shards(self, name: str) -> ShardedTensor:
        return self.outputs[name]

    def value(self, name: str, check_dups: bool = True) -> np.ndarray:
        """Reconstruct the global value (asserts replicas agree)."""
        return gather(self.outputs[name], check_dups=check_dups)

    def values(self) -> dict[str, np.ndarray]:
        return {name: self.value(name) for name in self.outputs}


@dataclass
class MeasuredStep:
    """A timed :meth:`Session.measure_train_step` outcome."""

    seconds: float                   # median wall time per step
    result: TrainResult              # first measured step
    # per-(stage, phase) tick timings, one {device: [per-op seconds]}
    # per executed tick, pooled across repeats (None unless the
    # executor records ticks)
    tick_device_seconds: dict[tuple[int, str],
                              list[dict[int, list[float]]]] | None = None


class Session:
    """Live sharded state for one Program, on one Executor."""

    def __init__(self, program: Program, strategy: "Strategy | str | int",
                 *, executor: Executor | None = None,
                 shape_env: dict[str, int] | None = None,
                 topology: Topology | None = None, seed: int = 0,
                 optimizer=None):
        self.program = program
        self.executor: Executor = executor or SimulatorExecutor()
        self.shape_env = dict(shape_env or {})
        self.topology = topology
        self.seed = seed
        self.weights: dict[str, ShardedTensor] = {}
        self.plan: CompiledPlan = program.compile(
            strategy, shape_env=self.shape_env, topology=topology)
        # training state (train_step): AdamW config + sharded m/v/count,
        # created lazily on the first step and resharded by switch()
        self.optimizer = optimizer
        self.opt_state: dict | None = None

    # -- state -------------------------------------------------------------
    @property
    def strategy(self) -> Strategy:
        return self.plan.strategy

    def _shard(self, name: str, value) -> ShardedTensor:
        if isinstance(value, ShardedTensor):
            return value
        annot = self.program.graph.tensors[name].annots[
            self.plan.strategy_index]
        return scatter(np.asarray(value), annot,
                       rng=np.random.default_rng(self.seed))

    def load(self, values: Mapping[str, object]) -> None:
        """Install parameter values (global arrays are scattered per the
        active strategy; ShardedTensors are taken as-is)."""
        params = {t.name for t in self.program.graph.parameters()}
        for name, value in values.items():
            if name not in params:
                raise ValueError(f"{name!r} is not a parameter "
                                 f"(have {sorted(params)})")
            self.weights[name] = self._shard(name, value)

    def weight_value(self, name: str) -> np.ndarray:
        return gather(self.weights[name])

    # -- execution ---------------------------------------------------------
    def run(self, feeds: Mapping[str, object] | None = None,
            fetches: Sequence[str] | None = None, *,
            num_microbatches: int = 1,
            schedule: str = "1f1b",
            virtual_stages_per_device: int | None = None) -> RunResult:
        """Execute one step: placeholders come from ``feeds`` (global
        arrays or ShardedTensors), parameters from session state.

        With ``num_microbatches=m > 1`` the step runs as a pipeline:
        batch-dim feeds are split into ``m`` microbatches, the plan's
        pipelines execute the explicit ``schedule`` ("1f1b", "gpipe" or
        "interleaved") timetable, and per-microbatch outputs are reduced
        by their microbatch role — losses/gradients (Partial) accumulate
        in microbatch order, batch-split outputs concatenate, parameters
        (Duplicate) pass through.  ``m=1`` is exactly the unpipelined
        path.

        ``schedule="interleaved"`` runs Megatron's virtual-stage 1F1B:
        each physical stage holds ``virtual_stages_per_device`` model
        chunks (default: the plan's deduced chunk count — how many times
        the strategy routes the dataflow around the device ring), the
        timetable spans ``S*v`` virtual stages, and ``m`` must be
        divisible by (or at most) the physical stage count.  Plans whose
        dataflow wraps (v > 1) can ONLY run interleaved; ``"1f1b"`` /
        ``"gpipe"`` on them raise :class:`ScheduleError`.

        The executed timetable comes back on ``RunResult.schedule``;
        ``RunResult.stats`` summarizes it (ticks, bubbles, p2p messages,
        and the priced makespan / bubble fraction — uniform tick
        durations here; pass costmodel durations to
        ``result.schedule.stats(durations)`` to price a real cluster).
        """
        feeds = dict(feeds or {})
        self._validate_schedule_kind(schedule, virtual_stages_per_device)
        if num_microbatches == 1:
            state = self._leaf_state(feeds)
            outs = self.executor.run(self.plan, state, fetches)
            return RunResult(outs)
        mplan = self.program.compile_micro(
            self.plan.strategy_index, num_microbatches,
            shape_env=self.shape_env, topology=self.topology)
        per_mb, sched = self._run_pipelined(
            mplan, feeds, fetches, schedule, virtual_stages_per_device)
        outs = self._combine(per_mb, mplan, full_plan=self.plan)
        return RunResult(outs, schedule=sched)

    def _validate_schedule_kind(self, schedule: str, v: int | None) -> None:
        """Knob validation up front — an unknown ``schedule=`` string
        fails here with the valid kinds listed, for every microbatch
        count, instead of deep inside ``build_schedule``."""
        if schedule not in SCHEDULES:
            raise ScheduleError(
                f"unknown schedule {schedule!r}; valid kinds are "
                f"{', '.join(repr(s) for s in SCHEDULES)}")
        supported = getattr(self.executor, "supported_schedules", None)
        if supported is not None and schedule not in supported:
            raise ScheduleError(
                f"executor {getattr(self.executor, 'name', '?')!r} does "
                f"not support schedule {schedule!r}; it supports "
                f"{', '.join(repr(s) for s in supported)}")
        if schedule != "interleaved" and v not in (None, 1):
            raise ScheduleError(
                f"virtual_stages_per_device={v} requires "
                f"schedule='interleaved' (got {schedule!r})")

    def _run_pipelined(self, mplan: CompiledPlan, feeds: dict, fetches,
                       schedule: str, v: int | None):
        """Shared microbatched-execution path of run/train_step: split
        feeds, build per-microbatch leaf states, execute the timetable
        on the session executor.  Returns (per-microbatch fetches,
        executed schedule)."""
        inferred = mplan.virtual_stages_per_device
        if schedule == "interleaved":
            v = inferred if v is None else v
            if v < inferred:
                raise ScheduleError(
                    f"plan interleaves {inferred} chunk(s) per device; "
                    f"virtual_stages_per_device={v} is too small")
        else:
            if inferred > 1:
                raise ScheduleError(
                    f"plan interleaves {inferred} chunks per device; "
                    f"run it with schedule='interleaved'")
            v = 1
        sched = mplan.schedule(mplan.num_microbatches, schedule,
                               virtual_stages_per_device=v)
        micro_feeds = self._split_feeds(feeds, mplan)
        states = []
        for j in range(mplan.num_microbatches):
            st: dict[str, ShardedTensor] = {}
            for t in mplan.graph.placeholders():
                annot = mplan.graph.tensors[t.name].annots[
                    mplan.strategy_index]
                st[t.name] = scatter(
                    micro_feeds[j][t.name], annot,
                    rng=np.random.default_rng(self.seed))
            for t in mplan.graph.parameters():
                if t.name not in self.weights:
                    raise ValueError(
                        f"parameter {t.name!r} not loaded; call "
                        f"session.load")
                st[t.name] = self.weights[t.name]
            states.append(st)
        if hasattr(self.executor, "run_schedule"):
            per_mb = self.executor.run_schedule(mplan, sched, states,
                                                fetches)
        else:  # third-party executors: host-level microbatch loop
            per_mb = [self.executor.run(mplan, st, fetches)
                      for st in states]
        return per_mb, sched

    def _combine(self, per_mb, mplan: CompiledPlan,
                 full_plan: CompiledPlan) -> dict[str, ShardedTensor]:
        """Reduce per-microbatch fetches by role (Partial accumulates,
        Split concatenates); full-batch shapes/annots come from the
        unmicrobatched plan over the same graph."""
        k = mplan.strategy_index
        return combine_outputs(
            per_mb, mplan.mb_roles,
            {name: full_plan.shapes[name] for name in per_mb[0]},
            {name: full_plan.graph.tensors[name].annots[k]
             for name in per_mb[0]})

    # -- training ----------------------------------------------------------
    def train_step(self, feeds: Mapping[str, object] | None = None, *,
                   num_microbatches: int = 1,
                   schedule: str = "1f1b",
                   virtual_stages_per_device: int | None = None,
                   loss: str | None = None,
                   fetches: Sequence[str] = ()) -> TrainResult:
        """One full training step on the session executor: forward ->
        backward -> gradient reduce -> AdamW, restart-free.

        The joint fwd+bwd graph (``Program.compile_train``) runs exactly
        like ``run``: unpipelined for ``num_microbatches=1``, otherwise
        as the explicit 1F1B / GPipe / interleaved timetable whose
        ``bwd`` ticks execute the real backward ExecItems; per-microbatch
        gradients carry the Partial role and accumulate bit-exactly in
        microbatch order.  Gradients arrive sharded EXACTLY like their
        parameters (the backward pass's grad-reduce comm: all-reduce for
        replicated params, reduce-scatter over the DP dim for Split
        params), so the AdamW update (``optim.adamw.sharded_apply_
        updates``) is elementwise per shard; optimizer state mirrors the
        weight sharding and is migrated by :meth:`switch`.

        ``loss`` defaults to the graph's single scalar sink; ``fetches``
        may name extra tensors (activations, activation grads via
        ``plan.grad_map``) to return on ``TrainResult.outputs``.
        """
        from repro.optim.adamw import (AdamWConfig, init_sharded_state,
                                       sharded_apply_updates)

        feeds = dict(feeds or {})
        self._validate_schedule_kind(schedule, virtual_stages_per_device)
        if self.optimizer is None:
            self.optimizer = AdamWConfig()
        k = self.plan.strategy_index
        tplan = self.program.compile_train(
            k, loss=loss, num_microbatches=num_microbatches,
            shape_env=self.shape_env, topology=self.topology)
        params = [t.name for t in tplan.graph.parameters()]
        for name in params:
            if name not in self.weights:
                raise ValueError(
                    f"parameter {name!r} not loaded; call session.load")
        grad_fetch = [tplan.grad_map[p] for p in params]
        fetch_list = [tplan.loss_name] + grad_fetch + list(fetches)
        sched = None
        if num_microbatches == 1:
            state = dict(self._leaf_state(dict(feeds)))
            outs = self.executor.run(tplan, state, fetch_list)
        else:
            per_mb, sched = self._run_pipelined(
                tplan, feeds, fetch_list, schedule,
                virtual_stages_per_device)
            full = self.program.compile_train(
                k, loss=loss, shape_env=self.shape_env,
                topology=self.topology)
            outs = self._combine(per_mb, tplan, full_plan=full)
        loss_value = float(gather(outs[tplan.loss_name]))
        grads = {p: outs[g] for p, g in zip(params, grad_fetch)}
        if self.opt_state is None:
            self.opt_state = init_sharded_state(self.weights)
        self.weights, self.opt_state, metrics = sharded_apply_updates(
            self.weights, grads, self.opt_state, self.optimizer)
        metrics["loss"] = loss_value
        extra = {f: outs[f] for f in fetches}
        return TrainResult(loss_value, grads, metrics, schedule=sched,
                           outputs=extra)

    def measure_train_step(self, feeds: Mapping[str, object] | None = None,
                           *, repeats: int = 3, warmup: int = 1,
                           **train_kw) -> "MeasuredStep":
        """Run :meth:`train_step` ``warmup + repeats`` times and report
        the median wall seconds of the measured calls, plus — when the
        executor records per-tick device timings
        (``SimulatorExecutor(record_ticks=True)``) — the per-(stage,
        phase) tick timings pooled across repeats, which the search
        validator re-prices into a parallel makespan.  Weights DO
        advance (each call is a real optimizer step); ``result`` is the
        first measured step's :class:`TrainResult`."""
        walls: list[float] = []
        ticks: dict[tuple[int, str], list[dict[int, float]]] = {}
        result: TrainResult | None = None
        for i in range(warmup + repeats):
            t0 = time.perf_counter()
            r = self.train_step(feeds, **train_kw)
            dt = time.perf_counter() - t0
            if i < warmup:
                continue
            walls.append(dt)
            if result is None:
                result = r
            rec = getattr(self.executor, "last_tick_device_seconds",
                          None)
            if rec:
                for key, occurrences in rec.items():
                    ticks.setdefault(key, []).extend(occurrences)
        assert result is not None  # repeats >= 1
        return MeasuredStep(statistics.median(walls), result,
                            ticks or None)

    def _leaf_state(self, feeds: dict) -> dict[str, ShardedTensor]:
        state: dict[str, ShardedTensor] = {}
        for t in self.program.graph.placeholders():
            if t.name not in feeds:
                raise ValueError(f"missing feed for placeholder {t.name!r}")
            state[t.name] = self._shard(t.name, feeds.pop(t.name))
        if feeds:
            raise ValueError(f"unknown feeds {sorted(feeds)}")
        for t in self.program.graph.parameters():
            if t.name not in self.weights:
                raise ValueError(
                    f"parameter {t.name!r} not loaded; call session.load")
            state[t.name] = self.weights[t.name]
        return state

    def _split_feeds(self, feeds: dict, mplan: CompiledPlan
                     ) -> list[dict[str, np.ndarray]]:
        """Split every placeholder feed along its batch dim into the
        micro plan's ``num_microbatches`` slices."""
        m = mplan.num_microbatches
        out: list[dict[str, np.ndarray]] = [{} for _ in range(m)]
        for t in self.program.graph.placeholders():
            if t.name not in feeds:
                raise ValueError(f"missing feed for placeholder {t.name!r}")
            value = feeds.pop(t.name)
            if isinstance(value, ShardedTensor):
                raise ValueError(
                    f"microbatched runs take GLOBAL arrays for feeds; "
                    f"{t.name!r} is a ShardedTensor")
            value = np.asarray(value)
            d = mplan.mb_roles[t.name]
            if value.shape[d] % m != 0:
                raise ValueError(
                    f"feed {t.name!r} batch dim {value.shape[d]} not "
                    f"divisible by {m} microbatches")
            for j, piece in enumerate(np.split(value, m, axis=d)):
                out[j][t.name] = piece
        if feeds:
            raise ValueError(f"unknown feeds {sorted(feeds)}")
        return out

    # -- dynamic switching (§6) --------------------------------------------
    def switch(self, strategy: "Strategy | str | int") -> SwitchReport:
        """Fused-BSR migration of all weights to ``strategy``; the session
        continues restart-free under the new compiled plan.

        ``strategy`` may be a Strategy object the Program has never seen
        (the elastic driver's mid-run re-selection): it is registered via
        :meth:`Program.add_strategy` first.  The returned report carries
        the measured end-to-end ``wall_seconds`` of the whole switch plus
        ``src_name``/``dst_name``."""
        t_wall = time.perf_counter()
        if isinstance(strategy, Strategy):
            dst = self.program.add_strategy(strategy)
        else:
            dst = self.program.index(strategy)
        src = self.plan.strategy_index
        names = self.program.names
        # validate BEFORE the same-strategy fast path: switching with
        # unloaded weights is an error regardless of the destination
        missing = [t.name for t in self.program.graph.parameters()
                   if t.name not in self.weights]
        if missing:
            raise ValueError(f"cannot switch with unloaded parameters "
                             f"{missing}")
        if dst == src:
            from repro.core.bsr import BsrPlan
            return SwitchReport(plan=BsrPlan([]), planning_seconds=0.0,
                                est_transfer_seconds=0.0, total_bytes=0,
                                message_count=0,
                                wall_seconds=time.perf_counter() - t_wall,
                                src_name=names[src], dst_name=names[dst])
        backend = "jax" if isinstance(self.executor, JaxExecutor) else "sim"
        mesh = getattr(self.executor, "mesh", None)
        # same topology fallback as Program.compile: explicit session
        # topology first, then the destination strategy's own
        topology = self.topology or \
            self.program.strategies[dst].topology
        outcome = core_switch(
            self.weights, self.program.graph, src, dst, self.shape_env,
            topology, backend=backend, mesh=mesh)
        if self.opt_state is not None:
            # optimizer m/v mirror the weight annotations: migrate them
            # through the same fused-BSR plan so training resumes
            # restart-free after the switch
            from repro.core.switching import execute_switch
            for key in ("m", "v"):
                self.opt_state[key] = execute_switch(
                    self.opt_state[key], self.program.graph, src, dst,
                    self.shape_env, topology, backend=backend, mesh=mesh,
                    report=outcome.report)
        self.weights = outcome.weights
        self.plan = self.program.compile(dst, shape_env=self.shape_env,
                                         topology=self.topology)
        outcome.report.wall_seconds = time.perf_counter() - t_wall
        outcome.report.src_name = names[src]
        outcome.report.dst_name = names[dst]
        return outcome.report
