"""`Session`: live sharded state + execution + dynamic strategy switching.

A Session owns the sharded weights of a Program under one active
strategy, executes steps through a pluggable
:class:`~repro.api.executors.Executor`, and — the paper's §6 headline —
switches strategies *without restart*: ``session.switch(new_strategy)``
re-shards every parameter through the fused-BSR migration plan and
returns the :class:`~repro.core.switching.SwitchReport` (message counts,
bytes over fast/slow links, planning + estimated transfer time).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import numpy as np

from repro.core.simulator import ShardedTensor, gather, scatter
from repro.core.switching import SwitchReport
from repro.core.switching import switch as core_switch
from repro.core.topology import Topology

from .executors import Executor, JaxExecutor, SimulatorExecutor
from .program import CompiledPlan, Program
from .strategy import Strategy


@dataclass
class RunResult:
    """One step's fetched tensors, sharded per the active strategy."""

    outputs: dict[str, ShardedTensor]

    def shards(self, name: str) -> ShardedTensor:
        return self.outputs[name]

    def value(self, name: str, check_dups: bool = True) -> np.ndarray:
        """Reconstruct the global value (asserts replicas agree)."""
        return gather(self.outputs[name], check_dups=check_dups)

    def values(self) -> dict[str, np.ndarray]:
        return {name: self.value(name) for name in self.outputs}


class Session:
    """Live sharded state for one Program, on one Executor."""

    def __init__(self, program: Program, strategy: "Strategy | str | int",
                 *, executor: Executor | None = None,
                 shape_env: dict[str, int] | None = None,
                 topology: Topology | None = None, seed: int = 0):
        self.program = program
        self.executor: Executor = executor or SimulatorExecutor()
        self.shape_env = dict(shape_env or {})
        self.topology = topology
        self.seed = seed
        self.weights: dict[str, ShardedTensor] = {}
        self.plan: CompiledPlan = program.compile(
            strategy, shape_env=self.shape_env, topology=topology)

    # -- state -------------------------------------------------------------
    @property
    def strategy(self) -> Strategy:
        return self.plan.strategy

    def _shard(self, name: str, value) -> ShardedTensor:
        if isinstance(value, ShardedTensor):
            return value
        annot = self.program.graph.tensors[name].annots[
            self.plan.strategy_index]
        return scatter(np.asarray(value), annot,
                       rng=np.random.default_rng(self.seed))

    def load(self, values: Mapping[str, object]) -> None:
        """Install parameter values (global arrays are scattered per the
        active strategy; ShardedTensors are taken as-is)."""
        params = {t.name for t in self.program.graph.parameters()}
        for name, value in values.items():
            if name not in params:
                raise ValueError(f"{name!r} is not a parameter "
                                 f"(have {sorted(params)})")
            self.weights[name] = self._shard(name, value)

    def weight_value(self, name: str) -> np.ndarray:
        return gather(self.weights[name])

    # -- execution ---------------------------------------------------------
    def run(self, feeds: Mapping[str, object] | None = None,
            fetches: Sequence[str] | None = None) -> RunResult:
        """Execute one step: placeholders come from ``feeds`` (global
        arrays or ShardedTensors), parameters from session state."""
        feeds = dict(feeds or {})
        state: dict[str, ShardedTensor] = {}
        for t in self.program.graph.placeholders():
            if t.name not in feeds:
                raise ValueError(f"missing feed for placeholder {t.name!r}")
            state[t.name] = self._shard(t.name, feeds.pop(t.name))
        if feeds:
            raise ValueError(f"unknown feeds {sorted(feeds)}")
        for t in self.program.graph.parameters():
            if t.name not in self.weights:
                raise ValueError(
                    f"parameter {t.name!r} not loaded; call session.load")
            state[t.name] = self.weights[t.name]
        outs = self.executor.run(self.plan, state, fetches)
        return RunResult(outs)

    # -- dynamic switching (§6) --------------------------------------------
    def switch(self, strategy: "Strategy | str | int") -> SwitchReport:
        """Fused-BSR migration of all weights to ``strategy``; the session
        continues restart-free under the new compiled plan."""
        dst = self.program.index(strategy)
        src = self.plan.strategy_index
        if dst == src:
            from repro.core.bsr import BsrPlan
            return SwitchReport(plan=BsrPlan([]), planning_seconds=0.0,
                                est_transfer_seconds=0.0, total_bytes=0,
                                message_count=0)
        backend = "jax" if isinstance(self.executor, JaxExecutor) else "sim"
        mesh = getattr(self.executor, "mesh", None)
        missing = [t.name for t in self.program.graph.parameters()
                   if t.name not in self.weights]
        if missing:
            raise ValueError(f"cannot switch with unloaded parameters "
                             f"{missing}")
        # same topology fallback as Program.compile: explicit session
        # topology first, then the destination strategy's own
        topology = self.topology or \
            self.program.strategies[dst].topology
        outcome = core_switch(
            self.weights, self.program.graph, src, dst, self.shape_env,
            topology, backend=backend, mesh=mesh)
        self.weights = outcome.weights
        self.plan = self.program.compile(dst, shape_env=self.shape_env,
                                         topology=self.topology)
        return outcome.report
