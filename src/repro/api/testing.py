"""Shared differential-test fixtures for the `repro.api` surface.

Lives in ``src/`` (not ``tests/``) because the same program builders are
consumed by both the pytest suite and the subprocess runtime selftest
(``repro.runtime.selftest``) — one definition, so the two can never
drift apart.  Import is side-effect free (no jax, no device forcing).
"""

from __future__ import annotations

import numpy as np

from repro import api


def zigzag_program(n: int = 4, name: str = "zig") -> "api.Program":
    """A 2-physical-stage program whose dataflow crosses the stage
    boundary three times (s0 -> s1 -> s0 -> s1): Megatron's v=2
    interleaved chunk layout, expressible only as virtual stages.

    Devices ``0..n/2-1`` form stage 0, the rest stage 1; activations are
    row-split within a stage (every stage-0 device is a P2P sender, so
    pipeline construction sees symmetric parallel chains).
    """
    half = n // 2
    s0, s1 = list(range(half)), list(range(half, n))
    row = api.DS({0: half}) if half > 1 else api.DS({})
    dup = api.DS({api.DUP: half})
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
               name="H")
    g.comm(h, name="H2")                     # -> stage 1   (chunk 0)
    g.parameter("W2", (12, 10))
    y1 = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y1")
    g.comm(y1, name="Y2")                    # -> stage 0   (chunk 1!)
    g.parameter("W3", (10, 8))
    y4 = g.relu(g.dot(g.tensors["Y2"], g.tensors["W3"], name="Y3"),
                name="Y4")
    g.comm(y4, name="Y5")                    # -> stage 1   (chunk 1)
    g.parameter("W4", (8, 6))
    y = g.dot(g.tensors["Y5"], g.tensors["W4"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    strat = api.Strategy(name, {
        "X": api.spmd(s0, row), "W1": api.spmd(s0, dup),
        "H2": api.spmd(s1, row), "W2": api.spmd(s1, dup),
        "Y2": api.spmd(s0, row), "W3": api.spmd(s0, dup),
        "Y5": api.spmd(s1, row), "W4": api.spmd(s1, dup),
    })
    return api.Program(g, [strat])


def loss_pipeline_program(n: int = 4, name: str = "pipe") -> "api.Program":
    """The canonical 2-stage loss pipeline of the selftest suite:
    ``L = sum(relu(X @ W1) @ W2)`` with stage 0 column-parallel over the
    first half of the devices and stage 1 row-parallel over the second
    half — scalar loss, so it trains end-to-end via
    ``Session.train_step``."""
    half = n // 2
    s0, s1 = list(range(half)), list(range(half, n))
    col = api.DS({1: half}) if half > 1 else api.DS({})
    row = api.DS({0: half}) if half > 1 else api.DS({})
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
               name="H")
    g.comm(h, name="H2")
    g.parameter("W2", (12, 6))
    y = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    strat = api.Strategy(name, {
        "X": api.spmd(s0, api.DS({api.DUP: half})),
        "W1": api.spmd(s0, col),
        "H2": api.spmd(s1, row),
        "W2": api.spmd(s1, api.DS({api.DUP: half})),
    })
    return api.Program(g, [strat])


def loss_pipeline_values(seed: int = 11):
    """Integer-valued leaves for :func:`loss_pipeline_program` (exact
    under float32 sums -> bitwise-comparable pipelined gradients) plus
    the expected ``Y`` and loss."""
    rng = np.random.default_rng(seed)
    xv = rng.integers(-4, 5, (16, 16)).astype(np.float32)
    w1v = rng.integers(-4, 5, (16, 12)).astype(np.float32)
    w2v = rng.integers(-4, 5, (12, 6)).astype(np.float32)
    want_y = np.maximum(xv @ w1v, 0) @ w2v
    return xv, {"W1": w1v, "W2": w2v}, want_y


def hetero_program(name: str = "het") -> "api.Program":
    """An hsize=2 (heterogeneous-subgroup, paper §3.2 top tier) training
    fixture over 4 devices: subgroup ``[0, 1]`` row-splits its batch
    slab of ``X`` while subgroup ``[2, 3]`` duplicates its slab (and the
    activation CommOp ``H2`` swaps those bottom-tier layouts across the
    slab boundary), with every weight hetero-duplicated.

    The weight gradients therefore come out ``hdim=Partial`` — each
    subgroup holds the summand contributed by its batch slab, with a
    further bottom-tier Partial inside whichever subgroup row-split its
    activations — so the grad-reduce CommOp must resolve the full
    two-tier reduction (bottom AR inside the split subgroup, then a
    top-tier SplitAR across subgroups) and both executors must execute
    it: the hsize>1 gradient path, end to end."""
    g = api.Graph()
    g.placeholder("X", (16, 16))
    g.parameter("W1", (16, 12))
    h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
               name="H")
    g.comm(h, name="H2")
    g.parameter("W2", (12, 6))
    y = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
    g.sum(g.sum(y, 1, name="L1"), 0, name="L")
    dup2 = api.DS({api.DUP: 2})
    strat = api.Strategy(name, {
        "X": api.HSPMD([[0, 1], [2, 3]], [api.DS({0: 2}), dup2], hdim=0),
        "W1": api.HSPMD([[0, 1], [2, 3]], [dup2, dup2]),
        "H2": api.HSPMD([[0, 1], [2, 3]], [dup2, api.DS({0: 2})], hdim=0),
        "W2": api.HSPMD([[0, 1], [2, 3]], [dup2, dup2]),
    })
    return api.Program(g, [strat])


def hetero_values(seed: int = 7):
    """Integer-valued leaves for :func:`hetero_program` plus the exact
    expected loss and weight gradients (graph-IR ``relu_grad`` uses the
    ``x > 0`` subgradient at exact zeros — integer data hits them)."""
    rng = np.random.default_rng(seed)
    xv = rng.integers(-4, 5, (16, 16)).astype(np.float32)
    ws = {"W1": rng.integers(-4, 5, (16, 12)).astype(np.float32),
          "W2": rng.integers(-4, 5, (12, 6)).astype(np.float32)}
    h0 = xv @ ws["W1"]
    hh = np.maximum(h0, 0)
    want_loss = float((hh @ ws["W2"]).sum())
    d_y = np.ones((16, 6), np.float32)
    d_h = (d_y @ ws["W2"].T) * (h0 > 0)
    want_grads = {"W1": xv.T @ d_h, "W2": hh.T @ d_y}
    return xv, ws, want_loss, want_grads


def zigzag_values(seed: int = 11):
    """Integer-valued leaves (exact under float32 summation) and the
    expected full-batch ``Y`` for :func:`zigzag_program`."""
    rng = np.random.default_rng(seed)
    xv = rng.integers(-4, 5, (16, 16)).astype(np.float32)
    ws = {f"W{i}": rng.integers(-2, 3, shp).astype(np.float32)
          for i, shp in [(1, (16, 12)), (2, (12, 10)), (3, (10, 8)),
                         (4, (8, 6))]}
    want_y = np.maximum(xv @ ws["W1"], 0) @ ws["W2"]
    want_y = np.maximum(want_y @ ws["W3"], 0) @ ws["W4"]
    return xv, ws, want_y


__all__ = ["hetero_program", "hetero_values",
           "loss_pipeline_program", "loss_pipeline_values",
           "zigzag_program", "zigzag_values"]
