"""Heterogeneous-cluster strategies (paper §7.1, Appendix A.2 Table 5).

The paper's optimal Hetu strategies are encoded verbatim as
:class:`Strategy` fixtures; the DeepSpeed/Megatron baselines come from
``best_uniform`` (their own tuners).  ``strategy_annotations`` expresses a
strategy's per-layer weight placement as HSPMD annotations — the bridge
that lets graph switching (fused BSR) and communication resolution operate
on cost-model strategies.
"""

from __future__ import annotations

from repro.core.annotations import DG, DS, DUP, HSPMD, PARTIAL
from repro.core.costmodel import (LLAMA_32B, LLAMA_70B, ClusterSpec,
                                  ModelSpec, PipelineSpec, Stage, Strategy,
                                  paper_cluster)

# rank convention (paper Appendix A): R0-15 = H800, R16-47 = H20


def _stages(*spec):
    """spec: (ranks, lo, hi) triples."""
    return tuple(Stage(tuple(ranks), (lo, hi)) for ranks, lo, hi in spec)


def hetu_32b_16h800_16h20() -> Strategy:
    """Table 5 row 1: two 4.5-stage pipelines, H20 stages carry fewer
    layers; 32 x bs1 microbatches each."""
    p1 = PipelineSpec(_stages(
        (range(16, 20), 0, 7), (range(20, 24), 7, 14),
        (range(0, 4), 14, 37), (range(4, 8), 37, 60)), 32, 1)
    p2 = PipelineSpec(_stages(
        (range(24, 28), 0, 7), (range(28, 32), 7, 14),
        (range(8, 12), 14, 37), (range(12, 16), 37, 60)), 32, 1)
    return Strategy((p1, p2))


def hetu_32b_16h800_32h20() -> Strategy:
    """Table 5 row 3: four 3-stage pipelines (DP=4)."""
    pipes = []
    h20_groups = [(16, 20, 20, 24), (24, 28, 28, 32),
                  (32, 36, 36, 40), (40, 44, 44, 48)]
    h800_groups = [(0, 4), (4, 8), (8, 12), (12, 16)]
    for (a, b, c, d), (e, f) in zip(h20_groups, h800_groups):
        pipes.append(PipelineSpec(_stages(
            (range(a, b), 0, 11), (range(c, d), 11, 22),
            (range(e, f), 22, 60)), 16, 1))
    return Strategy(tuple(pipes))


def hetu_70b_16h800_16h20() -> Strategy:
    """Table 5: 70B single pipeline, TP8 stages."""
    p = PipelineSpec(_stages(
        (range(16, 24), 0, 11), (range(24, 32), 11, 22),
        (range(0, 8), 22, 51), (range(8, 16), 51, 80)), 64, 1)
    return Strategy((p,))


HETU_STRATEGIES = {
    ("llama-32b", 16, 16): hetu_32b_16h800_16h20,
    ("llama-32b", 16, 32): hetu_32b_16h800_32h20,
    ("llama-70b", 16, 16): hetu_70b_16h800_16h20,
}


def priced_schedule_stats(cluster: ClusterSpec, model: ModelSpec,
                          strat: Strategy, seq_len: int,
                          fwd_fraction: float | str | None = None):
    """Per-pipeline :class:`~repro.core.schedule.ScheduleStats` of the
    timetables this strategy would execute, with tick durations priced
    from the cost model per (stage, phase) — the paper's temporal
    heterogeneity (§5, §7) made visible: the H20 stages' shorter layer
    ranges yield shorter ticks, and the *priced* makespan / bubble
    fraction reflect the actual (non-uniform) fill/drain shape rather
    than bottleneck-uniform slot counts.

    ``fwd_fraction`` controls the fwd:bwd tick split: ``None`` (the
    fast default) keeps the analytic 1:2 ratio; ``"measured"`` prices
    with the fwd share of a differentiated ``compile_train`` proxy plan
    (:func:`repro.search.rank.proxy_fwd_fraction`, memoized); a float
    passes through."""
    from repro.core.costmodel import pipeline_tick_durations
    from repro.core.schedule import build_schedule
    from repro.search.rank import resolve_fwd_fraction

    frac = resolve_fwd_fraction(fwd_fraction)
    out = []
    for p in strat.pipelines:
        sched = build_schedule(len(p.stages), p.n_micro, strat.schedule)
        out.append(sched.stats(pipeline_tick_durations(
            cluster, model, p, seq_len, fwd_fraction=frac)))
    return out


# ---------------------------------------------------------------------------
# strategy -> HSPMD annotations (per-layer weight placement)
# ---------------------------------------------------------------------------

def strategy_annotations(strat: Strategy, model: ModelSpec,
                         shard_dim: int = 0) -> dict[int, HSPMD]:
    """For each layer: the HSPMD annotation of its (flattened) weight.

    Each pipeline that owns the layer contributes one sharding subgroup
    (its TP group, Split along ``shard_dim``); pipelines are united under
    ``hdim = DUP`` (data-parallel replicas of the layer's weights) — the
    exact Fig 12 structure that graph switching reshards.
    """
    out: dict[int, HSPMD] = {}
    for layer in range(model.n_layers):
        dgs, dss = [], []
        for p in strat.pipelines:
            for st in p.stages:
                if st.layers[0] <= layer < st.layers[1]:
                    dgs.append(DG(st.ranks))
                    dss.append(DS({shard_dim: st.tp}) if st.tp > 1
                               else DS({}))
        if not dgs:
            raise ValueError(f"layer {layer} unassigned")
        out[layer] = HSPMD(dgs, dss, hdim=DUP)
    return out


def to_api_strategy(name: str, strat: Strategy, model: ModelSpec,
                    shard_dim: int = 0, topology=None):
    """Export a cost-model Strategy as a ``repro.api.Strategy`` over the
    per-layer weight view (``layer{i}`` tensors) — the bridge that lets
    ``api.Program`` / ``api.Session`` compile and switch the paper's
    Table 5 strategies."""
    from repro.api import Strategy as ApiStrategy
    annots = {f"layer{i}": a for i, a in
              strategy_annotations(strat, model, shard_dim).items()}
    return ApiStrategy(name, annots, topology)


def layer_weight_shapes(model: ModelSpec) -> dict[str, tuple[int, int]]:
    """Flattened per-layer weight shapes matching ``to_api_strategy``."""
    shape = (int(model.params_per_layer // model.d_model),
             int(model.d_model))
    return {f"layer{i}": shape for i in range(model.n_layers)}


def grad_sync_annotations(strat: Strategy, model: ModelSpec) \
        -> dict[int, tuple[HSPMD, HSPMD]]:
    """(src, dst) annotation pairs for per-layer gradient sync: Partial
    across DP subgroups -> Duplicate (SplitAR when TP degrees differ —
    the paper's Fig 17 pattern)."""
    out = {}
    for layer, annot in strategy_annotations(strat, model).items():
        if annot.hsize <= 1:
            continue
        src = HSPMD(annot.dgs, annot.dss, hdim=PARTIAL)
        dst = HSPMD(annot.dgs, annot.dss, hdim=DUP)
        out[layer] = (src, dst)
    return out
