"""Mixed-length data scenario (paper §7.3, Figs 15-16).

Per training step a fresh sample of variable-length sequences
(~200K tokens) is processed under one of four policies:

  * ``baseline``  — DeepSpeed/Megatron: pack everything into the full
    context window under a fixed long-sequence-friendly strategy;
  * ``hotspa`` (== Hetu-A) — bucket by length, switch between
    *homogeneous* strategies within the step (gradient accumulation
    across buckets), paying intra-step switch overhead per bucket pair;
  * ``hetu_b``    — pick one of two *heterogeneous* strategies per step
    from the batch's max sequence length; long sequences go to the
    high-TP pipeline and short ones to the small pipelines, balanced by
    a cost model; strategy switches happen only when consecutive steps
    change regime (Fig 16).

Step times come from the calibrated cluster cost model; switch costs from
the real fused-BSR planner (as in the elastic scenario).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.core.costmodel import (LLAMA_32B, ClusterSpec, ModelSpec,
                                  PipelineSpec, Stage, Strategy,
                                  paper_cluster, step_time)
from repro.core.switching import plan_tensor_switch
from repro.core.topology import NvlinkIbTopology
from repro.data.pipeline import (Bucket, CorpusConfig, SyntheticCorpus,
                                 bucketize, step_stream)
from repro.scenarios.hetero import layer_weight_shapes, strategy_annotations

H20_RANKS = list(range(32))


def _uniform(ranks, model, dp, tp, pp, micro, n_micro):
    from repro.core.costmodel import uniform_strategy
    return uniform_strategy(list(ranks), model, dp=dp, tp=tp, pp=pp,
                            global_batch=dp * n_micro * micro,
                            micro_bs=micro)


# Table 10: interval strategies for HotSPa / Hetu-A (32 H20, 32K context)
def bucket_strategies_32k(model: ModelSpec):
    return {
        Bucket(16384, 32768): _uniform(H20_RANKS, model, 2, 16, 1, 1, 4),
        Bucket(4096, 16384): _uniform(H20_RANKS, model, 2, 8, 2, 1, 8),
        Bucket(0, 4096): _uniform(H20_RANKS, model, 4, 4, 2, 1, 8),
    }


# Table 11: Hetu-B heterogeneous strategies (32 H20)
def hetu_b_strategy_long(model: ModelSpec) -> Strategy:
    """Strategy 1 (16K < max <= 32K): one TP16 long pipeline + four TP4
    short pipelines."""
    pipes = [PipelineSpec((Stage(tuple(range(0, 16)), (0, model.n_layers)),),
                          4, 1)]
    for g in range(4):
        ranks = tuple(range(16 + g * 4, 20 + g * 4))
        pipes.append(PipelineSpec((Stage(ranks, (0, model.n_layers)),), 8, 1))
    return Strategy(tuple(pipes))


def hetu_b_strategy_short(model: ModelSpec) -> Strategy:
    """Strategy 2 (max <= 16K): one TP8 long pipeline + three 2-stage
    TP4 short pipelines."""
    pipes = [PipelineSpec((Stage(tuple(range(0, 8)), (0, model.n_layers)),),
                          4, 1)]
    half = model.n_layers // 2
    for g in range(3):
        a = 8 + g * 8
        pipes.append(PipelineSpec(
            (Stage(tuple(range(a, a + 4)), (0, half)),
             Stage(tuple(range(a + 4, a + 8)), (half, model.n_layers))),
            8, 1))
    return Strategy(tuple(pipes))


@dataclass
class StepReport:
    step: int
    policy: str
    seconds: float
    max_len: int
    n_seqs: int
    switched: bool = False
    switch_s: float = 0.0


# -- sequence-exact cost accounting ------------------------------------------
#
# The physics the paper exploits: attention is quadratic in the *actual*
# attended length.  Packing short documents into a 32K window under a
# fixed long-context strategy pays 32K^2 attention per window and drags
# every token through a high-TP group; per-sequence processing pays
# sum(len^2) and lets short sequences ride cheap low-TP pipelines.

def _seq_flops(model: ModelSpec, length: int) -> float:
    """fwd+bwd FLOPs for ONE sequence at its own attended length."""
    dense = 6 * model.params_per_layer * length * model.n_layers
    attn = 12 * model.d_model * length * length * model.n_layers
    head = 6 * model.d_model * model.vocab * length
    return dense + attn + head


def _pipeline_rate(cluster: ClusterSpec, p: PipelineSpec,
                   ref_len: int, model: ModelSpec) -> float:
    """Effective FLOPs/s of one pipeline, scored by the PRICED timetable
    it would execute (``costmodel.pipeline_time`` re-times the 1F1B tick
    table under per-(stage, phase) durations), so heterogeneous stage
    splits pay their own fill ramp instead of the uniform
    ``(m + S - 1)/m`` bottleneck factor."""
    from repro.core.costmodel import pipeline_time
    if p.n_micro < 1 or p.micro_bs < 1:     # degenerate specs: clamp
        p = dataclasses.replace(p, n_micro=max(p.n_micro, 1),
                                micro_bs=max(p.micro_bs, 1))
    micro_tokens = p.micro_bs * ref_len
    per_micro = sum(model.layer_flops(micro_tokens, ref_len) * st.n_layers
                    for st in p.stages)
    t_step = pipeline_time(cluster, model, p, ref_len)
    return per_micro * p.n_micro / t_step


def _strategy_step_time(cluster, model, strat, seqs, context, *,
                        packed_window: int | None = None) -> float:
    """Sequence-exact processing time under a strategy.

    ``packed_window``: baseline semantics — sequences are packed into
    fixed windows of that size and attention is paid at window length.
    Otherwise sequences keep their own lengths and are dispatched to the
    pipeline with the earliest finish time (the paper's cost-model
    dispatch), longest first.
    """
    if packed_window:
        total = sum(min(len(s), packed_window) for s in seqs)
        n_windows = max(1, -(-total // packed_window))
        work = [_seq_flops(model, packed_window)] * n_windows
        ref = packed_window
    else:
        work = sorted((_seq_flops(model, len(s)) for s in seqs),
                      reverse=True)
        ref = max(len(s) for s in seqs)
    rates = [_pipeline_rate(cluster, p, min(ref, context), model)
             for p in strat.pipelines]
    finish = [0.0] * len(rates)
    for w in work:  # greedy earliest-finish dispatch
        i = min(range(len(rates)), key=lambda j: finish[j] + w / rates[j])
        finish[i] += w / rates[i]
    from repro.core.costmodel import dp_sync_time
    return max(finish) + dp_sync_time(cluster, model, strat)


def _switch_cost(model, src: Strategy, dst: Strategy, topo) -> float:
    shapes = layer_weight_shapes(model)
    sa = strategy_annotations(src, model)
    da = strategy_annotations(dst, model)
    tensors = [(name, sa[layer], da[layer], shapes[name], 2)
               for layer, name in enumerate(shapes)]
    return plan_tensor_switch(tensors, topo).est_transfer_seconds


def run_mixed_length(policy: str, *, context: int = 32768,
                     corpus_name: str = "commoncrawl", n_steps: int = 30,
                     tokens_per_step: int = 200_000,
                     model: ModelSpec = LLAMA_32B,
                     seed: int = 0) -> list[StepReport]:
    cluster = ClusterSpec(tuple(
        dataclasses.replace(paper_cluster(0, 32).ranks[0])
        for _ in range(32)))
    topo = NvlinkIbTopology(gpus_per_node=8, nvlink_gbps=900.0)
    corpus = SyntheticCorpus(CorpusConfig(corpus_name, seed=seed,
                                          max_len=context))
    buckets = bucket_strategies_32k(model)
    s_long = hetu_b_strategy_long(model)
    s_short = hetu_b_strategy_short(model)
    baseline = _uniform(H20_RANKS, model, 2, 16, 1, 1, 4)

    reports = []
    cur_b = None
    for step, seqs in enumerate(step_stream(corpus, tokens_per_step,
                                            n_steps)):
        max_len = max(len(s) for s in seqs)
        if policy == "baseline":
            t = _strategy_step_time(cluster, model, baseline, seqs, context,
                                    packed_window=context)
            reports.append(StepReport(step, policy, t, max_len, len(seqs)))
        elif policy in ("hotspa", "hetu_a"):
            # per-bucket sub-steps + intra-step strategy switches
            by_bucket = bucketize(seqs, tuple(buckets))
            t_total, switches = 0.0, 0
            prev = None
            for b, strat in buckets.items():
                sub = by_bucket.get(b, [])
                if not sub:
                    continue
                t_total += _strategy_step_time(
                    cluster, model, strat, sub, min(b.hi, context),
                    packed_window=min(b.hi, context))
                if prev is not None:
                    t_total += _switch_cost(model, prev, strat, topo)
                    switches += 1
                prev = strat
            reports.append(StepReport(step, policy, t_total, max_len,
                                      len(seqs), switched=switches > 0))
        elif policy == "hetu_b":
            want = s_long if max_len > 16384 else s_short
            t = _strategy_step_time(cluster, model, want, seqs, context)
            sw, t_sw = False, 0.0
            if cur_b is not None and want is not cur_b:
                t_sw = _switch_cost(model, cur_b, want, topo)
                t += t_sw
                sw = True
            cur_b = want
            reports.append(StepReport(step, policy, t, max_len, len(seqs),
                                      switched=sw, switch_s=t_sw))
        else:
            raise ValueError(policy)
    return reports
