"""Heterogeneous strategy search over the cluster cost model.

The paper (§7.2) selects strategies from "pre-profiled results combined
with a cost model"; related work (Metis, HexiScale) searches the hetero
strategy space.  HSPMD's role is to EXPRESS whatever a search finds —
this module provides a compact searcher so the scenarios do not depend on
hand-written fixtures alone:

  1. partition the ranks into device-type groups (H800 vs H20);
  2. enumerate pipeline counts / TP degrees per group (powers of two);
  3. assign stage layer counts proportionally to stage compute power
     (balanced-makespan heuristic, the paper's Table 5 shape);
  4. keep the feasible strategy with the best cost-model step time.
"""

from __future__ import annotations

import itertools

from repro.core.costmodel import (ClusterSpec, ModelSpec, PipelineSpec,
                                  Stage, Strategy, feasible, step_time)


def _balanced_stages(groups: list[tuple[tuple[int, ...], float]],
                     n_layers: int) -> list[Stage]:
    """Assign layers to TP groups proportionally to group throughput."""
    total = sum(p for _, p in groups)
    stages, lo = [], 0
    for i, (ranks, power) in enumerate(groups):
        hi = n_layers if i == len(groups) - 1 else min(
            n_layers, lo + max(1, round(n_layers * power / total)))
        if hi <= lo:
            hi = min(n_layers, lo + 1)
        stages.append(Stage(tuple(ranks), (lo, hi)))
        lo = hi
    if lo != n_layers:
        last = stages[-1]
        stages[-1] = Stage(last.ranks, (last.layers[0], n_layers))
    return stages


def search_hetero_strategy(cluster: ClusterSpec, model: ModelSpec,
                           ranks: list[int], global_batch: int,
                           seq_len: int,
                           n_pipelines_options=(1, 2, 4),
                           tp_options=(2, 4, 8, 16)) -> tuple[Strategy, float]:
    """Best hetero strategy found; raises if nothing is feasible."""
    by_type: dict[str, list[int]] = {}
    for r in ranks:
        by_type.setdefault(cluster.ranks[r].name, []).append(r)

    best: tuple[Strategy, float] | None = None
    for n_pipes in n_pipelines_options:
        if any(len(v) % n_pipes for v in by_type.values()):
            continue
        per_pipe = {t: [v[i::n_pipes] for i in range(n_pipes)]
                    for t, v in by_type.items()}
        for tps in itertools.product(tp_options, repeat=len(by_type)):
            pipes = []
            ok = True
            for pi in range(n_pipes):
                groups = []
                for (t, chunks), tp in zip(sorted(per_pipe.items()), tps):
                    chunk = chunks[pi]
                    if len(chunk) % tp:
                        ok = False
                        break
                    power_per = cluster.ranks[chunk[0]].tflops * tp
                    # slower device class feeds the early stages (paper
                    # Table 5 places H20 stages first)
                    for g in range(len(chunk) // tp):
                        groups.append((tuple(chunk[g * tp:(g + 1) * tp]),
                                       power_per))
                if not ok or not groups:
                    ok = False
                    break
                groups.sort(key=lambda g: g[1])  # slow stages first
                if len(groups) > model.n_layers:
                    ok = False
                    break
                stages = _balanced_stages(groups, model.n_layers)
                n_micro = max(global_batch // n_pipes, 1)
                pipes.append(PipelineSpec(tuple(stages), n_micro, 1))
            if not ok:
                continue
            strat = Strategy(tuple(pipes))
            if not feasible(cluster, model, strat):
                continue
            t = step_time(cluster, model, strat, seq_len)
            if best is None or t < best[1]:
                best = (strat, t)
    if best is None:
        raise RuntimeError("no feasible heterogeneous strategy found")
    return best


def schedule_report(strat: Strategy, cluster: ClusterSpec | None = None,
                    model: ModelSpec | None = None,
                    seq_len: int = 4096) -> str:
    """Per-pipeline 1F1B/GPipe timetable stats for a found strategy —
    the executable (`core.schedule`) counterpart of the term `step_time`
    prices, so searches can report the bubble shape their winner
    actually runs.  With ``cluster`` + ``model`` the ticks are priced
    per (stage, phase) from the cost model (non-uniform durations);
    otherwise the makespan is in uniform slots."""
    from repro.core.costmodel import pipeline_tick_durations
    from repro.core.schedule import build_schedule

    lines = []
    for i, p in enumerate(strat.pipelines):
        s = build_schedule(len(p.stages), p.n_micro, strat.schedule)
        durations = None
        if cluster is not None and model is not None:
            durations = pipeline_tick_durations(cluster, model, p, seq_len)
        lines.append(f"pipeline {i} [{strat.schedule}]: "
                     f"{s.stats(durations).summary()}")
    return "\n".join(lines)
