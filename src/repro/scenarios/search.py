"""Heterogeneous strategy search over the cluster cost model.

The paper (§7.2) selects strategies from "pre-profiled results combined
with a cost model"; related work (Metis, HexiScale) searches the hetero
strategy space.  This module is now a thin compatibility shim over the
:mod:`repro.search` subsystem (enumerate -> prune -> rank -> validate):
the old entry points keep their signatures, but enumeration and pruning
live in :mod:`repro.search.space` / :mod:`repro.search.prune`, and an
infeasible search raises :class:`repro.search.SearchError` (a
``RuntimeError`` subclass) carrying per-rule rejection counts instead
of a bare message.
"""

from __future__ import annotations

from repro.core.costmodel import ClusterSpec, ModelSpec, Strategy
from repro.search.prune import PruneReport, SearchError, prune
from repro.search.rank import rank
from repro.search.space import balanced_stages, enumerate_candidates

# The old private helper had an off-by-one that could emit zero-layer
# stages when the group count approached the layer count; it is now an
# alias of the fixed implementation (every stage gets >= 1 layer).
_balanced_stages = balanced_stages


def search_hetero_strategy(cluster: ClusterSpec, model: ModelSpec,
                           ranks: list[int], global_batch: int,
                           seq_len: int,
                           n_pipelines_options=(1, 2, 4),
                           tp_options=(2, 4, 8, 16)) -> tuple[Strategy, float]:
    """Best hetero strategy found; raises :class:`SearchError` (a
    ``RuntimeError``) with per-rule rejection counts if nothing is
    feasible.  Kept signature-compatible with the pre-subsystem
    searcher: ``n_micro = max(global_batch // n_pipelines, 1)`` and the
    analytic fwd/bwd split (so returned times stay comparable to
    ``best_uniform``'s ``step_time``)."""
    best: tuple[Strategy, float] | None = None
    n_cands, rejections = 0, []
    for n_pipes in sorted(n_pipelines_options):
        # the old searcher tolerated non-divisible global batches by
        # rounding the per-pipeline microbatch count up to >= 1
        gb = n_pipes * max(global_batch // n_pipes, 1)
        cands = enumerate_candidates(
            cluster, model, list(ranks), global_batch=gb,
            tp_options=tp_options, pipeline_options=(n_pipes,),
            include_uniform=False)
        report = prune(cluster, model, cands)
        n_cands += report.n_candidates
        rejections.extend(report.rejections)
        if not report.survivors:
            continue
        top = rank(cluster, model, report.survivors, seq_len,
                   fwd_fraction=None)[0]
        if best is None or top.predicted_step_s < best[1]:
            best = (top.candidate.strategy, top.predicted_step_s)
    if best is None:
        raise SearchError(
            PruneReport(n_cands, (), tuple(rejections)),
            "heterogeneous strategy")
    return best


def schedule_report(strat: Strategy, cluster: ClusterSpec | None = None,
                    model: ModelSpec | None = None,
                    seq_len: int = 4096) -> str:
    """Per-pipeline 1F1B/GPipe timetable stats for a found strategy —
    the executable (`core.schedule`) counterpart of the term `step_time`
    prices, so searches can report the bubble shape their winner
    actually runs.  With ``cluster`` + ``model`` the ticks are priced
    per (stage, phase) from the cost model (non-uniform durations);
    otherwise the makespan is in uniform slots."""
    from repro.core.costmodel import pipeline_tick_durations
    from repro.core.schedule import build_schedule

    lines = []
    for i, p in enumerate(strat.pipelines):
        s = build_schedule(len(p.stages), p.n_micro, strat.schedule)
        durations = None
        if cluster is not None and model is not None:
            durations = pipeline_tick_durations(cluster, model, p, seq_len)
        lines.append(f"pipeline {i} [{strat.schedule}]: "
                     f"{s.stats(durations).summary()}")
    return "\n".join(lines)
