"""Shim — the elastic scenario grew into the :mod:`repro.elastic`
package.

The analytic trace pricing (paper §7.2, Fig 14) lives in
:mod:`repro.elastic.pricing`; the live trace driver that actually runs
``train_step``s through device loss/join is
:mod:`repro.elastic.driver`.  Everything previously importable from
here keeps working.
"""

from repro.elastic.pricing import (TRACE_HETERO, TRACE_HOMOG,
                                   TransitionReport,
                                   checkpoint_restart_baseline, run_trace,
                                   two_pipeline_strategy)

__all__ = ["TRACE_HETERO", "TRACE_HOMOG", "TransitionReport",
           "checkpoint_restart_baseline", "run_trace",
           "two_pipeline_strategy"]
