"""ShapeDtypeStruct input specs for every (architecture x input shape).

``input_specs`` builds weak-type-correct, shardable stand-ins for every
model input — batches, parameters, optimizer state, decode caches — with
NO device allocation (everything flows through ``jax.eval_shape``).

The four assigned input shapes:

  train_4k      seq 4,096    global_batch 256   (training)
  prefill_32k   seq 32,768   global_batch 32    (inference prefill)
  decode_32k    seq 32,768   global_batch 128   (one-token decode w/ cache)
  long_500k     seq 524,288  global_batch 1     (long-context decode;
                                                 sub-quadratic archs only)

For [vlm] the batch carries precomputed patch/text embeddings + M-RoPE
positions; for [audio] it carries decoder tokens + 1500 stub frame
embeddings (DESIGN.md carve-out).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_decode_state, init_params

S = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention architecture: long_500k requires "
                       "sub-quadratic decode (skip noted in DESIGN.md)")
    return True, ""


def batch_specs_for(cfg: ModelConfig, shape: InputShape,
                    dtype=jnp.bfloat16) -> dict:
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    batch: dict = {}
    if cfg.input_kind == "embeds":
        batch["embeds"] = S((b, s, cfg.d_model), dtype)
        batch["positions3"] = S((3, b, s), jnp.int32)
    elif cfg.input_kind == "audio":
        batch["tokens"] = S((b, s), jnp.int32)
        if shape.kind != "decode":
            batch["audio_embeds"] = S((b, cfg.encdec.n_frames, cfg.d_model),
                                      dtype)
    else:
        batch["tokens"] = S((b, s), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = S((b, s), jnp.int32)
        batch["loss_mask"] = S((b, s), jnp.float32)
    return batch


def param_structs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0))


def opt_structs(params_struct):
    from repro.optim.adamw import init_opt_state
    return jax.eval_shape(init_opt_state, params_struct)


def decode_state_structs(cfg: ModelConfig, shape: InputShape,
                         dtype=jnp.bfloat16):
    enc_out = None
    if cfg.encdec:
        enc_out = S((shape.global_batch, cfg.encdec.n_frames, cfg.d_model),
                    dtype)

    def mk():
        eo = (jnp.zeros(enc_out.shape, enc_out.dtype)
              if enc_out is not None else None)
        return init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                 dtype, enc_out=eo)

    return jax.eval_shape(mk)


def input_specs(cfg: ModelConfig, shape_name: str, dtype=jnp.bfloat16):
    """Returns (kind, dict of ShapeDtypeStruct pytrees) for the step fn."""
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape_name}: {why}")
    out = {"batch": batch_specs_for(cfg, shape, dtype),
           "params": param_structs(cfg, dtype)}
    if shape.kind == "train":
        out["opt_state"] = opt_structs(out["params"])
    if shape.kind == "decode":
        out["state"] = decode_state_structs(cfg, shape, dtype)
    return shape.kind, out
