import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) the corresponding step function is
``jax.jit(...).lower(**ShapeDtypeStructs).compile()``-ed on the production
mesh — 16x16 single-pod AND 2x16x16 multi-pod — with NO array allocation.
Compiled artifacts yield ``memory_analysis()`` (fits-per-device proof) and
``cost_analysis()`` + HLO collective parsing (the §Roofline inputs).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k [--multi-pod] [--all] [--json out.json]

NOTE: the two os.environ lines above MUST run before any jax import —
jax locks the device count at first init.
"""

import argparse
import json
import re
import sys
import time

from repro.launch.hloparse import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   collective_bytes,
                                   normalize_cost_analysis)

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.kernels.policy import set_policy
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import INPUT_SHAPES, input_specs, shape_applicable
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import (batch_specs, decode_state_specs,
                                  param_specs, to_named)
from repro.train.steps import (build_decode_step, build_prefill_step,
                               build_train_step)



def build_step_and_args(cfg, shape_name, mesh, num_microbatches=8):
    kind, specs = input_specs(cfg, shape_name)
    pspecs = to_named(param_specs(specs["params"], cfg, mesh), mesh)
    bspecs = to_named(batch_specs(specs["batch"], mesh), mesh)
    if kind == "train":
        shape = INPUT_SHAPES[shape_name]
        n_mb = min(num_microbatches, shape.global_batch)
        step = build_train_step(cfg, AdamWConfig(), num_microbatches=n_mb)
        ospecs = {"m": pspecs, "v": pspecs,
                  "count": to_named(jax.tree.map(lambda _: None,
                                                 jnp.zeros(())), mesh)}
        from jax.sharding import NamedSharding, PartitionSpec as P
        ospecs["count"] = NamedSharding(mesh, P())
        jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                         out_shardings=(pspecs, ospecs, None))
        args = (specs["params"], specs["opt_state"], specs["batch"])
    elif kind == "prefill":
        step = build_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(pspecs, bspecs))
        args = (specs["params"], specs["batch"])
    else:
        step = build_decode_step(cfg)
        from repro.sharding.rules import serve_mode_fits
        if serve_mode_fits(specs["params"], specs["state"], mesh):
            pspecs = to_named(param_specs(specs["params"], cfg, mesh,
                                          mode="serve"), mesh)
        sspecs = to_named(decode_state_specs(specs["state"], cfg, mesh), mesh)
        jitted = jax.jit(step, in_shardings=(pspecs, sspecs, bspecs),
                         out_shardings=(None, sspecs))
        args = (specs["params"], specs["state"], specs["batch"])
    return jitted, args


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True) -> dict:
    set_policy("ref")   # dry-run lowers the XLA path (Mosaic targets TPU)
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        jitted, args = build_step_and_args(cfg, shape_name, mesh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())

    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # memory_analysis is per-device
        "bytes_per_device": {
            "arguments": int(getattr(mem, "argument_size_in_bytes", 0)),
            "outputs": int(getattr(mem, "output_size_in_bytes", 0)),
            "temps": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak": int(getattr(mem, "peak_memory_in_bytes", 0) or
                        getattr(mem, "temp_size_in_bytes", 0)),
        },
        # cost_analysis is the per-device partitioned program
        "per_device": {"flops": flops, "hbm_bytes": bytes_hbm,
                       "collective_bytes": coll_total,
                       "collectives": coll},
        "roofline_seconds": {
            "compute": flops / PEAK_FLOPS,
            "memory": bytes_hbm / HBM_BW,
            "collective": coll_total / ICI_BW,
        },
    }
    terms = result["roofline_seconds"]
    result["bottleneck"] = max(terms, key=terms.get)
    if verbose:
        print(f"[{arch} x {shape_name} @ {result['mesh']}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory/device: args "
              f"{result['bytes_per_device']['arguments'] / 2**30:.2f} GiB, "
              f"temps {result['bytes_per_device']['temps'] / 2**30:.2f} GiB")
        print(f"  per-device flops {flops:.3e}, hbm {bytes_hbm:.3e} B, "
              f"collectives {coll_total:.3e} B {coll}")
        print(f"  roofline terms (s): "
              + ", ".join(f"{k}={v * 1e3:.2f}ms" for k, v in terms.items())
              + f" -> bottleneck: {result['bottleneck']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--json", default=None, help="append results to file")
    args = ap.parse_args()

    assigned = [a for a in ARCHS if not a.startswith("llama")]
    combos = []
    if args.all:
        for a in assigned:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        combos = [(args.arch, args.shape)]

    results = []
    for arch, shape in combos:
        try:
            results.append(dryrun_one(arch, shape,
                                      multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"[{arch} x {shape}] FAILED: {type(e).__name__}: {e}")
            results.append({"arch": arch, "shape": shape,
                            "error": f"{type(e).__name__}: {e}"})
    if args.json:
        with open(args.json, "a") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    failed = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failed)}/{len(results)} combinations OK")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
