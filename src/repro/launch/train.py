"""Production training driver.

Selects an architecture config (``--arch``), builds the mesh from the
available devices, compiles the sharded train step (the same builder the
multi-pod dry-run lowers), and runs real steps on synthetic packed data —
checkpointing periodically.  ``--reduced`` swaps in the smoke-scale
variant so the full loop runs on CPU.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --reduced --steps 50 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import restore, save
from repro.configs import get_config
from repro.data.pipeline import CorpusConfig, SyntheticCorpus, pack_batch
from repro.launch.mesh import make_smoke_mesh
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding.rules import batch_specs, param_specs, to_named
from repro.train.steps import build_train_step


def make_batch(corpus, cfg, batch, seq, rng):
    seqs = corpus.sample_sequences(max(batch, 4))
    b = pack_batch(seqs, batch, seq)
    out = {k: jnp.asarray(v) for k, v in b.items()}
    if cfg.input_kind == "embeds":
        tok = out.pop("tokens")
        out["embeds"] = jax.nn.one_hot(tok % cfg.d_model, cfg.d_model,
                                       dtype=jnp.float32) * 0.02
        out["positions3"] = jnp.broadcast_to(out["positions"][None],
                                             (3,) + out["positions"].shape)
    elif cfg.input_kind == "audio":
        out["audio_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encdec.n_frames, cfg.d_model)) * 0.02,
            jnp.float32)
    return out


def strategy_report(params, mesh, num_microbatches: int = 1,
                    cfg=None, global_batch: int = 8,
                    seq_len: int = 256) -> None:
    """Describe the run's weight placement through ``repro.api``: the
    FSDP-style strategy over the mesh devices, the pipeline schedule the
    microbatch count implies (grad accumulation is the single-stage 1F1B
    case), the fused-BSR cost of draining to half the cluster (the
    elastic-training transition this driver would pay on a node
    failure), and — with ``cfg`` — the automated strategy search's pick
    for this device count (``repro.search``: enumerate -> prune ->
    rank)."""
    import jax.tree_util as jtu

    from repro import api

    leaves = jtu.tree_flatten_with_path(params)[0]
    shapes = {jtu.keystr(path): tuple(np.asarray(v).shape)
              for path, v in leaves}
    itemsizes = {jtu.keystr(path): np.asarray(v).dtype.itemsize
                 for path, v in leaves}
    devices = list(range(int(mesh.devices.size)))
    full = api.data_parallel_strategy("fsdp", devices, shapes)
    strategies = [full]
    if len(devices) >= 2:
        strategies.append(api.data_parallel_strategy(
            "fsdp-half", devices[:len(devices) // 2], shapes))
    prog = api.Program(api.weights_graph(shapes), strategies)
    plan = prog.compile("fsdp")
    print(f"placement[fsdp]: {len(shapes)} tensors over "
          f"{len(plan.devices)} device(s)")
    sched = plan.schedule(max(num_microbatches, 1), "1f1b")
    print(f"schedule[1f1b]: {plan.n_stages} stage(s) x "
          f"{sched.num_microbatches} microbatch(es) -> "
          f"{sched.stats().summary()}")
    if len(devices) >= 2:
        half = prog.strategy("fsdp-half")
        report = api.estimate_switch(
            [(n, full.annots[n], half.annots[n], shapes[n], itemsizes[n])
             for n in shapes])
        print(f"elastic drain to {len(devices) // 2} device(s): "
              f"{report.summary()}")
    if cfg is not None:
        from repro.core.costmodel import ModelSpec
        from repro.search import SearchError, Searcher, cpu_cluster
        spec = ModelSpec(cfg.name, cfg.n_layers, cfg.d_model,
                         getattr(cfg, "d_ff", 4 * cfg.d_model),
                         vocab=cfg.vocab)
        searcher = Searcher(spec, global_batch=global_batch,
                            seq_len=seq_len, tp_options=(1, 2),
                            pp_options=(1, 2, 4),
                            include_hetero=len(devices) > 1)
        try:
            result = searcher.search(cpu_cluster(len(devices)))
            print(f"strategy search over {len(devices)} device(s): "
                  f"{result.prune_report.summary()}")
            print(f"  winner {result.best.describe()}")
        except SearchError as exc:
            print(f"strategy search over {len(devices)} device(s): "
                  f"{exc}")


def elastic_probe_report() -> None:
    """Run the elastic probe trace LIVE (``repro.elastic``): real
    ``train_step``s through a shrink -> grow -> class-change trace with
    fused-BSR weight+optimizer migration, and print what each
    transition cost versus replaying it from a checkpoint.  This is the
    executable counterpart of ``strategy_report``'s analytic drain
    estimate — see docs/elastic.md."""
    from repro.elastic import ElasticDriver
    from repro.elastic.fixtures import (probe_feeds, probe_graph,
                                        probe_provider, probe_values)

    driver = ElasticDriver(probe_graph(), probe_values(),
                           probe_provider(), probe_feeds,
                           num_microbatches=2)
    run = driver.run([(0, (0, 1, 2, 3), "dp"), (2, (0, 1), "dp"),
                      (4, (0, 1, 2, 3), "pp")], 6)
    print(f"elastic probe: {run.summary()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--strategy-report", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="print the repro.api weight-placement + elastic "
                         "drain summary at startup (--no-strategy-report "
                         "skips the deduction/BSR planning it costs)")
    ap.add_argument("--elastic-probe", action="store_true",
                    help="also run the live elastic probe trace "
                         "(repro.elastic: shrink/grow/class-change with "
                         "fused-BSR migration) and print per-transition "
                         "costs before training starts")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} ({cfg.family}) layers={cfg.n_layers} "
          f"d={cfg.d_model} params~{cfg.param_count() / 1e6:.1f}M")

    mesh = make_smoke_mesh()
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.strategy_report:
        strategy_report(params, mesh, num_microbatches=args.microbatches,
                        cfg=cfg, global_batch=args.batch,
                        seq_len=args.seq)
    if args.elastic_probe:
        elastic_probe_report()
    opt_state = init_opt_state(params)
    start = 0
    if args.resume:
        (params, opt_state), start = restore(
            args.resume, (params, opt_state))
        print(f"resumed from {args.resume} @ step {start}")

    step_fn = build_train_step(cfg, AdamWConfig(lr=args.lr),
                               num_microbatches=args.microbatches)
    with mesh:
        pspecs = to_named(param_specs(params, cfg, mesh), mesh)
        jitted = jax.jit(step_fn)

        corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab,
                                              max_len=args.seq))
        rng = np.random.default_rng(0)
        t0 = time.time()
        for step in range(start, start + args.steps):
            batch = make_batch(corpus, cfg, args.batch, args.seq, rng)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % args.log_every == 0 or step == start + args.steps - 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.time() - t0
                tput = (step - start + 1) * args.batch * args.seq / dt
                print(f"step {step:5d} loss {loss:8.4f} gnorm {gn:8.3f} "
                      f"{tput:8.0f} tok/s")
            if args.ckpt and step and step % 100 == 0:
                save(args.ckpt, (params, opt_state), step,
                     {"arch": cfg.name})
        if args.ckpt:
            save(args.ckpt, (params, opt_state), start + args.steps,
                 {"arch": cfg.name})
            print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
