import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Roofline-term derivation from compiled dry-run artifacts.

Methodology (verified in tests/test_dryrun.py): XLA's
``compiled.cost_analysis()`` counts ``while`` bodies ONCE — scan trip
counts are NOT multiplied in.  A full train step is nested scans
(microbatches x layer stack), so raw full-step numbers undercount by the
trip counts.  We therefore lower each REPEATED COMPONENT separately on
the production mesh:

  layer:<kind>   one transformer block, fwd (+bwd with remat for train)
  encoder_layer  (enc-dec archs)
  embed_head     embedding lookup + final norm + LM head + loss
  optimizer      AdamW update over the full parameter pytree

and combine:  total = sum(component_cost x exact trip count).

Every component is a real ``jit(...).lower().compile()`` on the
production mesh — same sharding rules as the full step — so FLOPs, HBM
bytes and the collective mix come from the partitioned per-device HLO,
not an analytic model.  MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) is
reported alongside as the "useful compute" yardstick.
"""

import functools
import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.kernels.policy import set_policy
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import INPUT_SHAPES, shape_applicable
from repro.models.config import ModelConfig
from repro.models.model import (_empty_cache_block, apply_block, init_block,
                                layer_groups)
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.sharding.rules import param_specs, to_named
from repro.launch.hloparse import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   collective_bytes,
                                   normalize_cost_analysis)

SDS = jax.ShapeDtypeStruct
N_MICRO = 8


def _cost(compiled):
    c = normalize_cost_analysis(compiled.cost_analysis())
    return {
        "flops": float(c.get("flops", 0.0)),
        "hbm_bytes": float(c.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(compiled.as_text()),
    }


def _slice_group(tree):
    return jax.tree.map(lambda a: SDS(a.shape[1:], a.dtype), tree)


def _group_specs(cfg, mesh, gname, params_struct, mode="train"):
    full = param_specs(params_struct, cfg, mesh, mode=mode)
    sliced = jax.tree.map(lambda s: P(*tuple(s)[1:]),
                          full["groups"][gname],
                          is_leaf=lambda x: isinstance(x, P))
    return to_named(sliced, mesh)


def _bdims(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def layer_component(cfg: ModelConfig, kind: str, gname: str, mesh,
                    batch: int, seq: int, mode: str, params_struct,
                    serve_mode: str = "train"):
    """Lower one block (fwd/fwd+bwd/decode) and return its cost dict."""
    d = cfg.d_model
    bd = _bdims(mesh)
    import numpy as np
    nb = int(np.prod([mesh.shape[a] for a in bd]))
    bspec = bd if batch % nb == 0 else None
    x = SDS((batch, seq, d), jnp.bfloat16)
    xs = NamedSharding(mesh, P(bspec, None, None))
    lp_struct = _slice_group(params_struct["groups"][gname])
    lp_specs = _group_specs(cfg, mesh, gname, params_struct,
                            mode=serve_mode)
    pos = SDS((batch, seq), jnp.int32)
    pos_s = NamedSharding(mesh, P(bspec, None))
    ctx_extra = {}
    args = [lp_struct, x, pos]
    shardings = [lp_specs, xs, pos_s]
    if cfg.encdec and kind == "dec":
        eo = SDS((batch, cfg.encdec.n_frames, d), jnp.bfloat16)
        args.append(eo)
        shardings.append(NamedSharding(mesh, P(bspec, None, None)))

    if mode == "train":
        def f(lp, x, pos, *rest):
            ctx = {"positions": pos, "causal": True}
            if rest:
                ctx["enc_out"] = rest[0]

            def inner(lp, x):
                y, _, aux = apply_block(lp, x, cfg, kind, ctx)
                return jnp.sum(y.astype(jnp.float32)) + aux

            return jax.value_and_grad(
                jax.checkpoint(inner, prevent_cse=False),
                argnums=(0, 1))(lp, x)
    elif mode == "prefill":
        def f(lp, x, pos, *rest):
            ctx = {"positions": pos, "causal": True}
            if rest:
                ctx["enc_out"] = rest[0]
            y, _, _ = apply_block(lp, x, cfg, kind, ctx)
            return y
    else:  # decode
        cache = jax.eval_shape(
            functools.partial(_empty_cache_block, cfg, kind, batch, seq,
                              jnp.bfloat16))
        from repro.sharding.rules import decode_state_specs
        cspecs = to_named(decode_state_specs(cache, cfg, mesh), mesh)
        x1 = SDS((batch, 1, d), jnp.bfloat16)
        pos1 = SDS((batch, 1), jnp.int32)
        args = [lp_struct, x1, pos1, cache]
        shardings = [lp_specs, NamedSharding(mesh, P(bspec, None, None)),
                     NamedSharding(mesh, P(bspec, None)), cspecs]
        if cfg.encdec and kind == "dec":
            eo = SDS((batch, cfg.encdec.n_frames, d), jnp.bfloat16)
            args.append(eo)
            shardings.append(NamedSharding(mesh, P(bspec, None, None)))

        def f(lp, x, pos, cache, *rest):
            ctx = {"positions": pos, "causal": True}
            if rest:
                ctx["enc_out"] = rest[0]
            y, nc, _ = apply_block(lp, x, cfg, kind, ctx, cache=cache)
            return y, nc

    with mesh:
        compiled = jax.jit(f, in_shardings=tuple(shardings)) \
            .lower(*args).compile()
    return _cost(compiled)


def head_component(cfg: ModelConfig, mesh, batch: int, seq: int, mode: str,
                   params_struct, serve_mode: str = "train"):
    """Embedding lookup + final norm + head (+ loss & bwd for train)."""
    bd = _bdims(mesh)
    import numpy as np
    nb = int(np.prod([mesh.shape[a] for a in bd]))
    bspec = bd if batch % nb == 0 else None
    d = cfg.d_model
    # decode AND prefill heads touch only the sampled position (§Perf it.8)
    s = seq if mode == "train" else 1
    x = SDS((batch, s, d), jnp.bfloat16)
    xs = NamedSharding(mesh, P(bspec, None, None))
    keys = [k for k in ("embed", "lm_head", "final_norm")
            if k in params_struct]
    sub_struct = {k: params_struct[k] for k in keys}
    sub_specs = to_named({k: param_specs(params_struct, cfg, mesh,
                                         mode=serve_mode)[k]
                          for k in keys}, mesh)
    from repro.models.model import _norm_apply
    napp = _norm_apply(cfg)

    if mode == "train" and cfg.input_kind == "tokens":
        tokens = SDS((batch, s), jnp.int32)
        labels = SDS((batch, s), jnp.int32)
        ts = NamedSharding(mesh, P(bspec, None))

        def f(pp, tokens, h, labels):
            x = jnp.take(pp["embed"], tokens, axis=0) + h
            x = napp(pp["final_norm"], x, cfg.norm_eps)
            head = pp["lm_head"] if "lm_head" in pp else pp["embed"].T
            logits = x @ head
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, labels[..., None], -1)
            return jnp.mean(nll)

        g = jax.value_and_grad(f, argnums=(0, 2))
        with mesh:
            compiled = jax.jit(g, in_shardings=(sub_specs, ts, xs, ts)) \
                .lower(sub_struct, tokens, x, labels).compile()
    else:
        def f(pp, h):
            x = napp(pp["final_norm"], h, cfg.norm_eps)
            head = pp["lm_head"] if "lm_head" in pp else pp["embed"].T
            return x @ head

        with mesh:
            compiled = jax.jit(f, in_shardings=(sub_specs, xs)) \
                .lower(sub_struct, x).compile()
    return _cost(compiled)


def optimizer_component(cfg: ModelConfig, mesh, params_struct):
    pspecs = to_named(param_specs(params_struct, cfg, mesh), mesh)
    opt_struct = jax.eval_shape(init_opt_state, params_struct)
    ospecs = {"m": pspecs, "v": pspecs,
              "count": NamedSharding(mesh, P())}

    def f(p, g, o):
        return apply_updates(p, g, o, AdamWConfig())

    with mesh:
        compiled = jax.jit(f, in_shardings=(pspecs, pspecs, ospecs)) \
            .lower(params_struct, params_struct, opt_struct).compile()
    return _cost(compiled)


def roofline(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    set_policy("ref")
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.launch.specs import param_structs
    params_struct = param_structs(cfg)

    mode = shape.kind
    if mode == "train":
        mb = shape.global_batch // N_MICRO
        mult_layers = N_MICRO
        seq = shape.seq_len
    elif mode == "prefill":
        mb, mult_layers, seq = shape.global_batch, 1, shape.seq_len
    else:
        mb, mult_layers, seq = shape.global_batch, 1, shape.seq_len

    serve_mode = "train"
    if mode == "decode":
        from repro.launch.specs import decode_state_structs
        from repro.sharding.rules import serve_mode_fits
        state_struct = decode_state_structs(cfg, shape)
        if serve_mode_fits(params_struct, state_struct, mesh):
            serve_mode = "serve"

    components = []
    for gi, (kind, count) in enumerate(layer_groups(cfg)):
        gname = f"g{gi}_{kind}"
        c = layer_component(cfg, kind, gname, mesh, mb, seq, mode,
                            params_struct, serve_mode=serve_mode)
        components.append((f"layer:{kind}", count * mult_layers, c))
    if cfg.encdec and mode != "decode":
        c = layer_component(cfg, "enc", "encoder", mesh, mb,
                            cfg.encdec.n_frames,
                            "prefill" if mode != "train" else "train",
                            {"groups": {"encoder": params_struct["encoder"]}})
        components.append(("encoder_layer",
                           cfg.encdec.n_enc_layers * mult_layers, c))
    c = head_component(cfg, mesh, mb, seq, mode, params_struct,
                       serve_mode=serve_mode)
    components.append(("embed_head", mult_layers, c))
    if mode == "train":
        components.append(("optimizer", 1,
                           optimizer_component(cfg, mesh, params_struct)))

    flops = sum(m * c["flops"] for _, m, c in components)
    hbm = sum(m * c["hbm_bytes"] for _, m, c in components)
    coll_by_kind: dict[str, float] = {}
    for _, m, c in components:
        for k, v in c["collectives"].items():
            coll_by_kind[k] = coll_by_kind.get(k, 0.0) + m * v
    coll = sum(coll_by_kind.values())

    tokens = shape.global_batch * (shape.seq_len if mode == "train" else
                                   (shape.seq_len if mode == "prefill" else 1))
    n_active = cfg.param_count(active_only=True)
    model_flops = 6 * n_active * tokens if mode == "train" \
        else 2 * n_active * tokens
    chips = mesh.devices.size

    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": coll / ICI_BW,
    }
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "per_device": {"flops": flops, "hbm_bytes": hbm,
                       "collective_bytes": coll,
                       "collectives": coll_by_kind},
        "roofline_seconds": terms,
        "bottleneck": max(terms, key=terms.get),
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / (flops * chips)
        if flops else 0.0,
        "components": [
            {"name": n, "mult": m, **c} for n, m, c in components],
    }
    if verbose:
        print(f"[{arch} x {shape_name} @ {result['mesh']}] "
              + ", ".join(f"{k}={v * 1e3:.2f}ms" for k, v in terms.items())
              + f" -> {result['bottleneck']}"
              + f" | useful-flops ratio {result['useful_flops_ratio']:.2f}")
    return result


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    from repro.configs import ARCHS
    combos = ([(a, s) for a in ARCHS if not a.startswith("llama")
               for s in INPUT_SHAPES] if args.all
              else [(args.arch, args.shape)])
    for arch, shape in combos:
        try:
            r = roofline(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:  # noqa: BLE001
            print(f"[{arch} x {shape}] FAILED: {type(e).__name__}: {e}")
            r = {"arch": arch, "shape": shape,
                 "error": f"{type(e).__name__}: {e}"}
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
