"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init — the dry-run sets
``--xla_force_host_platform_device_count=512`` before any import).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis
    carries cross-pod data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py sets this automatically)")
    dev_array = np.array(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_runtime_mesh(n_devices: int | None = None, axis: str = "dev") -> Mesh:
    """1-D mesh for the communication-plan execution backend
    (``repro.runtime``): one axis over the first ``n_devices`` host
    devices; HSPMD logical device ids map onto axis positions."""
    devices = jax.devices()
    n = n_devices or len(devices)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(see repro.runtime.harness)")
    return Mesh(np.array(devices[:n]), (axis,))


def make_smoke_mesh(n_devices: int | None = None,
                    axes=("data", "model")) -> Mesh:
    """Tiny mesh over whatever devices exist (tests: usually 1)."""
    devices = jax.devices()
    n = n_devices or len(devices)
    shape = (1, n) if len(axes) == 2 else (n,)
    return Mesh(np.array(devices[:n]).reshape(shape), axes)
