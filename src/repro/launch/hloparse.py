"""HLO text parsing + TPU hardware constants (import-side-effect-free).

`launch.dryrun` / `launch.roofline` mutate XLA_FLAGS at import (they must —
the 512-device count locks at first jax init).  Everything other code
needs from them lives here so tests and benchmarks never inherit that
environment mutation into child processes.
"""

from __future__ import annotations

import re

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],{}: #*\"]+\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(expr: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(expr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def normalize_cost_analysis(cost) -> dict:
    """``Compiled.cost_analysis()`` returned ``[dict]`` through jax 0.4.x
    and a plain ``dict`` from 0.5 on; normalize to one flat dict."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op in the per-device
    program (proxy for on-wire traffic per device per step)."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(2).lower()
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    return out
