"""Multi-CPU-device harness.

jax locks the host device count at first backend init, so anything that
needs N > 1 devices must either set ``XLA_FLAGS`` before importing jax
(:func:`ensure_host_devices`) or run in a child process with the flag in
its environment (:func:`run_subprocess` — the pattern the test suite uses
so the main pytest process keeps seeing one device, per the dry-run spec).
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

FORCE_FLAG = "--xla_force_host_platform_device_count"
DEFAULT_DEVICES = 8


def _repo_root() -> str:
    # src/repro/runtime/harness.py -> repo root
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def host_device_env(n_devices: int = DEFAULT_DEVICES,
                    base: dict | None = None) -> dict:
    """Environment for a child process that must see ``n_devices`` host
    devices (existing XLA_FLAGS are preserved, any prior force-count flag
    is replaced)."""
    env = dict(os.environ if base is None else base)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(FORCE_FLAG)]
    flags.append(f"{FORCE_FLAG}={n_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.join(_repo_root(), "src")
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
    return env


def ensure_host_devices(n_devices: int = DEFAULT_DEVICES) -> None:
    """Make this process see ``n_devices`` host devices.

    Must run before jax initializes its backend; raises with instructions
    when it is already too late.
    """
    if "jax" in sys.modules:
        initialized = True
        try:
            from jax._src import xla_bridge
            initialized = xla_bridge.backends_are_initialized()
        except Exception:  # noqa: BLE001 — private API moved: assume locked
            pass
        if initialized:
            import jax
            have = len(jax.devices())
            if have < n_devices:
                raise RuntimeError(
                    f"jax already initialized with {have} device(s); set "
                    f"XLA_FLAGS={FORCE_FLAG}={n_devices} before importing "
                    f"jax (or use runtime.harness.run_subprocess)")
            return
        # imported but backend not created yet: XLA_FLAGS still applies
    os.environ["XLA_FLAGS"] = host_device_env(n_devices)["XLA_FLAGS"]


def run_subprocess(source: str, n_devices: int = DEFAULT_DEVICES,
                   timeout: float = 560.0,
                   extra_args: list[str] | None = None
                   ) -> subprocess.CompletedProcess:
    """Run ``python -c source`` (or ``python -m source`` when it names a
    dotted module path) with ``n_devices`` forced host devices and src on
    PYTHONPATH."""
    if re.fullmatch(r"[A-Za-z_][\w]*(\.[A-Za-z_][\w]*)*", source):
        cmd = [sys.executable, "-m", source]
    else:
        cmd = [sys.executable, "-c", source]
    return subprocess.run(cmd + (extra_args or []), capture_output=True,
                          text=True, env=host_device_env(n_devices),
                          timeout=timeout, cwd=_repo_root())
